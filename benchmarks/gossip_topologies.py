"""Per-topology gossip backend comparison: bytes/round and step time.

For every backhaul topology the paper evaluates (ring, torus, star,
complete, ER p∈{0.2,0.4,0.6}) this prints, per ``gossip_impl`` backend:

- neighbor-traffic bits moved per inter-cluster aggregation (per replica
  and network-total, from ``core.runtime.gossip_traffic_per_round`` — the
  formulas the GossipSchedule lowering realizes), plus the schedule shape
  (number of ppermute matchings / rotations), and
- with ``--measure``, measured wall time of the jitted inter-cluster mix
  on an 8-fake-device host mesh.

Asserts the headline claim: for every non-complete topology the sparse
backends move strictly less traffic than the dense all-gather.

  PYTHONPATH=src python benchmarks/gossip_topologies.py [--measure]
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

if "--measure" in sys.argv:  # must precede the first jax import
    os.environ.setdefault(
        "XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import numpy as np  # noqa: E402

from repro.config import FLConfig  # noqa: E402
from repro.core import topology as topo  # noqa: E402
from repro.core.gossip import GossipSchedule  # noqa: E402
from repro.core.runtime import gossip_traffic_per_round  # noqa: E402

M, DPC, PI = 8, 2, 3
MODEL_BITS = 6_603_710 * 32.0      # paper's FEMNIST CNN, fp32

CASES = [("ring", {}), ("torus", {"num_clusters": 9}), ("star", {}),
         ("erdos_renyi", {"er_prob": 0.2}),
         ("erdos_renyi", {"er_prob": 0.4}),
         ("erdos_renyi", {"er_prob": 0.6}),
         ("complete", {})]


def _case_name(name: str, kw) -> str:
    return (f"{name}_p{kw['er_prob']}" if name == "erdos_renyi" else name)


def measure_step_times(fl: FLConfig):
    """Wall time of the jitted inter-cluster mix per backend, on an m=4,
    dpc=2 geometry (R=8 replicas = the 8 fake host devices)."""
    import dataclasses
    import time

    import jax
    import jax.numpy as jnp
    from jax.sharding import Mesh
    from jax.sharding import PartitionSpec as P

    from repro.core.cefedavg import make_w_schedule, mix
    from repro.core.gossip import apply_gossip

    fl = dataclasses.replace(fl, num_clusters=4, devices_per_cluster=2)
    mesh = Mesh(np.asarray(jax.devices()).reshape(8, 1), ("data", "model"))
    R = fl.num_clusters * fl.devices_per_cluster
    sched = make_w_schedule(fl)
    x = jnp.asarray(np.random.default_rng(0).normal(
        size=(R, 1 << 18)).astype(np.float32))
    out = {}
    with mesh:
        for impl in ("dense", "sparse", "ringweight"):
            if impl == "dense":
                fn = jax.jit(lambda p: mix(sched.W_inter, p))
            else:
                gs = GossipSchedule.build(
                    sched.H, fl.pi, fl.devices_per_cluster,
                    mode="exact" if impl == "ringweight" else "rounds")
                fn = jax.jit(lambda p, gs=gs: apply_gossip(
                    gs, p, P("data"), mesh))
            jax.block_until_ready(fn(x))       # compile
            t0 = time.perf_counter()
            for _ in range(5):
                jax.block_until_ready(fn(x))
            out[impl] = (time.perf_counter() - t0) / 5
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--measure", action="store_true",
                    help="also time the jitted mix on 8 fake devices")
    args = ap.parse_args()

    print(f"{'topology':16s} {'impl':10s} {'matchings':>9s} "
          f"{'per_replica_MB':>14s} {'total_MB':>9s} {'vs_dense':>8s}"
          + ("  step_ms" if args.measure else ""))
    for name, kw in CASES:
        m = kw.pop("num_clusters", M)
        fl = FLConfig(num_clusters=m, devices_per_cluster=DPC, pi=PI,
                      topology=name, **kw)
        fl.validate()
        adj = topo.build_adjacency(name, m, fl)
        H = topo.mixing_matrix(adj)
        deg = adj.sum(1)
        times = (measure_step_times(fl)
                 if args.measure and name != "torus" else {})
        dense_total = None
        for impl in ("dense", "sparse", "ringweight"):
            tr = gossip_traffic_per_round(
                impl, num_clusters=m, devices_per_cluster=DPC, pi=PI,
                degrees=deg, model_bits=MODEL_BITS)
            if impl == "dense":
                dense_total = tr["total_bits"]
                nmatch = m * DPC - 1
            elif impl == "ringweight":
                nmatch = m - 1
            else:
                sch = GossipSchedule.build(H, PI, DPC, "rounds")
                nmatch = sch.num_matchings
                # the formula IS what the schedule moves — keep them honest
                assert sch.models_received_total(m * DPC) * MODEL_BITS == \
                    tr["total_bits"], (name, impl)
            ratio = tr["total_bits"] / dense_total
            if impl != "dense" and name != "complete":
                assert tr["total_bits"] < dense_total, \
                    f"{impl} must beat dense all-gather on {name}"
            extra = (f"  {times[impl] * 1e3:7.2f}" if impl in times else "")
            print(f"{_case_name(name, kw):16s} {impl:10s} {nmatch:9d} "
                  f"{tr['per_replica_bits'] / 8e6:14.1f} "
                  f"{tr['total_bits'] / 8e6:9.1f} {ratio:8.2f}" + extra)
    if args.measure:
        print("\nnote: step_ms is an 8-fake-device CPU host, where "
              "collectives are memcpys — the bytes columns are what govern "
              "wall time on real interconnects (see core/runtime.py).")
    print("\nOK: sparse and ringweight move less traffic than the dense "
          "all-gather on every non-complete backhaul.")


if __name__ == "__main__":
    main()
