"""Wall-clock time-to-accuracy: the paper's headline claim (§6, Figs. 5–6).

For each scenario — homogeneous devices, lognormal-heterogeneous speeds,
and heterogeneous + mobile (devices re-associate between edges) — runs
CE-FedAvg, Hier-FAvg and cloud FedAvg on the same federated task with the
same scenario seed (identical cohorts/speeds/mobility traces), couples the
simulation to the event clock under the paper's §6.1 hardware profile
(iPhone-class compute, 10/50/1 Mb/s links), and ASSERTS the paper's
ordering at the target accuracy:

    wall(CE-FedAvg)  <  wall(Hier-FAvg)   and
    wall(CE-FedAvg)  <  wall(FedAvg)

  PYTHONPATH=src python benchmarks/time_to_accuracy.py [--quick] [--full]
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from common import make_data, make_sim, paper_runtime  # noqa: E402

from repro.config import FLConfig  # noqa: E402
from repro.core.clock import run_wall_clock, time_to_accuracy  # noqa: E402
from repro.core.scenario import get_scenario  # noqa: E402

SCENARIO_NAMES = ("homogeneous", "lognormal", "mobility")
ALGOS = ("ce_fedavg", "hier_favg", "fedavg")


def run(*, rounds: int = 20, target: float = 0.75, full: bool = False,
        seed: int = 0, verbose: bool = True):
    """Run the 3×3 scenario×algorithm grid; returns {(scenario, algo): tta}.

    Asserts CE-FedAvg's wall-clock win in every scenario (the acceptance
    bar for the scenario engine) and that every algorithm reaches the
    target at all (otherwise the comparison would be vacuous)."""
    results = {}
    finals = {}
    for sname in SCENARIO_NAMES:
        sc = dataclasses.replace(get_scenario(sname), seed=seed)
        for algo in ALGOS:
            fl = FLConfig(algorithm=algo, num_clusters=4,
                          devices_per_cluster=4, tau=2, q=4, pi=10,
                          topology="ring")
            data = make_data(fl, full=full, noise=3.0, alpha=0.1, seed=seed)
            sim = make_sim(fl, data, full=full, lr=0.02, seed=seed,
                           scenario=sc)
            hist = run_wall_clock(sim, paper_runtime(fl, full=full), rounds)
            tta = time_to_accuracy(hist, target)
            results[(sname, algo)] = tta
            finals[(sname, algo)] = hist["acc"][-1]
            if verbose:
                reach = "never" if tta is None else f"{tta:10,.0f}s"
                print(f"  {sname:12s} {algo:11s} "
                      f"final_acc={hist['acc'][-1]:.3f} "
                      f"wall@{target:.0%}={reach}", flush=True)
    for sname in SCENARIO_NAMES:
        ce = results[(sname, "ce_fedavg")]
        hi = results[(sname, "hier_favg")]
        fa = results[(sname, "fedavg")]
        assert ce is not None, \
            f"[{sname}] CE-FedAvg never reached {target} " \
            f"(final {finals[(sname, 'ce_fedavg')]:.3f})"
        assert hi is not None and fa is not None, \
            f"[{sname}] a baseline never reached {target}: " \
            f"hier={hi} fedavg={fa}"
        assert ce < hi, f"[{sname}] CE {ce:.0f}s !< Hier-FAvg {hi:.0f}s"
        assert ce < fa, f"[{sname}] CE {ce:.0f}s !< FedAvg {fa:.0f}s"
        if verbose:
            print(f"[{sname}] OK: CE-FedAvg {ce:,.0f}s < "
                  f"Hier-FAvg {hi:,.0f}s, < FedAvg {fa:,.0f}s "
                  f"({(1 - ce / fa) * 100:.0f}% less than cloud FedAvg)")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer rounds (test-suite scale)")
    ap.add_argument("--full", action="store_true",
                    help="FEMNIST CNN on synthetic images instead of the "
                         "MLP surrogate")
    ap.add_argument("--target", type=float, default=0.75)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    rounds = 8 if args.quick else 20
    print(f"time-to-accuracy, target={args.target:.0%}, rounds≤{rounds}, "
          f"scenarios={SCENARIO_NAMES}")
    run(rounds=rounds, target=args.target, full=args.full, seed=args.seed)
    print("\nOK: CE-FedAvg reaches the target in less simulated wall time "
          "than both baselines in every scenario.")


if __name__ == "__main__":
    main()
