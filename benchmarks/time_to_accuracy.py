"""Wall-clock time-to-accuracy: the paper's headline claim (§6, Figs. 5–6).

For each scenario — homogeneous devices, lognormal-heterogeneous speeds,
and heterogeneous + mobile (devices re-associate between edges) — runs
CE-FedAvg, Hier-FAvg and cloud FedAvg on the same federated task with the
same scenario seed (identical cohorts/speeds/mobility traces), couples the
simulation to the event clock under the paper's §6.1 hardware profile
(iPhone-class compute, 10/50/1 Mb/s links), and ASSERTS the paper's
ordering at the target accuracy:

    wall(CE-FedAvg)  <  wall(Hier-FAvg)   and
    wall(CE-FedAvg)  <  wall(FedAvg)

A second, beyond-paper comparison (``run_schedules``) pits the
RoundProgram schedules against static CE-FedAvg on the SAME lognormal
fleet: adaptive per-cluster τ_k under the compute-bound edge profile
(microcontroller-class devices, where local training paces the round —
``runtime.compute_bound_runtime_model``), and time-varying π_t under
the paper's uplink-bound profile. ASSERTS

    wall(adaptive_tau)  <  wall(static)      (compute-bound, lognormal)

A third comparison (``run_async``) races async bounded-staleness
execution against the barrier on a lognormal straggler fleet with
client sampling (compute-bound profile) and ASSERTS

    wall(async, s=2)    <  wall(barrier)     (compute-bound, lognormal)

  PYTHONPATH=src python benchmarks/time_to_accuracy.py [--quick] [--full]
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))

from common import make_data, make_sim, paper_runtime  # noqa: E402

from repro.config import FLConfig  # noqa: E402
from repro.core.clock import run_wall_clock, time_to_accuracy  # noqa: E402
from repro.core.runtime import compute_bound_runtime_model  # noqa: E402
from repro.core.scenario import get_scenario  # noqa: E402

SCENARIO_NAMES = ("homogeneous", "lognormal", "mobility")
ALGOS = ("ce_fedavg", "hier_favg", "fedavg")


def run(*, rounds: int = 20, target: float = 0.75, full: bool = False,
        seed: int = 0, verbose: bool = True):
    """Run the 3×3 scenario×algorithm grid; returns {(scenario, algo): tta}.

    Asserts CE-FedAvg's wall-clock win in every scenario (the acceptance
    bar for the scenario engine) and that every algorithm reaches the
    target at all (otherwise the comparison would be vacuous)."""
    results = {}
    finals = {}
    for sname in SCENARIO_NAMES:
        sc = dataclasses.replace(get_scenario(sname), seed=seed)
        for algo in ALGOS:
            fl = FLConfig(algorithm=algo, num_clusters=4,
                          devices_per_cluster=4, tau=2, q=4, pi=10,
                          topology="ring")
            data = make_data(fl, full=full, noise=3.0, alpha=0.1, seed=seed)
            sim = make_sim(fl, data, full=full, lr=0.02, seed=seed,
                           scenario=sc)
            hist = run_wall_clock(sim, paper_runtime(fl, full=full), rounds)
            tta = time_to_accuracy(hist, target)
            results[(sname, algo)] = tta
            finals[(sname, algo)] = hist["acc"][-1]
            if verbose:
                reach = "never" if tta is None else f"{tta:10,.0f}s"
                print(f"  {sname:12s} {algo:11s} "
                      f"final_acc={hist['acc'][-1]:.3f} "
                      f"wall@{target:.0%}={reach}", flush=True)
    for sname in SCENARIO_NAMES:
        ce = results[(sname, "ce_fedavg")]
        hi = results[(sname, "hier_favg")]
        fa = results[(sname, "fedavg")]
        assert ce is not None, \
            f"[{sname}] CE-FedAvg never reached {target} " \
            f"(final {finals[(sname, 'ce_fedavg')]:.3f})"
        assert hi is not None and fa is not None, \
            f"[{sname}] a baseline never reached {target}: " \
            f"hier={hi} fedavg={fa}"
        assert ce < hi, f"[{sname}] CE {ce:.0f}s !< Hier-FAvg {hi:.0f}s"
        assert ce < fa, f"[{sname}] CE {ce:.0f}s !< FedAvg {fa:.0f}s"
        if verbose:
            print(f"[{sname}] OK: CE-FedAvg {ce:,.0f}s < "
                  f"Hier-FAvg {hi:,.0f}s, < FedAvg {fa:,.0f}s "
                  f"({(1 - ce / fa) * 100:.0f}% less than cloud FedAvg)")
    return results


def run_schedules(*, rounds: int = 16, target: float = 0.75,
                  seed: int = 0, verbose: bool = True):
    """RoundProgram schedules vs static CE-FedAvg on one lognormal fleet.

    All runs share the scenario seed (identical speeds/cohorts), so the
    only difference is the per-round program. Asserts the adaptive-τ_k
    win on the compute-bound profile — the acceptance bar for the IR:
    slow clusters take fewer local steps, so the max-over-participants
    compute charge collapses toward the fastest cluster's pace, and the
    small per-round accuracy loss repays itself in wall time. π_t decay
    is reported on the paper's uplink-bound profile (its win is in the
    backhaul term and is scenario-sized, so it is not asserted)."""
    sc = dataclasses.replace(get_scenario("lognormal"), seed=seed)
    fl = FLConfig(algorithm="ce_fedavg", num_clusters=4,
                  devices_per_cluster=4, tau=4, q=2, pi=10,
                  topology="ring")
    results = {}
    for name, schedule, rt in (
            ("static", None, compute_bound_runtime_model()),
            ("adaptive_tau", "adaptive_tau", compute_bound_runtime_model()),
            ("static_uplink", None, paper_runtime(fl)),
            ("pi_decay", "pi_decay", paper_runtime(fl))):
        data = make_data(fl, noise=3.0, alpha=0.1, seed=seed)
        sim = make_sim(fl, data, lr=0.02, seed=seed, scenario=sc,
                       schedule=schedule)
        hist = run_wall_clock(sim, rt, rounds)
        tta = time_to_accuracy(hist, target)
        results[name] = tta
        if verbose:
            reach = "never" if tta is None else f"{tta:10,.0f}s"
            print(f"  lognormal    {name:13s} "
                  f"final_acc={hist['acc'][-1]:.3f} "
                  f"wall@{target:.0%}={reach}", flush=True)
    st, ad = results["static"], results["adaptive_tau"]
    assert st is not None and ad is not None, \
        f"a schedule never reached {target}: static={st} adaptive={ad}"
    assert ad < st, \
        f"adaptive_tau {ad:.0f}s !< static {st:.0f}s (compute-bound)"
    if verbose:
        print(f"[schedules] OK: adaptive_tau {ad:,.0f}s < "
              f"static {st:,.0f}s ({(1 - ad / st) * 100:.0f}% less, "
              f"compute-bound lognormal fleet)")
        pd, su = results["pi_decay"], results["static_uplink"]
        if pd is not None and su is not None:
            print(f"[schedules] pi_decay {pd:,.0f}s vs static "
                  f"{su:,.0f}s (uplink-bound, reported)")
    return results


def run_async(*, rounds: int = 24, target: float = 0.70,
              staleness: int = 2, seed: int = 0, verbose: bool = True):
    """Async bounded-staleness vs barrier CE-FedAvg on one straggler
    fleet: lognormal-heterogeneous speeds with client sampling, under
    the compute-bound edge profile (local training paces the round —
    under the uplink-bound §6.1 constants the compute term async
    overlaps is milliseconds against minutes of communication).

    Both runs share the scenario seed, and the keyed per-(round,
    cluster) scenario draws guarantee they see identical cohorts and
    speeds; the only difference is the execution mode. Barrier rounds
    pay max-over-participants per block; async rounds let each cluster
    flow through its own timeline within ``staleness`` blocks of its
    gossip neighbors, so the per-round bottleneck cluster (re-drawn
    every round by sampling) stops pacing everyone else. ASSERTS

        wall_async(target)  <  wall_barrier(target)

    — the tentpole acceptance bar for async execution."""
    from repro.config import ScenarioConfig
    sc = ScenarioConfig(name="lognormal", speed_dist="lognormal",
                        speed_spread=0.6, sample_fraction=0.25,
                        dropout_prob=0.1, seed=seed)
    fl = FLConfig(algorithm="ce_fedavg", num_clusters=4,
                  devices_per_cluster=4, tau=2, q=4, pi=10,
                  topology="ring")
    rt = compute_bound_runtime_model()
    results = {}
    for name, s in (("barrier", None), (f"async_s{staleness}", staleness)):
        data = make_data(fl, noise=3.0, alpha=0.1, seed=seed)
        sim = make_sim(fl, data, lr=0.02, seed=seed,
                       scenario=dataclasses.replace(sc))
        hist = run_wall_clock(sim, rt, rounds, async_staleness=s)
        tta = time_to_accuracy(hist, target)
        results[name] = tta
        if verbose:
            reach = "never" if tta is None else f"{tta:10,.0f}s"
            print(f"  lognormal    {name:13s} "
                  f"final_acc={hist['acc'][-1]:.3f} "
                  f"wall@{target:.0%}={reach}", flush=True)
    ba, an = results["barrier"], results[f"async_s{staleness}"]
    assert ba is not None and an is not None, \
        f"a mode never reached {target}: barrier={ba} async={an}"
    assert an < ba, \
        f"async s={staleness} {an:.0f}s !< barrier {ba:.0f}s"
    if verbose:
        print(f"[async] OK: async s={staleness} {an:,.0f}s < "
              f"barrier {ba:,.0f}s ({(1 - an / ba) * 100:.0f}% less, "
              f"compute-bound lognormal straggler fleet)")
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer rounds (test-suite scale)")
    ap.add_argument("--full", action="store_true",
                    help="FEMNIST CNN on synthetic images instead of the "
                         "MLP surrogate")
    ap.add_argument("--target", type=float, default=0.75)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--schedules-only", action="store_true",
                    help="run only the RoundProgram schedule comparison")
    args = ap.parse_args()
    rounds = 8 if args.quick else 20
    print(f"time-to-accuracy, target={args.target:.0%}, rounds≤{rounds}, "
          f"scenarios={SCENARIO_NAMES}")
    if not args.schedules_only:
        run(rounds=rounds, target=args.target, full=args.full,
            seed=args.seed)
        print("\nOK: CE-FedAvg reaches the target in less simulated wall "
              "time than both baselines in every scenario.")
    print("\nRoundProgram schedules vs static CE-FedAvg (lognormal):")
    run_schedules(rounds=2 * rounds, target=args.target, seed=args.seed)
    print("\nOK: adaptive-tau reaches the target in less simulated wall "
          "time than the static schedule on the compute-bound profile.")
    print("\nAsync bounded-staleness vs barrier CE-FedAvg (lognormal "
          "stragglers + sampling):")
    run_async(rounds=3 * rounds, seed=args.seed)
    print("\nOK: async CE-FedAvg reaches the target in less simulated "
          "wall time than the barrier on the lognormal straggler fleet.")


if __name__ == "__main__":
    main()
