"""Shared benchmark scaffolding: synthetic-FEMNIST surrogate FL runs.

The paper's experiments are image classification under non-IID splits; on
this 1-core CPU host the benchmarks default to an MLP on synthetic
class-conditional Gaussians (same partitioners, same algorithms, same
runtime model) which preserves the paper's *relative orderings*. Pass
--full to run the actual FEMNIST CNN on synthetic images.
"""
from __future__ import annotations

import time
from typing import Dict, Optional

import jax.numpy as jnp

from repro.config import FLConfig
from repro.core.cefedavg import FLSimulator
from repro.core.runtime import RuntimeModel, paper_runtime_model
from repro.data.federated import (build_fl_data, cluster_partition,
                                  dirichlet_partition,
                                  make_synthetic_classification,
                                  make_synthetic_images)
from repro.models.cnn import (apply_femnist_cnn, apply_mlp_classifier,
                              init_femnist_cnn, init_mlp_classifier)

MLP_DIM, MLP_CLASSES = 16, 8


def make_data(fl: FLConfig, *, full: bool = False, cluster_iid=None,
              labels_per_cluster: int = 2, seed: int = 0,
              noise: float = 2.5, alpha: float = 0.3):
    # noise/alpha chosen so convergence takes several rounds (otherwise the
    # separable task converges in one round and time-to-accuracy ties)
    if full:
        x, y = make_synthetic_images(2048, 28, 1, 62, seed=seed)
        tx, ty = make_synthetic_images(512, 28, 1, 62, seed=seed + 1)
    else:
        x, y = make_synthetic_classification(1600, MLP_DIM, MLP_CLASSES,
                                             seed=seed, noise=noise)
        tx, ty = make_synthetic_classification(400, MLP_DIM, MLP_CLASSES,
                                               seed=seed + 1, noise=noise)
    if cluster_iid is None:
        parts = dirichlet_partition(y, fl.n, alpha, seed)
    else:
        parts = cluster_partition(y, fl.num_clusters,
                                  fl.devices_per_cluster,
                                  cluster_iid=cluster_iid,
                                  labels_per_cluster=labels_per_cluster,
                                  seed=seed)
    data = build_fl_data(x, y, parts, tx, ty, samples_per_device=64)
    return {k: jnp.asarray(v) for k, v in data.items()}


def make_sim(fl: FLConfig, data, *, full: bool = False, lr: float = 0.1,
             seed: int = 0, scenario=None, schedule=None, bank: bool = True,
             batch_size: int = 16) -> FLSimulator:
    if full:
        init = lambda k: init_femnist_cnn(k)            # noqa: E731
        apply = apply_femnist_cnn
    else:
        init = lambda k: init_mlp_classifier(k, MLP_DIM, 32,  # noqa: E731
                                             MLP_CLASSES)
        apply = apply_mlp_classifier
    return FLSimulator(init, apply, fl, data, lr=lr, batch_size=batch_size,
                       seed=seed, scenario=scenario, schedule=schedule,
                       bank=bank)


def paper_runtime(fl: FLConfig, *, full: bool = False) -> RuntimeModel:
    """Eq. (8) with the paper's §6.1 constants. The FEMNIST-CNN payload is
    used even in MLP-surrogate mode: the *learning* dynamics come from the
    surrogate, but the wall-time question Fig. 2/3 asks is about the
    paper's 6.6M-parameter uploads over 10/50/1 Mb/s links."""
    return paper_runtime_model()


def time_to_accuracy(hist: Dict, round_time: float,
                     target: float) -> Optional[float]:
    for r, a in zip(hist["round"], hist["acc"]):
        if a >= target:
            return r * round_time
    return None


class Timer:
    def __enter__(self):
        self.t0 = time.time()
        return self

    def __exit__(self, *a):
        self.dt = time.time() - self.t0


# every row() call also lands here so `benchmarks.run --json` can emit the
# machine-readable perf-trajectory records (BENCH_<tag>.json)
RECORDS: list = []


def row(name: str, us_per_call: float, derived: str):
    RECORDS.append({"name": name, "us_per_call": round(us_per_call, 1),
                    "derived": derived})
    print(f"{name},{us_per_call:.1f},{derived}", flush=True)


def reset_records():
    RECORDS.clear()


def dump_records(path: str) -> None:
    """Write the collected rows as a JSON list of
    ``{name, us_per_call, derived}`` records (the perf trajectory format
    described in docs/PERFORMANCE.md)."""
    import json
    with open(path, "w") as f:
        json.dump(RECORDS, f, indent=1)
        f.write("\n")
