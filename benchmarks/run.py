"""Benchmark harness — one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  PYTHONPATH=src python -m benchmarks.run [--full] [--only fig2,...]
    fig2  algorithm comparison: accuracy vs round + time-to-accuracy
    fig3  tau sweep at fixed q*tau
    fig4  cluster-count (m) sweep
    fig5  cluster-level IID vs non-IID (C = 2, 5, 8)
    fig6  backhaul topologies (ring / complete / ER(p))
    tab1  special-case equivalences (Table 1 / §4.3)
    kern  kernel-path microbenchmarks (XLA reference wall time, this host)
    roof  roofline summary from experiments/dryrun (if present)
    scale population scaling: streamed client store at n in {1e3, 1e4}
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from benchmarks.common import (Timer, make_data, make_sim,  # noqa: E402
                               paper_runtime, row, time_to_accuracy)
from repro.config import FLConfig  # noqa: E402

ROUNDS = 10
TARGET = 0.86


def _fl(algo="ce_fedavg", m=4, dpc=4, tau=2, q=8, pi=10, topology="ring",
        **kw):
    return FLConfig(algorithm=algo, num_clusters=m, devices_per_cluster=dpc,
                    tau=tau, q=q, pi=pi, topology=topology, **kw)


def fig2(full=False):
    """Fig. 2: CE-FedAvg vs FedAvg / Hier-FAvg / Local-Edge."""
    for algo, m, dpc in [("ce_fedavg", 4, 4), ("hier_favg", 4, 4),
                         ("fedavg", 1, 16), ("local_edge", 4, 4)]:
        fl = _fl(algo, m=m, dpc=dpc)
        sim = make_sim(fl, make_data(fl, full=full), full=full)
        with Timer() as t:
            hist = sim.run(ROUNDS)
        rt = paper_runtime(fl, full=full).round_time(algo, fl.tau, fl.q,
                                                     fl.pi)
        tta = time_to_accuracy(hist, rt, TARGET)
        row(f"fig2_{algo}", t.dt * 1e6 / ROUNDS,
            f"final_acc={hist['acc'][-1]:.3f};round_s={rt:.1f};"
            f"time_to_{TARGET:.0%}={'-' if tta is None else f'{tta:.0f}s'}")


def fig3(full=False):
    """Fig. 3: tau in {2,4,8} at fixed q*tau = 16."""
    for tau in (2, 4, 8):
        fl = _fl(tau=tau, q=16 // tau)
        sim = make_sim(fl, make_data(fl, full=full), full=full)
        with Timer() as t:
            hist = sim.run(ROUNDS)
        rt = paper_runtime(fl, full=full).round_time("ce_fedavg", tau,
                                                     16 // tau, fl.pi)
        tta = time_to_accuracy(hist, rt, TARGET)
        row(f"fig3_tau{tau}", t.dt * 1e6 / ROUNDS,
            f"final_acc={hist['acc'][-1]:.3f};round_s={rt:.1f};"
            f"time_to_{TARGET:.0%}={'-' if tta is None else f'{tta:.0f}s'}")


def fig4(full=False):
    """Fig. 4: m in {2,4,8} with n = 16 fixed (paper: n = 64, m<=16)."""
    n = 16
    for m in (2, 4, 8):
        fl = _fl(m=m, dpc=n // m)
        sim = make_sim(fl, make_data(fl, full=full), full=full)
        with Timer() as t:
            hist = sim.run(ROUNDS)
        row(f"fig4_m{m}", t.dt * 1e6 / ROUNDS,
            f"final_acc={hist['acc'][-1]:.3f};mean_acc="
            f"{np.mean(hist['acc']):.3f}")


def fig5(full=False):
    """Fig. 5: cluster-level data distribution (IID vs non-IID C)."""
    fl = _fl()
    for label, iid, C in [("iid", True, 0), ("noniid_C2", False, 2),
                          ("noniid_C5", False, 5)]:
        data = make_data(fl, full=full, cluster_iid=iid,
                         labels_per_cluster=max(C, 1))
        sim = make_sim(fl, data, full=full)
        with Timer() as t:
            hist = sim.run(ROUNDS)
        row(f"fig5_{label}", t.dt * 1e6 / ROUNDS,
            f"final_acc={hist['acc'][-1]:.3f};mean_acc="
            f"{np.mean(hist['acc']):.3f}")


def fig6(full=False):
    """Fig. 6: backhaul topology (ring, complete, ER p)."""
    from repro.core.cefedavg import make_w_schedule
    for label, topo, p in [("ring", "ring", 0.0),
                           ("er_p0.2", "erdos_renyi", 0.2),
                           ("er_p0.6", "erdos_renyi", 0.6),
                           ("complete", "complete", 0.0)]:
        fl = _fl(m=8, dpc=2, tau=1, q=1, pi=1, topology=topo, er_prob=p)
        sched = make_w_schedule(fl)
        sim = make_sim(fl, make_data(fl, full=full), full=full)
        with Timer() as t:
            hist = sim.run(ROUNDS)
        row(f"fig6_{label}", t.dt * 1e6 / ROUNDS,
            f"final_acc={hist['acc'][-1]:.3f};zeta={sched.zeta:.3f};"
            f"mean_acc={np.mean(hist['acc']):.3f}")


def tab1(full=False):
    """Table 1 / §4.3: special-case operator equivalences."""
    from repro.core.cefedavg import make_w_schedule
    s_ce = make_w_schedule(_fl("ce_fedavg", topology="complete", pi=1))
    s_h = make_w_schedule(_fl("hier_favg"))
    err1 = float(np.abs(s_ce.W_inter - s_h.W_inter).max())
    s1 = make_w_schedule(_fl("ce_fedavg", m=1, dpc=16))
    s2 = make_w_schedule(_fl("fedavg", m=1, dpc=16))
    err2 = float(np.abs(s1.W_inter - s2.W_inter).max())
    row("tab1_complete_equals_hier", 0.0, f"op_err={err1:.2e}")
    row("tab1_m1_equals_fedavg", 0.0, f"op_err={err2:.2e}")


def _smoke_compaction_sim(flc, scenario):
    """Compaction sim for --smoke: a 64->256->32 MLP (~25k params/row)
    on 64-sample batches, so per-round device work dominates the fixed
    host overhead and half/full_round_time reflects gradient-work
    scaling even on a 2-core CI runner."""
    from repro.core.cefedavg import FLSimulator
    from repro.data.federated import (build_fl_data, dirichlet_partition,
                                      make_synthetic_classification)
    from repro.models.cnn import apply_mlp_classifier, init_mlp_classifier
    x, y = make_synthetic_classification(1600, 64, 32, seed=0)
    tx, ty = make_synthetic_classification(128, 64, 32, seed=1)
    parts = dirichlet_partition(y, flc.n, 0.5, 0)
    data = build_fl_data(x, y, parts, tx, ty, samples_per_device=96)
    data = {k: jnp.asarray(v) for k, v in data.items()}
    return FLSimulator(
        lambda k: init_mlp_classifier(k, 64, 256, 32),
        apply_mlp_classifier, flc, data, lr=0.1, batch_size=64, seed=0,
        scenario=scenario)


def kern_bank(full=False, smoke=False):
    """ModelBank hot-path microbenchmarks (ISSUE 3 acceptance):

    1. the fused flat qτ-boundary — ONE in-place streaming pass with the
       precomputed W_inter·W_intra, exactly as the bank engine executes
       it — vs the per-leaf ``mix()`` baseline exactly as the legacy
       engine executes a global boundary: ``mix(W_intra, ·)`` inside the
       q-scan then ``mix(W_inter, ·)`` outside it (scan-separated, so
       XLA cannot fold the two passes; L tensordots + fresh output
       allocations per pass), at n=16 on the FEMNIST CNN. Each path is
       timed in its own tight best-of-reps loop (the standard kernel
       protocol): the bank side threads its donated buffer exactly as
       ``FLSimulator.step_round`` does, the legacy side re-calls on the
       resident pytree exactly as the legacy ``step_round`` does;
    2. cohort compaction — a 50%-participation scenario round vs a
       full-participation round of the same bank engine, wall-timed (the
       compacted round runs its gradient work on k_pad=8 rows, not 16).
    """
    from repro.core.cefedavg import make_w_schedule, mix
    from repro.kernels.gossip_mix import FlatLayout, gossip_mix_rows
    from repro.models.cnn import init_femnist_cnn
    n = 16
    fl = _fl(m=4, dpc=4)
    sched = make_w_schedule(fl)
    W_i = jnp.asarray(sched.W_intra, jnp.float32)
    W_e = jnp.asarray(sched.W_inter, jnp.float32)
    W_comb = jnp.asarray(sched.W_inter @ sched.W_intra, jnp.float32)
    # The boundary microbenchmark ALWAYS runs at the real FEMNIST-CNN
    # bank size (423 MB), --smoke included: the in-place fused pass
    # beats the per-leaf baseline *because* allocation/page-fault costs
    # dominate at that scale — at cache-or-near sizes the contrast
    # inverts or drowns in noise (measured 0.5x-4.3x at 1.6-21 MB
    # banks), which would make the CI regression guard meaningless.
    # Only the *round* benchmarks (compaction below) shrink under
    # --smoke; the boundary adds ~10 s.
    one = init_femnist_cnn(jax.random.PRNGKey(0))
    layout = FlatLayout.for_tree(one)
    params = jax.tree.map(
        lambda l: jnp.broadcast_to(l, (n,) + l.shape).copy(), one)
    params = jax.tree.map(
        lambda l: l * jax.random.normal(jax.random.PRNGKey(1),
                                        (n,) + (1,) * (l.ndim - 1)), params)
    Y = layout.flatten_stack(params)
    T = layout.total
    tag = "femnist_cnn"

    import functools
    import time as _time

    @jax.jit
    def f_leaf(p):
        # the legacy engine's qτ boundary: intra mix as the last op of
        # the scanned edge round, inter mix after the scan (the legacy
        # round does not donate — old and new params coexist)
        p, _ = jax.lax.scan(lambda c, _: (mix(W_i, c), None), p,
                            jnp.arange(1))
        return mix(W_e, p)

    # the bank engine's qτ boundary: one in-place pass on the donated
    # bank, each call consuming the previous round's buffer — timed by
    # threading the buffer exactly as FLSimulator.step_round does
    @functools.partial(jax.jit, donate_argnums=(0,))
    def f_flat(Y):
        return gossip_mix_rows(W_comb, Y)

    reps = 7
    jax.block_until_ready(f_leaf(params))
    jax.block_until_ready(f_leaf(params))
    t_leaf = t_flat = float("inf")
    for _ in range(reps):
        t0 = _time.perf_counter()
        jax.block_until_ready(f_leaf(params))
        t_leaf = min(t_leaf, _time.perf_counter() - t0)
    Yc = f_flat(Y)
    jax.block_until_ready(Yc)
    Yc = f_flat(Yc)
    jax.block_until_ready(Yc)
    for _ in range(reps):
        t0 = _time.perf_counter()
        Yc = f_flat(Yc)
        jax.block_until_ready(Yc)
        t_flat = min(t_flat, _time.perf_counter() - t0)
    speedup = t_leaf / t_flat
    row(f"kern_boundary_perleaf_{tag}_n{n}", t_leaf * 1e6,
        f"legacy qt-boundary;2 per-leaf passes;L={len(layout.sizes)};T={T}")
    row(f"kern_boundary_fused_{tag}_n{n}", t_flat * 1e6,
        f"bank qt-boundary;1 fused pass;speedup_vs_perleaf={speedup:.2f}x")
    if not smoke:
        assert speedup >= 2.0, (
            f"fused boundary must be >=2x the per-leaf baseline, got "
            f"{speedup:.2f}x")

    # -- cohort compaction: 50% participation vs full, wall-timed.
    # Best-of-reps per path (the standard tight-loop protocol above): a
    # mean over one or two rounds lets a stray recompile (a cohort
    # drawing a fresh bucket) or an allocator hiccup land inside the
    # measurement — observed up to ~4x outliers at smoke shapes, which
    # the CI regression guard would misread as a compaction regression.
    # Smoke mode also needs enough *device* work per round (bigger MLP,
    # bigger batch, q·τ = 4 local steps) that the ratio measures
    # gradient-work scaling and not the fixed per-round host overhead
    # the half path additionally pays for its scenario engine.
    from repro.config import ScenarioConfig
    rounds = 3 if smoke else 2
    rtag = "mlp_smoke" if smoke else "femnist_cnn"
    times = {}
    for frac in (1.0, 0.5):
        sc = (None if frac >= 1.0 else
              ScenarioConfig(name="bench", sample_fraction=frac, seed=0))
        if smoke:
            flc = _fl(m=4, dpc=4, tau=2, q=2, pi=2)
            sim = _smoke_compaction_sim(flc, sc)
        else:
            flc = _fl(m=4, dpc=4, tau=1, q=1, pi=2)
            sim = make_sim(flc, make_data(flc, full=True), full=True,
                           scenario=sc, batch_size=16)
        sim.step_round()                       # compile + first buckets
        jax.block_until_ready(sim.bank.params)
        best = float("inf")
        for _ in range(rounds):
            with Timer() as t:
                sim.step_round()
                jax.block_until_ready(sim.bank.params)
            best = min(best, t.dt)
        times[frac] = best
        label = "full" if frac >= 1.0 else "half"
        extra = (f"cohort_bucket={sim.last_bucket}" if frac < 1.0
                 else f"n={flc.n}")
        row(f"kern_round_{label}_participation_{rtag}", times[frac] * 1e6,
            f"bank_engine;{extra}")
    ratio = times[0.5] / times[1.0]
    row(f"kern_compaction_ratio_{rtag}", 0.0,
        f"half/full_round_time={ratio:.2f};gradient work scales with "
        f"cohort (<1.0 means compaction pays)")
    if not smoke:
        assert ratio < 0.85, (
            f"50% cohort must do measurably less work, ratio={ratio:.2f}")


def kern(full=False, smoke=False):
    """Kernel-path microbenchmarks (XLA reference path on this host; the
    Pallas kernels target TPU and are validated interpret-mode in tests)."""
    from repro.models.layers import attention_core
    from repro.models.ssm import ssd_chunked
    from repro.core.cefedavg import mix
    kern_bank(full=full, smoke=smoke)
    if smoke:
        return
    k = jax.random.PRNGKey(0)
    q = jax.random.normal(k, (1, 1024, 8, 64), jnp.float32)
    f = jax.jit(lambda q: attention_core(q, q, q, causal=True))
    f(q).block_until_ready()
    with Timer() as t:
        for _ in range(5):
            f(q).block_until_ready()
    row("kern_attention_1k", t.dt / 5 * 1e6, "xla_ref;B1_S1024_H8_D64")

    x = jax.random.normal(k, (1, 1024, 8, 32))
    dtv = jnp.abs(jax.random.normal(k, (1, 1024, 8))) * 0.1
    A = -jnp.ones((8,))
    Bm = jax.random.normal(k, (1, 1024, 32))
    g = jax.jit(lambda x, d, B: ssd_chunked(x, d, A, B, B, 128)[0])
    g(x, dtv, Bm).block_until_ready()
    with Timer() as t:
        for _ in range(5):
            g(x, dtv, Bm).block_until_ready()
    row("kern_ssd_1k", t.dt / 5 * 1e6, "xla_ref;B1_S1024_H8_P32_N32")

    W = jnp.ones((16, 16)) / 16
    params = {"w": jax.random.normal(k, (16, 1 << 18))}
    h = jax.jit(lambda p: mix(W, p))
    h(params)["w"].block_until_ready()
    with Timer() as t:
        for _ in range(5):
            h(params)["w"].block_until_ready()
    row("kern_gossip_mix_16MB", t.dt / 5 * 1e6, "xla_ref;n16_T262144_f32")


def roof(full=False):
    """Roofline summary from the dry-run records (EXPERIMENTS.md
    §Roofline); derived field mirrors the per-combination JSON."""
    recs = sorted(glob.glob("experiments/dryrun/*_16x16.json"))
    if not recs:
        row("roofline_missing", 0.0, "run repro.launch.dryrun first")
        return
    for path in recs:
        r = json.load(open(path))
        if "terms" not in r:
            continue
        t = r["terms"]
        row(f"roof_{r['arch']}_{r['shape']}", t["roofline_bound_s"] * 1e6,
            f"bottleneck={t['bottleneck']};comp={t['compute_s']:.3f};"
            f"mem={t['memory_s']:.3f};coll={t['collective_s']:.3f};"
            f"useful={r['useful_ratio']:.3f}")


def async_clock(full=False, smoke=False):
    """Async bounded-staleness vs barrier makespan — pure clock math,
    deterministic and host-independent: cumulative wall clock over N
    rounds of the canonical CE-FedAvg program on the lognormal
    straggler fleet with client sampling (the run_async benchmark
    scenario), charged barrier (`charge_program`) vs async
    (`charge_program_async`, carried across rounds). The s=2 record's
    ``async/barrier_makespan`` ratio is a regression contract: async
    must never charge MORE than the barrier (check_regression caps it
    at 1.0)."""
    import dataclasses

    from repro.core.clock import EventClock
    from repro.core.runtime import compute_bound_runtime_model
    from repro.core.scenario import ScenarioEngine, get_scenario
    fl = _fl(m=4, dpc=4, tau=2, q=4)
    prog = fl.round_program()
    rt = compute_bound_runtime_model()
    sc = dataclasses.replace(get_scenario("lognormal"), speed_spread=0.6,
                             sample_fraction=0.25, dropout_prob=0.1)
    rounds = 8 if smoke else 24
    eng = ScenarioEngine(sc, fl)
    realized = []
    for _ in range(rounds):
        plan = eng.step()
        speeds = np.asarray(eng.speed_multipliers) * rt.hw.device_flops
        realized.append((speeds, np.asarray(plan.mask, float),
                         np.asarray(plan.labels)))
    for s in (1, 2, 4):
        with Timer() as t:
            cb, ca = EventClock(rt, fl), EventClock(rt, fl)
            for speeds, mask, labels in realized:
                cb.charge_program(prog, speeds, mask)
                ca.charge_program_async(prog, speeds, mask, staleness=s,
                                        labels=labels)
        row(f"clock_async_s{s}_lognormal", t.dt * 1e6 / rounds,
            f"async/barrier_makespan={ca.now / cb.now:.4f};"
            f"rounds={rounds};async_s={ca.now:.1f};barrier_s={cb.now:.1f}")


def faults(full=False, smoke=False):
    """Graceful degradation under chaos-level fault injection
    (docs/FAULT_MODEL.md): CE-FedAvg with edge outages + backhaul link
    loss + straggler timeouts vs the fault-free run at matched rounds.
    The ``faulted/clean_final_acc`` ratio is the regression contract —
    check_regression floors it (faults may slow convergence, not wreck
    it); the faulted run must also still clear the accuracy target."""
    import dataclasses

    from repro.core.clock import run_wall_clock
    from repro.core.scenario import get_faults, get_scenario

    fl = _fl(m=4, dpc=4, tau=2, q=4)
    rounds = 6 if smoke else ROUNDS
    rt = paper_runtime(fl)
    base = dataclasses.replace(get_scenario("lognormal"),
                               speed_spread=0.6)
    hists = {}
    for tag, sc in (("clean", base),
                    ("chaos", dataclasses.replace(
                        base, faults=get_faults("chaos")))):
        data = make_data(fl, full=full, seed=0)
        sim = make_sim(fl, data, full=full, seed=0, scenario=sc)
        with Timer() as t:
            hists[tag] = run_wall_clock(sim, rt, rounds,
                                        eval_every=rounds)
        hists[tag]["dt"] = t.dt
    clean, chaos = hists["clean"], hists["chaos"]
    ratio = chaos["acc"][-1] / max(clean["acc"][-1], 1e-9)
    row("faults_chaos_cefedavg", chaos["dt"] * 1e6 / rounds,
        f"faulted/clean_final_acc={ratio:.4f};"
        f"faulted_acc={chaos['acc'][-1]:.4f};"
        f"clean_acc={clean['acc'][-1]:.4f};"
        f"faulted_wall_s={chaos['wall_time'][-1]:.1f};"
        f"clean_wall_s={clean['wall_time'][-1]:.1f};rounds={rounds}")
    if not smoke:
        assert chaos["acc"][-1] >= TARGET, \
            f"faulted CE-FedAvg missed target: {chaos['acc'][-1]:.3f}"
        assert ratio >= 0.85, f"fault degradation too steep: {ratio:.3f}"


def scale(full=False, smoke=False):
    """Population scaling (ISSUE 9): the streamed client-state store at
    n in {10^3, 10^4} virtual clients — us/round plus the peak resident
    slab bytes and the compressed cold-store footprint. The contract is
    O(cohort) memory: both sizes run the same cohort config, so the
    resident slab must NOT grow with n. The ``resident_n10k/n1k``
    derived ratio is the regression guard (check_regression ceilings
    it); it is exact byte accounting of ``peak_slab_bytes``, identical
    on every host."""
    import dataclasses

    from repro.config import PopulationConfig
    from repro.core.cefedavg import FLSimulator
    from repro.core.scenario import get_scenario
    from repro.data.federated import (build_fl_data, dirichlet_partition,
                                      make_synthetic_classification)
    from repro.models.cnn import apply_mlp_classifier, init_mlp_classifier

    m = 4
    n = m * 4                                  # enumerated data shards
    fl = _fl(m=m, dpc=n // m, tau=2, q=2, pi=2)
    x, y = make_synthetic_classification(1600, 16, 8, seed=0, noise=2.5)
    tx, ty = make_synthetic_classification(400, 16, 8, seed=1, noise=2.5)
    parts = dirichlet_partition(y, n, alpha=0.3, seed=0)
    data = build_fl_data(x, y, parts, tx, ty, samples_per_device=64)
    base = get_scenario("sampled")
    rounds = 3 if smoke else 8
    peaks = {}
    for pop in (1_000, 10_000):
        scenario = dataclasses.replace(base, population=PopulationConfig(
            clients_per_cluster=pop // m, cohort_per_cluster=4))
        sim = FLSimulator(
            lambda k: init_mlp_classifier(k, 16, 32, 8),
            apply_mlp_classifier, fl, data, lr=0.1, batch_size=16,
            seed=0, scenario=scenario)
        sim.step_round()                       # compile + first bucket
        best = float("inf")
        for _ in range(rounds):
            # the streamed round ends with its host page-out, so the
            # wall time below is already synchronized — no block needed
            with Timer() as t:
                sim.step_round()
            best = min(best, t.dt)
        peaks[pop] = sim.peak_slab_bytes
        row(f"scale_pop_n{pop}", best * 1e6,
            f"peak_slab_bytes={sim.peak_slab_bytes};"
            f"store_bytes={sim.store.nbytes};"
            f"cohort_cap={sim.engine.cohort_cap};"
            f"population={sim.engine.population}")
    ratio = peaks[10_000] / max(peaks[1_000], 1)
    row("scale_resident_ratio", 0.0,
        f"resident_n10k/n1k={ratio:.4f};resident slab must track the "
        f"cohort, not the population")
    if not smoke:
        assert ratio <= 1.0 + 1e-9, (
            f"resident slab grew with population: {ratio:.4f}")

    # -- paging pipeline (ISSUE 10): serial vs double-buffered driver.
    # Config chosen so paging is the round, not a footnote: a wide MLP
    # (64->2048->32, ~200k params/row) under the int8 codec with a
    # small fixed cohort (3/cluster, full sampling, no dropout — one
    # slab bucket, so no mid-measurement recompiles) and tau=q=pi=1.
    # Per round the serial driver then pays host-side codec work plus
    # 2 full-width f32 H2D slabs, which is exactly what the pipelined
    # driver moves on device / shrinks to codec width. The two drivers
    # are stepped ALTERNATELY inside one loop (host load drift hits
    # both equally) and compared on median round time; the
    # ``pipelined/serial_round_us`` ratio at n=10^4 is the regression
    # contract — check_regression caps it at 1.0, the overlapped
    # driver must never fall behind the serial oracle it shadows.
    from repro.models.cnn import (apply_mlp_classifier as _apply,
                                  init_mlp_classifier as _init)
    flp = _fl(m=m, dpc=n // m, tau=1, q=1, pi=1)
    basep = dataclasses.replace(base, sample_fraction=1.0,
                                dropout_prob=0.0)
    xb, yb = make_synthetic_classification(1600, 64, 32, seed=0)
    txb, tyb = make_synthetic_classification(128, 64, 32, seed=1)
    partsb = dirichlet_partition(yb, n, 0.5, 0)
    datab = build_fl_data(xb, yb, partsb, txb, tyb,
                          samples_per_device=96)
    rounds = 10 if smoke else 16
    for pop in (1_000, 10_000):
        scenario = dataclasses.replace(basep, population=PopulationConfig(
            clients_per_cluster=pop // m, cohort_per_cluster=3,
            codec="int8"))
        sims, page0, ts = {}, {}, {}
        for tag, pipe in (("serial", False), ("pipelined", True)):
            sims[tag] = FLSimulator(
                lambda k: _init(k, 64, 2048, 32), _apply, flp, datab,
                lr=0.1, batch_size=16, seed=0, scenario=scenario,
                codec="int8", pipeline=pipe)
            for _ in range(3):                 # compile + warm pipeline
                sims[tag].step_round()
            page0[tag] = sims[tag]._page_seconds
            ts[tag] = []
        for _ in range(rounds):
            for tag in ("serial", "pipelined"):
                with Timer() as t:
                    sims[tag].step_round()
                ts[tag].append(t.dt)
        med = {tag: float(np.median(v)) for tag, v in ts.items()}
        for tag in ("serial", "pipelined"):
            sim = sims[tag]
            page = (sim._page_seconds - page0[tag]) / rounds
            extra = (f"rounds_per_s={1.0 / med[tag]:.2f};"
                     f"paging_frac={min(page / med[tag], 1.0):.3f};"
                     f"population={pop};T={sim._layout.total};"
                     f"codec=int8;rounds={rounds}")
            if tag == "pipelined":
                pr = med["pipelined"] / med["serial"]
                extra = f"pipelined/serial_round_us={pr:.4f};" + extra
            row(f"scale_{tag}_n{pop}", med[tag] * 1e6, extra)
    pr10k = med["pipelined"] / med["serial"]
    if not smoke:
        assert pr10k <= 1.0 + 1e-9, (
            f"pipelined round slower than serial: {pr10k:.4f}")


BENCHES = {"fig2": fig2, "fig3": fig3, "fig4": fig4, "fig5": fig5,
           "fig6": fig6, "tab1": tab1, "kern": kern, "roof": roof,
           "async": async_clock, "faults": faults, "scale": scale}


def main() -> None:
    import inspect
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="run the real FEMNIST CNN (slow on CPU)")
    ap.add_argument("--only", default="")
    ap.add_argument("--json", default="",
                    help="also write the rows as BENCH_<tag>.json records "
                         "({name, us_per_call, derived}; the perf "
                         "trajectory format, docs/PERFORMANCE.md)")
    ap.add_argument("--smoke", action="store_true",
                    help="CI perf-smoke mode: full-size fused-boundary "
                         "bench, reduced-shape rounds, no hard ratio "
                         "asserts (benchmarks/check_regression.py guards "
                         "the derived ratios instead)")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else list(BENCHES)
    from benchmarks.common import dump_records, reset_records
    reset_records()
    print("name,us_per_call,derived")
    try:
        for n in names:
            fn = BENCHES[n]
            kw = {"full": args.full}
            if "smoke" in inspect.signature(fn).parameters:
                kw["smoke"] = args.smoke
            fn(**kw)
    finally:
        # a failed perf assert must not discard the rows already timed
        if args.json:
            dump_records(args.json)


if __name__ == '__main__':
    main()
