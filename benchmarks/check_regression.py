"""CI perf regression guard (ISSUE 4): smoke bench vs committed baseline.

Absolute ``us_per_call`` numbers are not comparable across hosts or
shapes (the smoke lane runs tiny MLP surrogates on shared CI runners),
but the *derived ratios* in the bench records are contracts the hot path
must keep. This script parses the ``key=value`` fields out of the
``derived`` strings of a smoke-mode ``benchmarks.run --json`` file and
checks each guarded metric against the committed ``BENCH_<tag>.json``
baseline with a generous tolerance:

- ``speedup_vs_perleaf`` (fused single-pass qt-boundary vs the legacy
  2-pass per-leaf boundary) is memory-bound at every shape: the smoke
  value must stay within ``tolerance`` of the committed speedup
  (``smoke >= baseline / tolerance``).
- ``half/full_round_time`` (cohort compaction) only *pays* at real
  shapes — at smoke shapes fixed dispatch overhead dominates — so the
  guard is one-sided: the half-participation round must not blow past
  the full round by more than ``tolerance``
  (``smoke <= max(1, baseline) * tolerance``), which still catches the
  real failure modes (per-round recompiles, full-n gradient work plus
  the gather).
- ``faulted/clean_final_acc`` (graceful degradation under the chaos
  fault preset) must stay within ``tolerance`` of the committed ratio —
  an engine that crashes or collapses under injected faults fails the
  bench itself; one that quietly degrades accuracy fails this floor.

Exit code 1 on any regression or missing record; the smoke JSON is also
uploaded as a workflow artifact for the perf trajectory.

``--baseline`` defaults to the NEWEST committed ``BENCH_<tag>.json``
(highest pr-number tag), so landing a new trajectory point automatically
becomes the next guard baseline without touching CI.

  PYTHONPATH=src python -m benchmarks.check_regression \\
      --smoke bench_smoke.json [--baseline BENCH_pr3.json] --tolerance 2.5
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import re
import sys

# guarded metrics: (derived field, baseline records, smoke record, mode)
#   floor    smoke >= baseline / tol          (higher is better)
#   ceiling  smoke <= max(1, baseline) * tol  (lower is better, smoke
#            shapes may legitimately sit near 1)
#   cap1     smoke <= 1.0 exactly (deterministic clock math, identical
#            on every host — no tolerance)
# The boundary benchmark runs at the real FEMNIST bank size even under
# --smoke (the fused-pass advantage is scale-dependent), so its record
# name matches the baseline's; only the compaction rounds shrink — a
# baseline may therefore carry either the full-shape or the smoke-shape
# compaction record (full-lane BENCH_pr3 vs smoke-lane BENCH_pr5), so
# the baseline lookup takes candidates in preference order.
CHECKS = (
    ("speedup_vs_perleaf", ("kern_boundary_fused_femnist_cnn_n16",),
     "kern_boundary_fused_femnist_cnn_n16", "floor"),
    ("half/full_round_time", ("kern_compaction_ratio_femnist_cnn",
                              "kern_compaction_ratio_mlp_smoke"),
     "kern_compaction_ratio_mlp_smoke", "ceiling"),
    # async rounds must never charge MORE wall clock than the barrier —
    # pure deterministic clock math, so no host tolerance: hard cap 1.0
    ("async/barrier_makespan", ("clock_async_s2_lognormal",),
     "clock_async_s2_lognormal", "cap1"),
    # graceful degradation: final accuracy under chaos faults (edge
    # outages + link loss + straggler timeouts) relative to the
    # fault-free run of the same config. Training dynamics on the tiny
    # smoke surrogate are noisier than clock math, so the usual
    # floor-with-tolerance applies.
    ("faulted/clean_final_acc", ("faults_chaos_cefedavg",),
     "faults_chaos_cefedavg", "floor"),
    # O(cohort) memory (ISSUE 9): peak resident slab bytes of the
    # streamed client store at n=10^4 vs n=10^3 virtual clients under
    # the same cohort config. Exact byte accounting (host-independent)
    # that must not scale with the population; the ceiling tolerance
    # only absorbs one slab-bucket power-of-two step.
    ("resident_n10k/n1k", ("scale_resident_ratio",),
     "scale_resident_ratio", "ceiling"),
    # paging pipeline (ISSUE 10): the double-buffered driver vs the
    # serial streamed oracle at n=10^4, median round time over
    # alternately-stepped sims (host load drift cancels in the ratio).
    # The pipelined driver strictly removes work from the round — host
    # codec moved on device, f32 slabs off the link, params resident —
    # so like the async makespan this is a hard cap: never above 1.0.
    ("pipelined/serial_round_us", ("scale_pipelined_n10000",),
     "scale_pipelined_n10000", "cap1"),
)

_NUM = r"([-+0-9.eE]+)"


def derived_field(records, name, field: str) -> float:
    """Numeric ``field=<value>`` from the first present record of
    ``name`` (a record name, or a preference-ordered tuple of them)."""
    names = (name,) if isinstance(name, str) else tuple(name)
    by_name = {r["name"]: r for r in records}
    hit = next((n for n in names if n in by_name), None)
    if hit is None:
        raise KeyError(f"record {names!r} missing "
                       f"(have {sorted(by_name)})")
    derived = by_name[hit]["derived"]
    m = re.search(re.escape(field) + "=" + _NUM, derived)
    if not m:
        raise KeyError(f"field {field!r} missing from {hit!r}: {derived}")
    return float(m.group(1))


def newest_baseline(root: str = ".") -> str:
    """The newest committed ``BENCH_<tag>.json`` in ``root`` — highest
    ``pr<N>`` number first, lexicographic tag as a fallback."""
    paths = sorted(glob.glob(os.path.join(root, "BENCH_*.json")))
    if not paths:
        raise FileNotFoundError(f"no BENCH_*.json under {root!r}")

    def rank(p):
        m = re.search(r"BENCH_pr(\d+)\.json$", os.path.basename(p))
        return (1, int(m.group(1)), p) if m else (0, -1, p)
    return max(paths, key=rank)


def check(smoke_records, baseline_records, tolerance: float):
    """Evaluate every guarded metric; returns (failures, report lines)."""
    failures, lines = [], []
    for field, base_name, smoke_name, mode in CHECKS:
        base = derived_field(baseline_records, base_name, field)
        smoke = derived_field(smoke_records, smoke_name, field)
        if mode == "floor":
            bound = base / tolerance
            ok = smoke >= bound
            rel = f">= {bound:.2f}"
        elif mode == "cap1":
            # deterministic contract, tolerance-free: never above 1.0
            bound = 1.0 + 1e-9
            ok = smoke <= bound
            rel = "<= 1.00"
        else:
            bound = max(1.0, base) * tolerance
            ok = smoke <= bound
            rel = f"<= {bound:.2f}"
        lines.append(f"{'OK  ' if ok else 'FAIL'} {field}: smoke={smoke:.2f} "
                     f"{rel} (baseline={base:.2f}, tol={tolerance}x)")
        if not ok:
            failures.append(field)
    return failures, lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", required=True,
                    help="bench_smoke.json from benchmarks.run --smoke")
    ap.add_argument("--baseline", default=None,
                    help="committed perf-trajectory baseline (default: "
                         "the newest BENCH_*.json in the repo root)")
    ap.add_argument("--tolerance", type=float, default=2.5)
    args = ap.parse_args(argv)
    if args.baseline is None:
        args.baseline = newest_baseline(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))))
        print(f"baseline: {args.baseline}")
    with open(args.smoke) as f:
        smoke = json.load(f)
    with open(args.baseline) as f:
        baseline = json.load(f)
    try:
        failures, lines = check(smoke, baseline, args.tolerance)
    except KeyError as e:
        print(f"FAIL missing bench record: {e}")
        return 1
    print("\n".join(lines))
    if failures:
        print(f"perf regression in: {', '.join(failures)}")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
