"""Logical-axis sharding: map named parameter/activation axes to mesh axes.

Models annotate every array dimension with a *logical* name ("heads", "ff",
"vocab", ...). At launch time :func:`resolve_specs` turns those names into
``PartitionSpec``s for a concrete mesh, falling back to replication whenever
the dimension size is not divisible by the mesh axis (e.g. 40 heads on a
16-way model axis) so that every assigned architecture lowers on the fixed
production mesh.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# Logical axis -> preferred mesh axis. ``None`` = always replicated.
DEFAULT_RULES: Dict[str, Optional[str]] = {
    # parameter axes
    "vocab": "model",
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "ff": "model",
    "experts": "model",
    "layers": None,
    "state": None,
    "conv": None,
    "ssm_inner": "model",
    "ssm_heads": "model",
    "patch": None,
    # activation axes
    "batch": "data",
    "seq": None,
    "kv_seq": None,
    "replica": "replica",  # rewritten to the concrete replica axes at launch
}


def replica_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes that carry federated device replicas (pod+data if present)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def _resolve_one(
    shape: Tuple[int, ...],
    logical: Tuple[Optional[str], ...],
    mesh: Mesh,
    rules: Dict[str, Optional[str]],
) -> P:
    assert len(shape) == len(logical), (shape, logical)
    used: set = set()
    out = []
    for size, name in zip(shape, logical):
        if name == "?":
            out.append(P.UNCONSTRAINED)
            continue
        axis = rules.get(name) if name else None
        if axis == "replica":
            raxes = replica_axes(mesh)
            rsize = int(np.prod([mesh.shape[a] for a in raxes]))
            if raxes and size % rsize == 0 and not (set(raxes) & used):
                out.append(tuple(raxes) if len(raxes) > 1 else raxes[0])
                used.update(raxes)
            else:
                out.append(None)
            continue
        if (
            axis is not None
            and axis in mesh.axis_names
            and axis not in used
            and size % mesh.shape[axis] == 0
        ):
            out.append(axis)
            used.add(axis)
        else:
            out.append(None)
    return P(*out)


def resolve_specs(shapes: Any, logicals: Any, mesh: Mesh,
                  rules: Optional[Dict[str, Optional[str]]] = None) -> Any:
    """Map a pytree of ShapeDtypeStructs + a matching pytree of logical-axis
    tuples to a pytree of PartitionSpecs."""
    rules = dict(DEFAULT_RULES, **(rules or {}))
    return jax.tree.map(
        lambda s, l: _resolve_one(tuple(s.shape), tuple(l), mesh, rules),
        shapes,
        logicals,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def named_shardings(specs: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda p: NamedSharding(mesh, p), specs,
                        is_leaf=lambda x: isinstance(x, P))


def prepend_axis(logicals: Any, name: str) -> Any:
    """Prepend a logical axis (e.g. the FL replica axis) to every leaf."""
    return jax.tree.map(
        lambda l: (name,) + tuple(l),
        logicals,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x),
    )


def constrain(x: jax.Array, *logical: Optional[str],
              rules: Optional[Dict[str, Optional[str]]] = None) -> jax.Array:
    """with_sharding_constraint by logical names; no-op outside a mesh."""
    mesh = _current_mesh()
    if mesh is None or mesh.empty:
        return x
    rules = dict(DEFAULT_RULES, **(rules or {}))
    spec = _resolve_one(tuple(x.shape), tuple(logical), mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _current_mesh() -> Optional[Mesh]:
    try:
        env = jax._src.mesh.thread_resources.env  # type: ignore[attr-defined]
        m = env.physical_mesh
        return None if m.empty else m
    except Exception:
        return None
