from repro.core.topology import (  # noqa: F401
    build_adjacency,
    mixing_matrix,
    zeta,
    omega1,
    omega2,
    cluster_assignment,
    intra_cluster_operator,
    inter_cluster_operator,
    assignment_matrix,
    masked_intra_operator,
    masked_inter_operator,
)
from repro.core.cefedavg import FLSimulator, make_w_schedule  # noqa: F401
from repro.core.modelbank import (ModelBank, cohort_buckets,  # noqa: F401
                                  compact_plan)
from repro.core.gossip import GossipSchedule  # noqa: F401
from repro.core.runtime import (RuntimeModel, HardwareProfile,  # noqa: F401
                                gossip_traffic_per_round)
from repro.core.scenario import (ScenarioEngine, SCENARIOS,  # noqa: F401
                                 get_scenario, make_masked_w)
from repro.core.clock import (EventClock, run_wall_clock,  # noqa: F401
                              time_to_accuracy)
