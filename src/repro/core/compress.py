"""Uplink compression for CE-FedAvg (paper §2: quantization/sparsification).

The paper positions CFEL against communication-compression methods ([8],
[24] ATOMO, [25] FedPAQ) and the two are composable: devices upload
*compressed model deltas* at aggregation boundaries, shrinking the qW/b_d2e
and πW/b_e2e terms of eq. (8) at some convergence cost. Implemented:

- ``topk``   magnitude sparsification with error feedback (memory) —
             uploads fraction·|θ| values + indices;
- ``int8``   per-leaf affine quantization with stochastic rounding —
             uploads |θ| bytes instead of 4|θ|;
- ``none``   exact.

``compress_tree``/``decompress_tree`` operate leaf-wise and are used by the
simulator at intra-cluster boundaries; ``bits_per_param`` feeds the runtime
model so time-to-accuracy reflects the smaller payloads.

The cold-row codecs at the bottom (``encode_cold_rows`` /
``decode_cold_rows``) are the host-side numpy siblings of the uplink
path, used by the streaming client-state store
(``core/clientstore.py``) to keep paged-out client rows compressed:
same per-leaf affine int8 scheme as ``_int8_leaf``, but deterministic
rounding — a row paged out and back in must reproduce the identical
bytes on every visit, independent of any RNG stream.

As of the paging pipeline (ISSUE 10) these host codecs are the
*oracle* path: the pipelined driver encodes/decodes cold rows on
device via :mod:`repro.kernels.cold_codec`, whose kernels are asserted
byte-identical to ``encode_cold_rows``/``decode_cold_rows`` in
``tests/test_kernels.py``. The host path remains the store's default
for the serial driver and for snapshot/restore.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclass(frozen=True)
class CompressionConfig:
    kind: str = "none"          # none | topk | int8
    topk_frac: float = 0.05     # fraction of entries kept (topk)
    stochastic: bool = True     # stochastic rounding (int8)
    error_feedback: bool = True  # residual accumulation (topk)

    def validate(self):
        assert self.kind in ("none", "topk", "int8")
        assert 0.0 < self.topk_frac <= 1.0

    def bits_per_param(self) -> float:
        """Effective uplink bits per model parameter."""
        if self.kind == "none":
            return 32.0
        if self.kind == "int8":
            return 8.0
        # topk: 32-bit value + 32-bit index per kept entry
        return 64.0 * self.topk_frac


def _topk_leaf(x: jax.Array, frac: float) -> jax.Array:
    flat = x.reshape(-1)
    k = max(1, int(flat.size * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(flat) >= thresh
    return (flat * mask).reshape(x.shape)


def _int8_leaf(x: jax.Array, key: Optional[jax.Array],
               stochastic: bool) -> jax.Array:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    y = x / scale
    if stochastic and key is not None:
        y = jnp.floor(y + jax.random.uniform(key, y.shape))
    else:
        y = jnp.round(y)
    q = jnp.clip(y, -127, 127).astype(jnp.int8)
    return q.astype(x.dtype) * scale  # dequantized view (value-faithful)


def compress_tree(cfg: CompressionConfig, tree: Any,
                  residual: Optional[Any] = None,
                  key: Optional[jax.Array] = None
                  ) -> Tuple[Any, Optional[Any]]:
    """Returns (dequantized compressed tree, new error-feedback residual).

    The returned tree holds the *values the receiver reconstructs*, so it
    can be fed straight into the mixing operators; the compression loss is
    (tree + residual) - returned.
    """
    cfg.validate()
    if cfg.kind == "none":
        return tree, residual
    leaves, treedef = jax.tree.flatten(tree)
    res_leaves = (jax.tree.leaves(residual) if residual is not None
                  else [jnp.zeros_like(l) for l in leaves])
    keys = (jax.random.split(key, len(leaves)) if key is not None
            else [None] * len(leaves))
    out, new_res = [], []
    for leaf, res, k in zip(leaves, res_leaves, keys):
        src = leaf + (res if cfg.error_feedback else 0.0)
        if cfg.kind == "topk":
            sent = _topk_leaf(src, cfg.topk_frac)
        else:
            sent = _int8_leaf(src, k, cfg.stochastic)
        out.append(sent)
        new_res.append(src - sent if cfg.error_feedback
                       else jnp.zeros_like(leaf))
    return (jax.tree.unflatten(treedef, out),
            jax.tree.unflatten(treedef, new_res))


def compress_flat(cfg: CompressionConfig, vec: jax.Array,
                  residual: Optional[jax.Array],
                  key: Optional[jax.Array],
                  segments: Tuple[Tuple[int, int], ...]
                  ) -> Tuple[jax.Array, Optional[jax.Array]]:
    """Flat-domain :func:`compress_tree` for the ModelBank engine.

    ``vec``/``residual`` are one device's (T,) flattened update;
    ``segments`` are the static per-leaf ``(offset, size)`` boundaries of
    the bank's FlatLayout, so top-k selection and int8 scales stay
    *per-leaf* — identical semantics (and identical per-leaf key
    sequence) to the pytree path, just without materializing the tree."""
    cfg.validate()
    if cfg.kind == "none":
        return vec, residual
    keys = (jax.random.split(key, len(segments)) if key is not None
            else [None] * len(segments))
    out, new_res = [], []
    for (off, size), k in zip(segments, keys):
        src = vec[off:off + size]
        if cfg.error_feedback and residual is not None:
            src = src + residual[off:off + size]
        if cfg.kind == "topk":
            sent = _topk_leaf(src, cfg.topk_frac)
        else:
            sent = _int8_leaf(src, k, cfg.stochastic)
        out.append(sent)
        new_res.append(src - sent if cfg.error_feedback
                       else jnp.zeros_like(sent))
    return jnp.concatenate(out), (jnp.concatenate(new_res)
                                  if residual is not None else residual)


def compression_ratio(cfg: CompressionConfig) -> float:
    """Payload ratio vs uncompressed f32 (for the runtime model)."""
    return cfg.bits_per_param() / 32.0


# ---------------------------------------------------------------------------
# cold-row codecs (streaming client-state store, core/clientstore.py)
# ---------------------------------------------------------------------------

#: codecs a paged-out client row may be stored under. ``f32`` is
#: lossless (the default — it keeps resident-vs-streamed parity and
#: bit-identical resume exact); ``f16``/``int8`` trade round-trip error
#: for 2x/4x smaller cold rows.
COLD_CODECS = ("f32", "f16", "int8")

_COLD_DTYPE = {"f32": np.float32, "f16": np.float16, "int8": np.int8}


def cold_bits_per_param(codec: str) -> int:
    """Stored bits per parameter of one cold row (excl. int8 scales)."""
    return {"f32": 32, "f16": 16, "int8": 8}[codec]


def cold_dtype(codec: str) -> np.dtype:
    """Storage dtype of the ``q`` array for ``codec``."""
    return np.dtype(_COLD_DTYPE[codec])


def encode_cold_rows(rows: np.ndarray, codec: str,
                     segments: Tuple[Tuple[int, int], ...]
                     ) -> Dict[str, np.ndarray]:
    """Batch-encode (S, T) float32 client-state rows for the cold store.

    Host-side numpy on purpose: cold rows live off-accelerator, and the
    encode runs at round *boundaries*, not in the jitted round. Returns
    ``{"q": (S, T) codec dtype, "scale": (S, nseg) float32}`` —
    ``scale`` has width 0 for the non-affine codecs, so the pair is a
    fixed-structure checkpoint payload for every codec.

    ``int8`` quantizes per FlatLayout segment (one affine scale per
    leaf per row, ``scale = max|seg| / 127`` — the ``_int8_leaf``
    discipline) with **deterministic** ``np.rint`` rounding, so the
    absolute round-trip error is bounded by ``scale / 2`` per entry and
    re-encoding a decoded row is a fixed point."""
    assert codec in COLD_CODECS, codec
    rows = np.asarray(rows, np.float32)
    assert rows.ndim == 2, rows.shape
    S = rows.shape[0]
    if codec == "f32":
        return {"q": rows.copy(), "scale": np.zeros((S, 0), np.float32)}
    if codec == "f16":
        return {"q": rows.astype(np.float16),
                "scale": np.zeros((S, 0), np.float32)}
    q = np.empty(rows.shape, np.int8)
    scale = np.empty((S, len(segments)), np.float32)
    for j, (off, size) in enumerate(segments):
        seg = rows[:, off:off + size]
        s = (np.maximum(np.abs(seg).max(axis=1), 1e-12)
             / 127.0).astype(np.float32)
        scale[:, j] = s
        q[:, off:off + size] = np.clip(
            np.rint(seg / s[:, None]), -127, 127).astype(np.int8)
    return {"q": q, "scale": scale}


def decode_cold_rows(enc: Dict[str, np.ndarray], codec: str,
                     segments: Tuple[Tuple[int, int], ...]) -> np.ndarray:
    """Decode :func:`encode_cold_rows` output back to (S, T) float32."""
    assert codec in COLD_CODECS, codec
    q = np.asarray(enc["q"])
    if codec in ("f32", "f16"):
        return q.astype(np.float32)
    scale = np.asarray(enc["scale"], np.float32)
    out = np.empty(q.shape, np.float32)
    for j, (off, size) in enumerate(segments):
        out[:, off:off + size] = (q[:, off:off + size].astype(np.float32)
                                  * scale[:, j][:, None])
    return out
