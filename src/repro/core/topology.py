"""Edge-backhaul topologies and gossip mixing matrices (paper §3-§4).

The mixing matrix H must satisfy Assumption 4: supported on the graph,
doubly stochastic, symmetric, with spectral gap 1 - ζ > 0. We use
Metropolis–Hastings weights, which satisfy all of these for any connected
undirected graph.
"""
from __future__ import annotations

from typing import Optional

import numpy as np


# ---------------------------------------------------------------------------
# graphs
# ---------------------------------------------------------------------------

def ring(m: int) -> np.ndarray:
    adj = np.zeros((m, m), bool)
    for i in range(m):
        adj[i, (i + 1) % m] = adj[(i + 1) % m, i] = True
    if m == 1:
        adj[0, 0] = False
    return adj


def complete(m: int) -> np.ndarray:
    adj = np.ones((m, m), bool)
    np.fill_diagonal(adj, False)
    return adj


def star(m: int) -> np.ndarray:
    adj = np.zeros((m, m), bool)
    adj[0, 1:] = adj[1:, 0] = True
    return adj


def torus(m: int) -> np.ndarray:
    side = int(round(np.sqrt(m)))
    assert side * side == m, "torus requires a square number of nodes"
    adj = np.zeros((m, m), bool)
    for r in range(side):
        for c in range(side):
            i = r * side + c
            for j in ((r, (c + 1) % side), ((r + 1) % side, c)):
                jj = j[0] * side + j[1]
                if jj != i:
                    adj[i, jj] = adj[jj, i] = True
    return adj


def erdos_renyi(m: int, p: float, seed: int = 0) -> np.ndarray:
    """Connected ER graph (resample until connected, as in the paper's
    experiments with p in {0.2, 0.4, 0.6}).

    If 1000 samples all come out disconnected (tiny p), the last sample is
    superimposed with a ring — re-establishing the symmetric/zero-diagonal
    invariants explicitly and asserting connectivity rather than returning
    whatever the OR produced."""
    rng = np.random.default_rng(seed)
    adj = np.zeros((m, m), bool)
    for _ in range(1000):
        adj = rng.random((m, m)) < p
        adj = np.triu(adj, 1)
        adj = adj | adj.T
        if _connected(adj):
            return adj
    adj = adj | ring(m)
    adj = adj | adj.T
    np.fill_diagonal(adj, False)
    assert _connected(adj), "ring fallback must be connected"
    return adj


def _connected(adj: np.ndarray) -> bool:
    m = adj.shape[0]
    seen = {0}
    frontier = [0]
    while frontier:
        i = frontier.pop()
        for j in np.nonzero(adj[i])[0]:
            if j not in seen:
                seen.add(int(j))
                frontier.append(int(j))
    return len(seen) == m


TOPOLOGIES = {
    "ring": lambda m, cfg=None: ring(m),
    "complete": lambda m, cfg=None: complete(m),
    "star": lambda m, cfg=None: star(m),
    "torus": lambda m, cfg=None: torus(m),
    "erdos_renyi": lambda m, cfg=None: erdos_renyi(
        m, cfg.er_prob if cfg else 0.4, cfg.topology_seed if cfg else 0),
}


def build_adjacency(name: str, m: int, cfg=None) -> np.ndarray:
    if name not in TOPOLOGIES:
        raise ValueError(f"unknown topology {name!r}")
    adj = TOPOLOGIES[name](m, cfg)
    assert _connected(adj) or m == 1, f"{name}({m}) not connected"
    return adj


# ---------------------------------------------------------------------------
# mixing matrices
# ---------------------------------------------------------------------------

def mixing_matrix(adj: np.ndarray, kind: str = "metropolis") -> np.ndarray:
    """Doubly-stochastic symmetric H supported on the graph (Assumption 4)."""
    m = adj.shape[0]
    if m == 1:
        return np.ones((1, 1))
    deg = adj.sum(1)
    H = np.zeros((m, m))
    if kind == "metropolis":
        for i in range(m):
            for j in np.nonzero(adj[i])[0]:
                H[i, j] = 1.0 / (max(deg[i], deg[j]) + 1.0)
        np.fill_diagonal(H, 1.0 - H.sum(1))
    elif kind == "uniform_neighbor":
        dmax = deg.max()
        H = adj / (dmax + 1.0)
        np.fill_diagonal(H, 1.0 - H.sum(1))
    else:
        raise ValueError(kind)
    assert np.all(H >= -1e-12)
    return H


def zeta(H: np.ndarray) -> float:
    """ζ = max(|λ2|, |λm|) — second-largest eigenvalue magnitude."""
    ev = np.sort(np.abs(np.linalg.eigvalsh(H)))
    return float(ev[-2]) if len(ev) > 1 else 0.0


def omega1(z: float, pi: int) -> float:
    zp = z ** (2 * pi)
    return zp / (1.0 - zp) if zp < 1 else np.inf


def omega2(z: float, pi: int) -> float:
    zp = z ** pi
    if zp >= 1:
        return np.inf
    return 1.0 / (1.0 - zp * zp) + 2.0 / (1.0 - zp) + zp / (1.0 - zp) ** 2


# ---------------------------------------------------------------------------
# cluster operators (paper eq. 11)
# ---------------------------------------------------------------------------

def cluster_assignment(cluster_sizes) -> np.ndarray:
    """B in {0,1}^{m x n}: B[i,k]=1 iff device k in cluster i (contiguous)."""
    m = len(cluster_sizes)
    n = int(sum(cluster_sizes))
    B = np.zeros((m, n))
    k = 0
    for i, s in enumerate(cluster_sizes):
        B[i, k:k + s] = 1.0
        k += s
    return B


def intra_cluster_operator(cluster_sizes) -> np.ndarray:
    """V = B^T diag(c) B — within-cluster averaging (n x n)."""
    B = cluster_assignment(cluster_sizes)
    c = 1.0 / np.asarray(cluster_sizes, float)
    return B.T @ np.diag(c) @ B


def inter_cluster_operator(cluster_sizes, H: np.ndarray,
                           pi: int) -> np.ndarray:
    """B^T diag(c) H^pi B — cluster averaging followed by pi gossip steps."""
    B = cluster_assignment(cluster_sizes)
    c = 1.0 / np.asarray(cluster_sizes, float)
    Hp = np.linalg.matrix_power(H, pi)
    return B.T @ np.diag(c) @ Hp @ B
