"""Edge-backhaul topologies and gossip mixing matrices (paper §3-§4).

The mixing matrix H must satisfy Assumption 4: supported on the graph,
doubly stochastic, symmetric, with spectral gap 1 - ζ > 0. We use
Metropolis–Hastings weights, which satisfy all of these for any connected
undirected graph.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# graphs
# ---------------------------------------------------------------------------

def ring(m: int) -> np.ndarray:
    """Ring backhaul graph on m edge servers (paper §6.1 default)."""
    adj = np.zeros((m, m), bool)
    for i in range(m):
        adj[i, (i + 1) % m] = adj[(i + 1) % m, i] = True
    if m == 1:
        adj[0, 0] = False
    return adj


def complete(m: int) -> np.ndarray:
    """Complete backhaul graph: one gossip step equals cloud averaging
    (the §4.3 reduction CE-FedAvg → Hier-FAvg)."""
    adj = np.ones((m, m), bool)
    np.fill_diagonal(adj, False)
    return adj


def star(m: int) -> np.ndarray:
    """Star backhaul: server 0 is the hub (a cloud-like bottleneck that
    still satisfies Assumption 4's connectivity)."""
    adj = np.zeros((m, m), bool)
    adj[0, 1:] = adj[1:, 0] = True
    return adj


def torus(m: int) -> np.ndarray:
    """2-D torus backhaul (degree-4 grid with wraparound), m = side²."""
    side = int(round(np.sqrt(m)))
    assert side * side == m, "torus requires a square number of nodes"
    adj = np.zeros((m, m), bool)
    for r in range(side):
        for c in range(side):
            i = r * side + c
            for j in ((r, (c + 1) % side), ((r + 1) % side, c)):
                jj = j[0] * side + j[1]
                if jj != i:
                    adj[i, jj] = adj[jj, i] = True
    return adj


def erdos_renyi(m: int, p: float, seed: int = 0) -> np.ndarray:
    """Connected ER graph (resample until connected, as in the paper's
    experiments with p in {0.2, 0.4, 0.6}).

    If 1000 samples all come out disconnected (tiny p), the last sample is
    superimposed with a ring — re-establishing the symmetric/zero-diagonal
    invariants explicitly and asserting connectivity rather than returning
    whatever the OR produced."""
    rng = np.random.default_rng(seed)
    adj = np.zeros((m, m), bool)
    for _ in range(1000):
        adj = rng.random((m, m)) < p
        adj = np.triu(adj, 1)
        adj = adj | adj.T
        if _connected(adj):
            return adj
    adj = adj | ring(m)
    adj = adj | adj.T
    np.fill_diagonal(adj, False)
    assert _connected(adj), "ring fallback must be connected"
    return adj


def _connected(adj: np.ndarray) -> bool:
    m = adj.shape[0]
    seen = {0}
    frontier = [0]
    while frontier:
        i = frontier.pop()
        for j in np.nonzero(adj[i])[0]:
            if j not in seen:
                seen.add(int(j))
                frontier.append(int(j))
    return len(seen) == m


def connected_components(adj: np.ndarray) -> np.ndarray:
    """(m,) component label per node of a (possibly disconnected)
    adjacency — labels are 0..k-1 in order of each component's smallest
    node. Backhaul link loss (``FaultModel``) can partition the graph
    mid-run; gossip then runs per component (``mixing_matrix`` of a
    disconnected graph is block-diagonal over these labels), and the
    fault trace records the component count as the degradation signal."""
    m = adj.shape[0]
    comp = np.full(m, -1, dtype=np.int64)
    k = 0
    for s in range(m):
        if comp[s] >= 0:
            continue
        comp[s] = k
        frontier = [s]
        while frontier:
            i = frontier.pop()
            for j in np.nonzero(adj[i])[0]:
                if comp[j] < 0:
                    comp[j] = k
                    frontier.append(int(j))
        k += 1
    return comp


TOPOLOGIES = {
    "ring": lambda m, cfg=None: ring(m),
    "complete": lambda m, cfg=None: complete(m),
    "star": lambda m, cfg=None: star(m),
    "torus": lambda m, cfg=None: torus(m),
    "erdos_renyi": lambda m, cfg=None: erdos_renyi(
        m, cfg.er_prob if cfg else 0.4, cfg.topology_seed if cfg else 0),
}


def build_adjacency(name: str, m: int, cfg=None) -> np.ndarray:
    """Backhaul adjacency by name (ring/complete/star/torus/erdos_renyi),
    asserted connected so Assumption 4's spectral gap exists."""
    if name not in TOPOLOGIES:
        raise ValueError(f"unknown topology {name!r}")
    adj = TOPOLOGIES[name](m, cfg)
    assert _connected(adj) or m == 1, f"{name}({m}) not connected"
    return adj


# ---------------------------------------------------------------------------
# mixing matrices
# ---------------------------------------------------------------------------

def mixing_matrix(adj: np.ndarray, kind: str = "metropolis") -> np.ndarray:
    """Doubly-stochastic symmetric H supported on the graph (Assumption 4)."""
    m = adj.shape[0]
    if m == 1:
        return np.ones((1, 1))
    deg = adj.sum(1)
    H = np.zeros((m, m))
    if kind == "metropolis":
        for i in range(m):
            for j in np.nonzero(adj[i])[0]:
                H[i, j] = 1.0 / (max(deg[i], deg[j]) + 1.0)
        np.fill_diagonal(H, 1.0 - H.sum(1))
    elif kind == "uniform_neighbor":
        dmax = deg.max()
        H = adj / (dmax + 1.0)
        np.fill_diagonal(H, 1.0 - H.sum(1))
    else:
        raise ValueError(kind)
    assert np.all(H >= -1e-12)
    return H


def zeta(H: np.ndarray) -> float:
    """ζ = max(|λ2|, |λm|) — second-largest eigenvalue magnitude."""
    ev = np.sort(np.abs(np.linalg.eigvalsh(H)))
    return float(ev[-2]) if len(ev) > 1 else 0.0


def omega1(z: float, pi: int) -> float:
    """ω₁(ζ, π) of Theorem 1 (eq. 23): inter-cluster divergence factor."""
    zp = z ** (2 * pi)
    return zp / (1.0 - zp) if zp < 1 else np.inf


def omega2(z: float, pi: int) -> float:
    """ω₂(ζ, π) of Theorem 1 (eq. 23): gossip-error amplification factor."""
    zp = z ** pi
    if zp >= 1:
        return np.inf
    return 1.0 / (1.0 - zp * zp) + 2.0 / (1.0 - zp) + zp / (1.0 - zp) ** 2


# ---------------------------------------------------------------------------
# cluster operators (paper eq. 11)
# ---------------------------------------------------------------------------

def cluster_assignment(cluster_sizes) -> np.ndarray:
    """B in {0,1}^{m x n}: B[i,k]=1 iff device k in cluster i (contiguous)."""
    m = len(cluster_sizes)
    n = int(sum(cluster_sizes))
    B = np.zeros((m, n))
    k = 0
    for i, s in enumerate(cluster_sizes):
        B[i, k:k + s] = 1.0
        k += s
    return B


def intra_cluster_operator(cluster_sizes) -> np.ndarray:
    """V = B^T diag(c) B — within-cluster averaging (n x n)."""
    B = cluster_assignment(cluster_sizes)
    c = 1.0 / np.asarray(cluster_sizes, float)
    return B.T @ np.diag(c) @ B


def inter_cluster_operator(cluster_sizes, H: np.ndarray,
                           pi: int) -> np.ndarray:
    """B^T diag(c) H^pi B — cluster averaging followed by pi gossip steps."""
    B = cluster_assignment(cluster_sizes)
    c = 1.0 / np.asarray(cluster_sizes, float)
    Hp = np.linalg.matrix_power(H, pi)
    return B.T @ np.diag(c) @ Hp @ B


# ---------------------------------------------------------------------------
# depth>2 hierarchies: tiered groups and per-tier mixing operators
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Hierarchy:
    """A depth-L aggregation hierarchy as branching factors root→leaf.

    ``levels = (l_0, ..., l_{L-1})`` reads "l_0 regions × l_1 edges per
    region × ... × l_{L-1} devices per edge"; the paper's two-tier setup
    is ``(m, devices_per_cluster)``. A ``TierMix(ℓ)`` op averages each
    device group at tier ℓ and (for ℓ >= 1) gossips among sibling groups
    under their common parent, so its mixing matrix is block-diagonal —
    one backhaul graph per parent (``kron(I, H_block)``) — and tier 1 at
    depth 2 reduces exactly to the paper's edge backhaul ``InterGossip``.

    >>> h = Hierarchy((2, 2, 2))
    >>> [(lvl, h.tier_name(lvl), h.num_groups(lvl), h.group_size(lvl))
    ...  for lvl in range(h.depth)]
    [(0, 'device', 4, 2), (1, 'edge', 4, 2), (2, 'region', 2, 4)]
    """
    levels: Tuple[int, ...]

    def __post_init__(self):
        object.__setattr__(self, "levels", tuple(self.levels))
        assert len(self.levels) >= 2 and all(s >= 1 for s in self.levels), \
            f"hierarchy needs >= 2 tiers of size >= 1: {self.levels}"

    @staticmethod
    def from_config(fl) -> "Hierarchy":
        """The hierarchy of an :class:`repro.config.FLConfig` (its
        ``tiers`` property — depth 2 unless ``fl.hierarchy`` is set)."""
        return Hierarchy(tuple(fl.tiers))

    @property
    def depth(self) -> int:
        """Number of tiers L; valid TierMix levels are 0..L-1."""
        return len(self.levels)

    @property
    def n(self) -> int:
        """Total leaf devices."""
        return int(np.prod(self.levels))

    @property
    def num_edges(self) -> int:
        """Leaf clusters (the paper's m) = prod(levels[:-1])."""
        return int(np.prod(self.levels[:-1]))

    def num_nodes(self, level: int) -> int:
        """Aggregation nodes at tier ``level`` >= 1 (edges at 1, the
        ``levels[0]`` top nodes at L-1)."""
        assert 1 <= level < self.depth, (level, self.depth)
        return int(np.prod(self.levels[:self.depth - level]))

    def node_size(self, level: int) -> int:
        """Leaf devices under one tier-``level`` node."""
        return self.n // self.num_nodes(level)

    def num_siblings(self, level: int) -> int:
        """Gossip-graph size at tier ``level``: children of one parent
        (all ``levels[0]`` top nodes at the topmost tier)."""
        assert 1 <= level < self.depth, (level, self.depth)
        return self.levels[self.depth - 1 - level]

    def num_parents(self, level: int) -> int:
        """Independent gossip graphs (diagonal blocks of H_ℓ)."""
        return self.num_nodes(level) // self.num_siblings(level)

    # -- the partition a TierMix(level) averages over ------------------------
    def num_groups(self, level: int) -> int:
        """Device groups averaged by ``TierMix(level)``: tier 0 averages
        per edge (same partition as tier 1's pre-gossip mean)."""
        return self.num_nodes(max(level, 1))

    def group_size(self, level: int) -> int:
        """Devices per ``TierMix(level)`` group."""
        return self.n // self.num_groups(level)

    def tier_name(self, level: int) -> str:
        """Registry name of the tier: device / edge / region / tier<ℓ>."""
        return ("device", "edge", "region")[level] if level <= 2 \
            else f"tier{level}"

    def node_of_edge(self, level: int) -> np.ndarray:
        """(num_edges,) static map edge id → tier-``level`` node id
        (contiguous nesting); composes with mobility's device→edge
        labels to give device→node labels at any tier."""
        return np.arange(self.num_edges) // (
            self.num_edges // self.num_nodes(level))

    def node_labels(self, level: int, labels) -> np.ndarray:
        """(n,) device → tier-``level`` node id under device→edge
        assignment ``labels``."""
        return self.node_of_edge(level)[np.asarray(labels, int)]

    # -- per-tier mixing -----------------------------------------------------
    def adjacency(self, level: int, topology: str = "ring",
                  cfg=None) -> np.ndarray:
        """Block-diagonal backhaul adjacency of tier ``level``: one
        ``topology`` graph over each parent's ``num_siblings`` children
        (a single graph over all nodes at depth 2 / the top tier)."""
        blk = build_adjacency(topology, self.num_siblings(level), cfg)
        reps = self.num_parents(level)
        return np.kron(np.eye(reps, dtype=bool), blk).astype(bool)

    def mixing(self, level: int, topology: str = "ring",
               kind: str = "metropolis", cfg=None) -> np.ndarray:
        """H_ℓ: Metropolis weights of the (block-diagonal) tier graph.
        Block-diagonal adjacency gives kron(I, H_block) exactly, since
        Metropolis weights depend only on within-block degrees."""
        if self.num_siblings(level) == 1:
            return np.eye(self.num_nodes(level))
        return mixing_matrix(self.adjacency(level, topology, cfg), kind)

    def tier_operator(self, level: int, pi: int = 1,
                      topology: str = "ring", kind: str = "metropolis",
                      cfg=None) -> np.ndarray:
        """Dense (n, n) operator of ``TierMix(level, pi)`` under the
        static contiguous assignment: tier 0 is the intra-cluster V,
        tier ℓ >= 1 is B_ℓ^T diag(c) H_ℓ^π B_ℓ (eq. 11 generalized to
        the tier's node partition)."""
        if level == 0:
            return intra_cluster_operator(
                [self.levels[-1]] * self.num_edges)
        sizes = [self.node_size(level)] * self.num_nodes(level)
        return inter_cluster_operator(
            sizes, self.mixing(level, topology, kind, cfg), pi)


# ---------------------------------------------------------------------------
# generalized operators: unequal / time-varying clusters + participation
# (the scenario engine, core/scenario.py, builds these per global round)
# ---------------------------------------------------------------------------

def assignment_matrix(labels, m: int) -> np.ndarray:
    """B_t ∈ {0,1}^{m×n} from per-device cluster labels.

    Generalizes :func:`cluster_assignment` to arbitrary (non-contiguous,
    unequal, possibly time-varying) membership — mobility re-draws
    ``labels`` between global rounds."""
    labels = np.asarray(labels, int)
    assert labels.ndim == 1 and (0 <= labels).all() and (labels < m).all()
    B = np.zeros((m, labels.shape[0]))
    B[labels, np.arange(labels.shape[0])] = 1.0
    return B


def masked_cluster_average(B: np.ndarray,
                           mask: Optional[np.ndarray] = None) -> np.ndarray:
    """P ∈ R^{m×n}: row i averages uniformly over the *participating*
    members of cluster i (the renormalized diag(c)·B of eq. 11).

    A cluster whose members all sat the round out falls back to the plain
    member average (its devices did not train, so this is their shared
    edge model); a cluster with no members at all gets a zero row."""
    m, n = B.shape
    w = B if mask is None else B * np.asarray(mask, float)[None, :]
    counts = w.sum(1)
    sizes = B.sum(1)
    P = np.zeros_like(B)
    for i in range(m):
        if counts[i] > 0:
            P[i] = w[i] / counts[i]
        elif sizes[i] > 0:
            P[i] = B[i] / sizes[i]
    return P


def masked_intra_operator(B: np.ndarray,
                          mask: Optional[np.ndarray] = None) -> np.ndarray:
    """V_t = B^T P — intra-cluster averaging over participating devices.

    Every member (participating or not) is synced to its cluster's
    participant average, mirroring the edge pushing y_{t} down to all
    attached devices at the aggregation boundary (Algorithm 1 line 12).
    With ``mask`` all-ones this is exactly
    :func:`intra_cluster_operator` for the same membership."""
    return B.T @ masked_cluster_average(B, mask)


def masked_inter_operator(B: np.ndarray, H: np.ndarray, pi: int,
                          mask: Optional[np.ndarray] = None) -> np.ndarray:
    """B^T H^π P — the row-stochastic generalization of eq. 11's
    B^T diag(c) H^π B to unequal clusters and partial participation.

    For equal cluster sizes diag(c) = (1/s)·I commutes with H^π, so this
    coincides exactly with :func:`inter_cluster_operator`; for unequal
    sizes the paper's written order is no longer stochastic (its rows sum
    to c_i Σ_j H^π[i,j]·n_j ≠ 1) while this one always averages each
    cluster before gossiping. Rows are renormalized so empty clusters
    (zero rows of P) shed their weight onto the remaining clusters."""
    P = masked_cluster_average(B, mask)
    W = B.T @ np.linalg.matrix_power(H, pi) @ P
    s = W.sum(1, keepdims=True)
    # every device's own cluster is nonempty and H has positive diagonal,
    # so each row keeps positive mass even if other clusters are empty
    assert (s > 1e-12).all(), "device row lost all mass (empty own cluster?)"
    return W / s


def masked_global_average(n: int,
                          mask: Optional[np.ndarray] = None) -> np.ndarray:
    """A_t: every device receives the mean over participating devices —
    cloud aggregation (FedAvg / Hier-FAvg) over the sampled cohort.
    Uniform over all devices when the mask is empty or absent."""
    if mask is None or np.asarray(mask, float).sum() == 0:
        return np.ones((n, n)) / n
    mask = np.asarray(mask, float)
    return np.tile(mask / mask.sum(), (n, 1))


def renormalize_rows(W: np.ndarray, mask: np.ndarray) -> np.ndarray:
    """Restrict W's columns to participating devices and renormalize each
    row; rows left with no support become identity (the device keeps its
    model). Used to mask decentralized gossip (dec_local_sgd), where each
    device is its own edge and an offline device neither sends nor
    receives."""
    mask = np.asarray(mask, float)
    Wm = W * mask[None, :]
    out = np.eye(W.shape[0])
    s = Wm.sum(1)
    ok = (s > 1e-12) & (mask > 0)   # offline rows stay identity too
    out[ok] = Wm[ok] / s[ok, None]
    return out
