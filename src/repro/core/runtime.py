"""Runtime model — paper §4.2 eq. (8) and the baselines' adapted variants.

Total runtime of p global rounds of CE-FedAvg:
    p * [ max_k qτC/c_k + qW/b_d2e + πW/b_e2e ]
where C = FLOPs per SGD step, c_k device speed (FLOP/s), W model bits,
b_d2e device→edge uplink, b_e2e edge↔edge backhaul.

Baselines (paper §6.1 adaptation):
  FedAvg      p * [ qτC/c + W/b_d2c ]               (cloud aggregation)
  Hier-FAvg   p * [ qτC/c + (q-1)W/b_d2e + W/b_d2c ]
  Local-Edge  p * [ qτC/c + qW/b_d2e ]              (no inter-cluster)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional, Sequence, Tuple

MBPS = 1e6  # bits/s


@dataclass(frozen=True)
class HardwareProfile:
    """Paper §6.1 defaults: iPhone X devices, 10 Mb/s uplink,
    50 Mb/s backhaul, 1 Mb/s device→cloud."""
    device_flops: float = 691.2e9        # c_k
    b_d2e: float = 10 * MBPS
    b_e2e: float = 50 * MBPS
    b_d2c: float = 1 * MBPS
    bytes_per_param: int = 4
    # depth>2 hierarchies: bandwidth of tier ℓ's links for ℓ >= 2
    # (b_tiers[0] = tier 2 / region, ...); empty falls back to b_e2e
    b_tiers: Tuple[float, ...] = ()

    def tier_bandwidth(self, level: int) -> float:
        """Link bandwidth of a ``TierMix(level)`` exchange: the backhaul
        ``b_e2e`` for tier 1 (and any tier without its own entry), the
        per-tier override ``b_tiers[level-2]`` above it."""
        if level <= 1 or level - 2 >= len(self.b_tiers):
            return self.b_e2e
        return self.b_tiers[level - 2]

    @staticmethod
    def tpu_v5e(chips_per_replica: int = 16) -> "HardwareProfile":
        """TPU adaptation: replica = a model-parallel group of v5e chips;
        'uplink' = intra-pod ICI, 'backhaul' = inter-pod DCI."""
        return HardwareProfile(
            device_flops=197e12 * chips_per_replica,
            b_d2e=8 * 50e9 * 8,     # ICI: ~50 GB/s/link, 8 bits/byte
            b_e2e=25e9 * 8,         # DCI-ish slow tier
            b_d2c=2.5e9 * 8,
            bytes_per_param=2,
        )


@dataclass(frozen=True)
class WorkloadProfile:
    model_params: int                 # parameter count
    flops_per_step: float             # C: FLOPs of one SGD step (fwd+bwd)

    def model_bits(self, hw: HardwareProfile) -> float:
        """W in eq. (8): the parameter payload at the wire precision the
        hardware profile transmits (``hw.bytes_per_param``)."""
        return self.model_params * hw.bytes_per_param * 8.0


class RuntimeModel:
    """Eq. (8) wall-clock model, split into compute and communication.

    ``device_speeds`` (FLOP/s per device) makes the compute term the
    paper's max_k qτC/c_k straggler rule; ``compute_time`` also accepts a
    per-call subset of speeds so the event clock (core/clock.py) can charge
    only the devices participating in a given round."""

    def __init__(self, hw: HardwareProfile, wl: WorkloadProfile,
                 device_speeds: Optional[Sequence[float]] = None):
        self.hw = hw
        self.wl = wl
        self.speeds = list(device_speeds) if device_speeds else None

    def compute_time(self, steps: int,
                     speeds: Optional[Sequence[float]] = None) -> float:
        """max_k steps·C/c_k — the slowest (participating) device paces
        every aggregation boundary."""
        if speeds is not None and len(speeds):
            slowest = min(speeds)
        elif self.speeds:
            slowest = min(self.speeds)
        else:
            slowest = self.hw.device_flops
        return steps * self.wl.flops_per_step / slowest

    def comm_time(self, algorithm: str, q: int, pi: int,
                  uplink_ratio: float = 1.0) -> float:
        """Communication terms of one global round under eq. (8).

        ``uplink_ratio`` scales the device→edge payload (compression,
        core.compress.compression_ratio)."""
        W = self.wl.model_bits(self.hw)
        Wu = W * uplink_ratio
        hw = self.hw
        if algorithm == "ce_fedavg":
            return q * Wu / hw.b_d2e + pi * W / hw.b_e2e
        if algorithm == "hier_favg":
            return (q - 1) * Wu / hw.b_d2e + W / hw.b_d2c
        if algorithm == "fedavg":
            return Wu / hw.b_d2c
        if algorithm == "local_edge":
            return q * Wu / hw.b_d2e
        if algorithm == "dec_local_sgd":
            return pi * W / hw.b_e2e
        raise ValueError(algorithm)

    def round_time(self, algorithm: str, tau: int, q: int, pi: int,
                   uplink_ratio: float = 1.0,
                   speeds: Optional[Sequence[float]] = None) -> float:
        """Wall time of ONE global round (qτ local steps) under eq. (8)."""
        return (self.compute_time(q * tau, speeds)
                + self.comm_time(algorithm, q, pi, uplink_ratio))

    def total_time(self, algorithm: str, rounds: int, tau: int, q: int,
                   pi: int, uplink_ratio: float = 1.0) -> float:
        return rounds * self.round_time(algorithm, tau, q, pi, uplink_ratio)


def paper_runtime_model(
        device_speeds: Optional[Sequence[float]] = None) -> RuntimeModel:
    """The §6.1 reference runtime: iPhone-class devices over 10/50/1 Mb/s
    links carrying the FEMNIST CNN (6,603,710 params; C = 13.3 MFLOPs ×
    batch 50 × fwd+bwd factor 3). The single source for the constants the
    quickstart, the time-to-accuracy CLI and the benchmarks all price
    against."""
    return RuntimeModel(HardwareProfile(),
                        WorkloadProfile(6_603_710, 13.30e6 * 50 * 3),
                        device_speeds)


def compute_bound_runtime_model(
        device_speeds: Optional[Sequence[float]] = None) -> RuntimeModel:
    """A compute-dominated counterpart to :func:`paper_runtime_model`:
    microcontroller-class devices (100 MFLOP/s — two to three orders
    below the §6.1 iPhone) behind LAN-class links (50/200/10 Mb/s), the
    on-premise federated-edge regime where local training, not the
    uplink, paces the round. This is the profile under which schedule
    adaptations of the *compute* term (adaptive per-cluster τ_k,
    ``core.program.make_schedule("adaptive_tau", ...)``) move wall-clock
    time-to-accuracy; under the paper's uplink-bound §6.1 constants the
    compute term is milliseconds against minutes of communication."""
    return RuntimeModel(
        HardwareProfile(device_flops=0.1e9, b_d2e=50 * MBPS,
                        b_e2e=200 * MBPS, b_d2c=10 * MBPS),
        WorkloadProfile(6_603_710, 13.30e6 * 50 * 3),
        device_speeds)


def gossip_traffic_per_round(impl: str, *, num_clusters: int,
                             devices_per_cluster: int, pi: int,
                             degrees: Sequence[int],
                             model_bits: float) -> Dict[str, float]:
    """Inter-cluster aggregation traffic of one global round, in bits.

    Per-replica received bits (the latency-relevant number) and total
    network bits, by ``gossip_impl`` backend:

      dense      (R−1)·W   per replica — the (R,R)·(R,…) contraction
                 all-gathers every other replica's model
      sparse     π·deg(c)·W per replica (max over clusters reported) — π
                 gossip rounds, each receiving one model per backhaul edge
      ringweight (M−1)·W   per replica — M−1 weighted cyclic rotations

    ``degrees`` are the backhaul degrees deg(c) of the M clusters.
    """
    M, dpc = num_clusters, devices_per_cluster
    R = M * dpc
    W = float(model_bits)
    deg = list(degrees)
    assert len(deg) == M, (len(deg), M)
    if M == 1:
        return {"per_replica_bits": 0.0, "total_bits": 0.0}
    if impl == "dense":
        per, tot = (R - 1) * W, R * (R - 1) * W
    elif impl == "sparse":
        per, tot = pi * max(deg) * W, pi * sum(deg) * dpc * W
    elif impl == "ringweight":
        per, tot = (M - 1) * W, R * (M - 1) * W
    else:
        raise ValueError(impl)
    return {"per_replica_bits": per, "total_bits": tot}


def convergence_bound(T: int, eta: float, L: float, sigma2: float,
                      eps2: float, eps_i2: float, n: int, m: int,
                      tau: int, q: int, z: float, pi: int,
                      f_gap: float = 1.0) -> float:
    """Theorem 1 RHS (eq. 23) — used to sanity-check parameter effects."""
    from repro.core.topology import omega1, omega2
    o1, o2 = omega1(z, pi), omega2(z, pi)
    t1 = 2 * f_gap / (eta * T)
    t2 = eta * L * sigma2 / n
    t3 = 8 * eta**2 * L**2 * (o1 * q * tau + (m - 1) / n * q * tau) * sigma2
    t4 = 16 * eta**2 * L**2 * q**2 * tau**2 * o2 * eps2
    t5 = 8 * (n - m) / n * eta**2 * L**2 * tau * sigma2
    t6 = 16 * L**2 * eta**2 * tau**2 * eps_i2
    return t1 + t2 + t3 + t4 + t5 + t6
