"""Scenario engine: device heterogeneity, client sampling and mobility.

The paper's headline result is *wall-clock time to a target accuracy*
(§6, Figs. 5–6) on heterogeneous mobile devices, but the static
``make_w_schedule`` assumes every device trains every round in a fixed,
equal-size cluster. A :class:`ScenarioEngine` lifts those assumptions one
global round at a time:

- **heterogeneity** — per-device speed multipliers drawn once from a
  uniform / lognormal / bimodal distribution (all mean ≈ 1 so profiles
  stay comparable to the homogeneous §6.1 constants);
- **client sampling** — each round every cluster draws a
  ⌈fraction·|cluster|⌉ cohort of its members, thinned by straggler
  dropout; non-participants neither compute nor upload, and the
  V/A/H-operators are renormalized over the cohort
  (``topology.masked_*``);
- **mobility** — each device re-associates to a uniformly random other
  edge with probability ``move_prob`` per round (never emptying its
  current cluster), re-drawing the assignment matrix B_t and therefore
  the W_intra/W_inter pair for unequal, time-varying clusters.

``ScenarioEngine.step()`` returns a :class:`RoundPlan` whose operators
``FLSimulator`` feeds to its jitted round; ``core.clock.EventClock``
charges the plan's cohort for wall time. When the scenario is trivial
(full participation, no mobility) every plan reproduces the static
``make_w_schedule`` operators exactly — the parity regime asserted in
``tests/test_scenario.py``.

A :class:`FaultModel` (ISSUE 8) optionally layers *infrastructure*
faults on top: edge-server outage windows, backhaul link loss and
straggler timeouts, all realized from draws keyed by
``(fault seed, round, stream, entity)`` so the fault trace is a pure
function of the config and the round index — a killed-and-resumed run
replays the identical faults it would have seen uninterrupted
(``tests/test_scenario.py::test_fault_trace_*``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.config import FaultConfig, FLConfig, ScenarioConfig
from repro.core import topology as topo


def sample_speed_multipliers(sc: ScenarioConfig, n: int,
                             rng: np.random.Generator) -> np.ndarray:
    """Per-device relative speeds c_k / c̄ for the scenario's distribution.

    Multipliers are positive and have mean ≈ 1, so the homogeneous
    hardware profile's ``device_flops`` stays the fleet average."""
    if sc.speed_dist == "homogeneous":
        return np.ones(n)
    if sc.speed_dist == "uniform":
        lo, hi = 1.0 - sc.speed_spread, 1.0 + sc.speed_spread
        return rng.uniform(lo, hi, n)
    if sc.speed_dist == "lognormal":
        sigma = sc.speed_spread
        return rng.lognormal(mean=-0.5 * sigma * sigma, sigma=sigma, size=n)
    if sc.speed_dist == "bimodal":
        slow = rng.random(n) < sc.slow_fraction
        return np.where(slow, sc.slow_factor, 1.0)
    raise ValueError(sc.speed_dist)


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """One round's realized faults (see :class:`FaultModel`).

    ``cluster_down`` marks clusters whose edge server is dark this
    round; ``link_up`` is the symmetric keep-mask over the backhaul
    adjacency (``n_components`` counts the surviving graph's connected
    components — >1 means this round gossips per partition);
    ``attempts``/``timed_out`` record the straggler-timeout retry
    ladder (aborted attempts per device, and which devices were
    dropped after exhausting retries) with ``ref_mult`` the
    cohort-median speed multiplier their budgets were derived from."""
    round_index: int
    cluster_down: np.ndarray   # (m,) bool — edge server dark this round
    link_up: np.ndarray        # (m,m) bool — surviving backhaul links
    n_components: int          # components of the surviving graph
    attempts: np.ndarray       # (n,) int — aborted timeout attempts
    timed_out: np.ndarray      # (n,) bool — dropped after max_retries
    ref_mult: float            # cohort-median speed mult (budget basis)

    @property
    def any(self) -> bool:
        """True iff any fault fired this round."""
        return bool(self.cluster_down.any() or (~self.link_up).any()
                    or self.timed_out.any() or (self.attempts > 0).any())

    def trace(self) -> Tuple:
        """Hashable summary of the realized faults — what the replay
        determinism tests compare between a straight-through run and a
        killed-and-resumed one."""
        return (int(self.round_index),
                tuple(np.nonzero(self.cluster_down)[0].tolist()),
                tuple(map(tuple, np.argwhere(~self.link_up).tolist())),
                int(self.n_components),
                tuple(self.attempts.tolist()),
                tuple(np.nonzero(self.timed_out)[0].tolist()))


@dataclasses.dataclass(frozen=True)
class RoundPlan:
    """One global round's realized scenario: who participates, where each
    device lives, and the mixing operators those two facts induce.

    Under fault injection ``fault`` carries the round's
    :class:`FaultPlan` (``None`` on fault-free rounds) and ``H_eff``
    the link-loss-degraded mixing matrix the operators were built from
    (``None`` when every backhaul link survived)."""
    round_index: int
    num_clusters: int         # m
    labels: np.ndarray        # (n,) cluster id per device (B_t rows)
    mask: np.ndarray          # (n,) float 0/1 participation
    W_intra: np.ndarray       # (n,n) masked/unequal intra-cluster operator
    W_inter: np.ndarray       # (n,n) masked/unequal inter-cluster operator
    fault: Optional[FaultPlan] = None
    H_eff: Optional[np.ndarray] = None  # (m,m) degraded mixing matrix

    @property
    def active(self) -> np.ndarray:
        """Boolean participation (the cohort the clock charges)."""
        return self.mask > 0

    @property
    def cohort(self) -> np.ndarray:
        """Indices of the participating devices — the rows the ModelBank
        engine gathers into its compacted (k_pad, T) batch."""
        return np.nonzero(self.mask > 0)[0]

    @property
    def cluster_sizes(self) -> np.ndarray:
        """Device count per cluster under this round's B_t."""
        return np.bincount(self.labels, minlength=self.num_clusters)


def make_masked_w(fl: FLConfig, labels: np.ndarray, mask: np.ndarray,
                  H: np.ndarray,
                  pi: Optional[int] = None
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-round (W_intra, W_inter) for the algorithm under assignment
    ``labels`` and participation ``mask`` — the time-varying eq. 11.
    ``pi`` overrides the gossip depth of the inter operator (time-varying
    π_t schedules, ``core.program.InterGossip``); default ``fl.pi``.

    Reduces to :func:`repro.core.cefedavg.make_w_schedule`'s operators
    when ``labels`` is the contiguous equal-cluster assignment and
    ``mask`` is all-ones."""
    n = labels.shape[0]
    pi = fl.pi if pi is None else pi
    eye = np.eye(n)
    B = topo.assignment_matrix(labels, fl.num_clusters)
    if fl.algorithm == "ce_fedavg":
        return (topo.masked_intra_operator(B, mask),
                topo.masked_inter_operator(B, H, pi, mask))
    if fl.algorithm == "hier_favg":
        return (topo.masked_intra_operator(B, mask),
                topo.masked_global_average(n, mask))
    if fl.algorithm == "fedavg":
        return eye, topo.masked_global_average(n, mask)
    if fl.algorithm == "local_edge":
        V = topo.masked_intra_operator(B, mask)
        return V, V
    if fl.algorithm == "dec_local_sgd":
        Hp = np.linalg.matrix_power(H, pi)
        return eye, topo.renormalize_rows(Hp, mask)
    raise ValueError(fl.algorithm)


class FaultModel:
    """Keyed per-round fault realization of a
    :class:`repro.config.FaultConfig`.

    Stateless by construction: every draw reads a counter-based
    generator keyed by ``(fault seed, round, stream, entity)``, and an
    outage window active at round t is *recomputed* from the window
    starts of the last ``outage_len`` rounds rather than carried as
    state — so ``realize(t, ...)`` is a pure function of (config, t,
    cohort) and a resumed run replays the identical fault trace.

    >>> import numpy as np
    >>> from repro.config import FaultConfig, FLConfig
    >>> fm = FaultModel(FaultConfig(outage_prob=0.3, outage_len=2,
    ...                             link_drop_prob=0.2, seed=7),
    ...                 FLConfig(num_clusters=4, devices_per_cluster=2))
    >>> plan = fm.realize(3, np.ones(8), np.ones(8),
    ...                   np.repeat(np.arange(4), 2))
    >>> plan.trace() == fm.realize(3, np.ones(8), np.ones(8),
    ...                            np.repeat(np.arange(4), 2)).trace()
    True
    """

    #: stream tags (disjoint from ScenarioEngine's so a shared seed
    #: still yields independent draws)
    _STREAM_OUTAGE = 11
    _STREAM_OUTAGE_LEN = 12
    _STREAM_LINK = 13

    def __init__(self, fc: FaultConfig, fl: FLConfig,
                 adj: Optional[np.ndarray] = None):
        fc.validate()
        self.fc, self.fl = fc, fl
        if adj is None:
            hier = topo.Hierarchy.from_config(fl)
            adj = hier.adjacency(1, fl.topology, fl)
        self.adj = np.asarray(adj, bool)

    def _rng(self, round_idx: int, stream: int,
             entity: int = 0) -> np.random.Generator:
        """Counter-based generator keyed by
        ``(fault seed, round, stream, entity)`` — same keying
        discipline as ``ScenarioEngine._round_rng``."""
        return np.random.default_rng(np.random.SeedSequence(
            [int(self.fc.seed), int(round_idx), int(stream), int(entity)]))

    def cluster_down(self, round_idx: int) -> np.ndarray:
        """(m,) bool: clusters inside an outage window at ``round_idx``.

        A window starting at round s (prob ``outage_prob``, keyed by
        (s, cluster)) lasts 1..``outage_len`` rounds (length keyed by
        the same s) — so membership at t only needs the keyed draws of
        rounds t-outage_len+1..t, never any carried state."""
        m = self.fl.num_clusters
        down = np.zeros(m, bool)
        if self.fc.outage_prob <= 0.0:
            return down
        for c in range(m):
            for s in range(max(0, round_idx - self.fc.outage_len + 1),
                           round_idx + 1):
                if self._rng(s, self._STREAM_OUTAGE, c).random() \
                        < self.fc.outage_prob:
                    length = int(self._rng(s, self._STREAM_OUTAGE_LEN, c)
                                 .integers(1, self.fc.outage_len + 1))
                    if s + length > round_idx:
                        down[c] = True
                        break
        return down

    def link_up(self, round_idx: int) -> np.ndarray:
        """(m,m) bool symmetric keep-mask over the backhaul adjacency:
        each undirected link drops for this round independently with
        prob ``link_drop_prob`` (keyed per (round, edge))."""
        m = self.fl.num_clusters
        up = np.ones((m, m), bool)
        if self.fc.link_drop_prob <= 0.0:
            return up
        for i in range(m):
            for j in range(i + 1, m):
                if not self.adj[i, j]:
                    continue
                if self._rng(round_idx, self._STREAM_LINK,
                             i * m + j).random() < self.fc.link_drop_prob:
                    up[i, j] = up[j, i] = False
        return up

    def timeouts(self, mask: np.ndarray, speeds: np.ndarray
                 ) -> Tuple[np.ndarray, np.ndarray, float]:
        """Straggler-timeout retry ladder over the participating cohort.

        A participant's local compute scales as 1/speed; its attempt-a
        budget is ``timeout_factor * retry_backoff**a`` times the
        cohort-*median* compute. Returns ``(attempts, timed_out,
        ref_mult)``: aborted attempts per device (the smallest a whose
        budget covers it), the devices no budget covers within
        ``max_retries`` retries (dropped from the round), and the
        median multiplier the budgets were derived from. Deterministic
        given the cohort — no RNG stream needed."""
        n = speeds.shape[0]
        attempts = np.zeros(n, np.int64)
        timed_out = np.zeros(n, bool)
        active = np.asarray(mask) > 0
        if self.fc.timeout_factor <= 0.0 or not active.any():
            return attempts, timed_out, 1.0
        ref = float(np.median(speeds[active]))
        # time_d <= budget_a  <=>  ref <= F * backoff^a * speed_d
        need = ref / (self.fc.timeout_factor * np.maximum(speeds, 1e-12))
        for a in range(self.fc.max_retries + 1):
            covered = need <= self.fc.retry_backoff ** a
            if a == 0:
                pending = active & ~covered
            else:
                attempts[pending] += 1
                pending = pending & ~covered
        timed_out = pending
        attempts[timed_out] += 1  # the final, also-aborted attempt
        return attempts, timed_out, ref

    def realize(self, round_idx: int, mask: np.ndarray,
                speeds: np.ndarray, labels: np.ndarray) -> FaultPlan:
        """The round's full :class:`FaultPlan`: outage windows, link
        survival (+ component count of the surviving graph) and the
        timeout ladder over the cohort that outages left standing."""
        down = self.cluster_down(round_idx)
        up = self.link_up(round_idx)
        ncomp = int(topo.connected_components(self.adj & up).max()) + 1
        cohort = np.asarray(mask) * (~down[np.asarray(labels)])
        attempts, timed_out, ref = self.timeouts(cohort, speeds)
        return FaultPlan(round_idx, down, up, ncomp, attempts,
                         timed_out, ref)


class ScenarioEngine:
    """Stateful per-round realization of a :class:`ScenarioConfig`.

    Deterministic given ``sc.seed``: two engines with the same config
    produce the same speed draw, cohort sequence and mobility trace, so
    different algorithms can be compared under identical conditions.

    Every per-round draw is *keyed*, not sequential: mobility and
    sampling read counter-based generators seeded by
    ``(seed, round_idx, stream, cluster_id)`` (:meth:`_round_rng`), so
    a round's realized randomness never depends on how many draws any
    other round — or any other cluster — consumed before it. That is
    what keeps async bounded-staleness execution (clusters advancing
    out of lockstep, ``FLSimulator.step_round_async``) on exactly the
    same cohort/mobility trace as the barrier run."""

    #: stream tags for :meth:`_round_rng` (distinct per draw purpose)
    _STREAM_MOBILITY = 1
    _STREAM_SAMPLING = 2

    def __init__(self, sc: ScenarioConfig, fl: FLConfig):
        sc.validate()
        fl.validate()
        self.sc, self.fl = sc, fl
        # one-time draws only (the per-device speed multipliers); every
        # per-round draw goes through the keyed _round_rng streams
        self.rng = np.random.default_rng(sc.seed)
        self.labels = np.repeat(np.arange(fl.num_clusters),
                                fl.devices_per_cluster)
        # tier-1 backhaul graph, block-diagonal under a depth>2 hierarchy
        # (same construction as cefedavg.make_w_schedule)
        hier = topo.Hierarchy.from_config(fl)
        adj = hier.adjacency(1, fl.topology, fl)
        self.adj = np.asarray(adj, bool)
        self.H = topo.mixing_matrix(adj, fl.mixing)
        self.speed_multipliers = sample_speed_multipliers(sc, fl.n, self.rng)
        self.faults = (FaultModel(sc.faults, fl, self.adj)
                       if sc.faults is not None and not sc.faults.trivial
                       else None)
        self.round_index = 0

    # -- per-round draws -----------------------------------------------------
    def _round_rng(self, round_idx: int, stream: int,
                   cluster: int = 0) -> np.random.Generator:
        """Counter-based generator keyed by
        ``(seed, round_idx, stream, cluster)``: the same (round,
        cluster) always sees the same randomness regardless of draw
        order, interleaving, or extra draws elsewhere."""
        return np.random.default_rng(np.random.SeedSequence(
            [int(self.sc.seed), int(round_idx), int(stream), int(cluster)]))

    def _step_mobility(self) -> None:
        """Re-associate each device w.p. ``move_prob`` to a uniform other
        edge. A move that would empty the source cluster is skipped: an
        edge with no attached devices has no model to gossip, and the
        operator algebra (and the paper's B_t) assume nonempty clusters.

        Draws are keyed per (round, source cluster) and applied in fixed
        cluster order, so the re-drawn B_t is identical whether the
        engine is driven by a barrier or an async round."""
        m = self.fl.num_clusters
        if self.sc.move_prob <= 0.0 or m < 2:
            return
        labels = self.labels.copy()
        sizes = np.bincount(labels, minlength=m)
        for c in range(m):
            members = np.nonzero(self.labels == c)[0]
            if members.size == 0:
                continue
            rng = self._round_rng(self.round_index, self._STREAM_MOBILITY, c)
            moves = rng.random(members.size) < self.sc.move_prob
            dsts = rng.integers(0, m - 1, members.size)
            for k, moved, dst in zip(members, moves, dsts):
                if not moved or sizes[labels[k]] <= 1:
                    continue
                dst = int(dst)
                if dst >= labels[k]:
                    dst += 1
                sizes[labels[k]] -= 1
                sizes[dst] += 1
                labels[k] = dst
        self.labels = labels

    def _draw_mask(self) -> np.ndarray:
        """Per-cluster stratified cohort: each cluster samples
        ⌈fraction·|cluster|⌉ of its members, thinned by straggler
        dropout, from a generator keyed by (round, cluster). Reduces to
        the global ⌈fraction·n⌉ cardinality for equal clusters, and
        guarantees at least one surviving device overall (pathological
        dropout keeps the first sampled device)."""
        n = self.fl.n
        mask = np.zeros(n)
        first = None
        for c in range(self.fl.num_clusters):
            members = np.nonzero(self.labels == c)[0]
            if members.size == 0:
                continue
            rng = self._round_rng(self.round_index, self._STREAM_SAMPLING, c)
            k = max(1, int(np.ceil(self.sc.sample_fraction * members.size)))
            cohort = members[rng.choice(members.size, size=k, replace=False)]
            if first is None:
                first = int(cohort[0])
            kept = cohort[rng.random(k) >= self.sc.dropout_prob]
            mask[kept] = 1.0
        if mask.sum() == 0:
            mask[first] = 1.0  # pathological dropout: keep one device
        return mask

    def step(self) -> RoundPlan:
        """Advance one global round: mobility, then sampling, then
        faults (outages silence whole clusters, link loss degrades the
        round's mixing matrix, timeouts drop stragglers), then the
        induced (W_intra, W_inter). Fault degradation never raises: a
        fully-dark round simply yields an all-zero cohort and identity
        mixing."""
        self._step_mobility()
        mask = self._draw_mask()
        fault, H_eff = None, None
        H_t = self.H
        if self.faults is not None:
            fault = self.faults.realize(self.round_index, mask,
                                        self.speed_multipliers, self.labels)
            # dark clusters train nothing; exhausted stragglers drop out
            mask = (mask * (~fault.cluster_down[self.labels])
                    * (~fault.timed_out))
            if not fault.link_up.all():
                # re-weight over the surviving (maybe partitioned) graph;
                # mixing_matrix of a disconnected graph is block-diagonal,
                # i.e. per-component gossip
                H_eff = topo.mixing_matrix(self.adj & fault.link_up,
                                           self.fl.mixing)
                H_t = H_eff
        W_intra, W_inter = make_masked_w(self.fl, self.labels, mask, H_t)
        plan = RoundPlan(self.round_index, self.fl.num_clusters,
                         self.labels.copy(), mask, W_intra, W_inter,
                         fault=fault, H_eff=H_eff)
        self.round_index += 1
        return plan

    def active_speeds(self, plan: RoundPlan) -> np.ndarray:
        """Speed multipliers of the plan's participating devices.

        Convenience accessor for external analyses; the wall-clock
        harness itself passes the full ``speed_multipliers`` vector plus
        the plan's mask to ``EventClock.charge_program``, which needs
        per-device alignment with adaptive ``tau_dev`` cutoffs."""
        return self.speed_multipliers[plan.active]


# ---------------------------------------------------------------------------
# virtual populations (ISSUE 9): distribution-driven cohorts, no (n,) state
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CohortPlan:
    """One streamed round's realized cohort over a virtual population.

    Unlike :class:`RoundPlan` there are no (n,)-shaped vectors: the
    population is never enumerated. ``clients`` are the sampled virtual
    client ids (home-cluster-sorted), ``labels`` their clusters for
    this round (home, unless visit mobility re-attached them) and
    ``speeds`` their keyed per-client multipliers. ``fault``/``H_eff``
    exist for interface parity with :class:`RoundPlan` (the wall-clock
    harness reads both) and are always ``None`` — fault injection is
    not supported with a virtual population."""
    round_index: int
    num_clusters: int
    clients: np.ndarray       # (k,) int64 sampled virtual client ids
    labels: np.ndarray        # (k,) cluster attachment this round
    speeds: np.ndarray        # (k,) per-client speed multipliers
    population: int           # realized total population size
    fault: Optional[FaultPlan] = None
    H_eff: Optional[np.ndarray] = None

    @property
    def mask(self) -> np.ndarray:
        """Cohort-aligned participation (every sampled client trains)."""
        return np.ones(self.clients.shape[0])

    @property
    def cohort(self) -> np.ndarray:
        """The sampled client ids (alias, mirrors ``RoundPlan``)."""
        return self.clients


class PopulationEngine:
    """Keyed per-round cohort realization of a virtual population
    (:class:`repro.config.PopulationConfig` inside a ScenarioConfig).

    Stateless beyond ``round_index`` by construction: cluster sizes are
    a one-time keyed draw, and every per-round draw (cohort sampling,
    visit mobility, per-client speeds) reads a counter-based generator
    keyed by ``(seed, round, stream, entity)`` — the same discipline as
    :class:`ScenarioEngine` but on disjoint streams — so the cohort
    trace is a pure function of (config, round) and a resumed run
    replays it identically with no per-client state to checkpoint.

    Client ids are implicit: cluster c owns the contiguous id range
    ``[offsets[c], offsets[c+1])`` under the realized size prefix sums,
    so membership tests and home-cluster lookups are O(log m) searches,
    never O(n) tables. Mobility is *visit-based*: a sampled client
    re-attaches to a uniformly random other edge for the round with
    prob ``move_prob`` (it downloads and trains that edge's model —
    the device-associates-to-nearest-edge reality), then hands its
    state back through the store at page-out; home membership never
    changes, so cluster sizes stay the realized draw."""

    #: stream tags (disjoint from ScenarioEngine's and FaultModel's)
    _STREAM_SIZES = 21
    _STREAM_SAMPLING = 22
    _STREAM_MOBILITY = 23
    _STREAM_SPEED = 24

    def __init__(self, sc: ScenarioConfig, fl: FLConfig):
        sc.validate()
        fl.validate()
        assert sc.population is not None, \
            "PopulationEngine needs ScenarioConfig.population"
        assert fl.algorithm != "dec_local_sgd", \
            "dec_local_sgd enumerates one device per cluster (n == m) " \
            "— incompatible with per-cluster client distributions"
        self.sc, self.fl, self.pop = sc, fl, sc.population
        m = fl.num_clusters
        hier = topo.Hierarchy.from_config(fl)
        adj = hier.adjacency(1, fl.topology, fl)
        self.adj = np.asarray(adj, bool)
        self.H = topo.mixing_matrix(adj, fl.mixing)
        self.faults = None            # (interface parity with ScenarioEngine)
        self.labels = np.zeros(0, np.int64)   # population is not enumerated
        # one-time keyed realization of the per-cluster member counts
        sizes = np.empty(m, np.int64)
        for c in range(m):
            rng = np.random.default_rng(np.random.SeedSequence(
                [int(sc.seed), 0, self._STREAM_SIZES, c]))
            base = float(self.pop.clients_per_cluster)
            if self.pop.size_dist == "fixed":
                s = base
            elif self.pop.size_dist == "uniform":
                s = base * rng.uniform(1.0 - self.pop.size_spread,
                                       1.0 + self.pop.size_spread)
            else:  # lognormal
                sig = self.pop.size_spread
                s = base * rng.lognormal(-0.5 * sig * sig, sig)
            sizes[c] = max(1, int(round(s)))
        self.sizes = sizes
        self.offsets = np.concatenate(
            [[0], np.cumsum(sizes)]).astype(np.int64)
        self.population = int(sizes.sum())
        kc = max(1, int(np.ceil(sc.sample_fraction
                                * self.pop.cohort_per_cluster)))
        self._k_per_cluster = kc
        #: upper bound on the streamed working set (cohort + one cold
        #: representative per cluster) — sizes the slab buckets
        self.cohort_cap = int(sum(min(kc, int(s)) for s in sizes) + m)
        #: cohort-aligned speed multipliers of the latest step() — what
        #: the wall-clock harness charges (re-assigned every round)
        self.speed_multipliers = np.ones(0)
        self.round_index = 0

    # -- keyed draws ---------------------------------------------------------
    def _round_rng(self, round_idx: int, stream: int,
                   entity: int = 0) -> np.random.Generator:
        return np.random.default_rng(np.random.SeedSequence(
            [int(self.sc.seed), int(round_idx), int(stream), int(entity)]))

    def home_cluster(self, ids: np.ndarray) -> np.ndarray:
        """Home cluster of each client id (prefix-sum range lookup)."""
        return (np.searchsorted(self.offsets, np.asarray(ids, np.int64),
                                side="right") - 1).astype(np.int64)

    def client_speeds(self, ids: np.ndarray) -> np.ndarray:
        """Per-client speed multipliers, keyed by client id (a client's
        hardware is its identity — redrawn rounds see the same speed)."""
        ids = np.asarray(ids, np.int64).reshape(-1)
        out = np.empty(ids.shape[0])
        for j, i in enumerate(ids):
            rng = np.random.default_rng(np.random.SeedSequence(
                [int(self.sc.seed), 0, self._STREAM_SPEED, int(i)]))
            out[j] = sample_speed_multipliers(self.sc, 1, rng)[0]
        return out

    def representatives(self, sampled: np.ndarray) -> np.ndarray:
        """One cold (unsampled) member id per cluster — the working-set
        lane whose post-round row is read back as the cluster's synced
        reference. Fully-sampled clusters get no representative (any
        participant's synced row serves)."""
        taken = set(int(i) for i in np.asarray(sampled).reshape(-1))
        reps = []
        for c in range(self.fl.num_clusters):
            lo, hi = int(self.offsets[c]), int(self.offsets[c + 1])
            for i in range(lo, hi):
                if i not in taken:
                    reps.append(i)
                    break
        return np.asarray(reps, np.int64)

    def step(self) -> CohortPlan:
        """Advance one streamed round: per-cluster keyed cohort draw
        (``ceil(sample_fraction * cohort_per_cluster)`` members without
        replacement, thinned by dropout, at least one survivor
        overall), then keyed visit mobility over the cohort, then keyed
        per-client speeds."""
        r = self.round_index
        m = self.fl.num_clusters
        parts, first = [], None
        for c in range(m):
            rng = self._round_rng(r, self._STREAM_SAMPLING, c)
            size = int(self.sizes[c])
            kk = min(self._k_per_cluster, size)
            picks = self.offsets[c] + np.sort(
                rng.choice(size, size=kk, replace=False))
            if first is None:
                first = int(picks[0])
            kept = picks[rng.random(kk) >= self.sc.dropout_prob]
            parts.append(kept)
        clients = np.concatenate(parts).astype(np.int64)
        if clients.size == 0:
            clients = np.asarray([first], np.int64)
        labels = self.home_cluster(clients)
        if self.sc.move_prob > 0.0 and m > 1:
            home = labels.copy()
            for c in range(m):
                sel = np.nonzero(home == c)[0]
                if sel.size == 0:
                    continue
                rng = self._round_rng(r, self._STREAM_MOBILITY, c)
                moves = rng.random(sel.size) < self.sc.move_prob
                dst = rng.integers(0, m - 1, sel.size)
                dst = dst + (dst >= c)
                labels[sel[moves]] = dst[moves]
        speeds = self.client_speeds(clients)
        self.speed_multipliers = speeds
        self.round_index += 1
        return CohortPlan(r, m, clients, labels, speeds, self.population)


# ---------------------------------------------------------------------------
# named presets (the scenarios the benchmarks and CLI expose)
# ---------------------------------------------------------------------------

SCENARIOS: Dict[str, ScenarioConfig] = {
    "homogeneous": ScenarioConfig(name="homogeneous"),
    "uniform": ScenarioConfig(
        name="uniform", speed_dist="uniform", speed_spread=0.5),
    "lognormal": ScenarioConfig(
        name="lognormal", speed_dist="lognormal", speed_spread=0.6),
    "bimodal": ScenarioConfig(
        name="bimodal", speed_dist="bimodal", slow_fraction=0.25,
        slow_factor=0.2),
    "sampled": ScenarioConfig(
        name="sampled", sample_fraction=0.5, dropout_prob=0.1),
    "mobility": ScenarioConfig(
        name="mobility", speed_dist="lognormal", speed_spread=0.6,
        move_prob=0.25),
    "mobile_sampled": ScenarioConfig(
        name="mobile_sampled", speed_dist="lognormal", speed_spread=0.6,
        sample_fraction=0.8, dropout_prob=0.05, move_prob=0.25),
}


def get_scenario(name: str) -> ScenarioConfig:
    """Look up a named preset (see :data:`SCENARIOS`)."""
    if name not in SCENARIOS:
        raise ValueError(
            f"unknown scenario {name!r}; choose from {sorted(SCENARIOS)}")
    return SCENARIOS[name]


#: fault presets (docs/FAULT_MODEL.md): attach to any ScenarioConfig via
#: ``dataclasses.replace(sc, faults=get_faults("outage"))`` or the
#: launcher's ``--faults`` flag
FAULTS: Dict[str, FaultConfig] = {
    "outage": FaultConfig(outage_prob=0.08, outage_len=2),
    "flaky_links": FaultConfig(link_drop_prob=0.15),
    "stragglers": FaultConfig(timeout_factor=1.5, max_retries=2,
                              retry_backoff=1.5),
    "chaos": FaultConfig(outage_prob=0.05, outage_len=2,
                         link_drop_prob=0.1, timeout_factor=1.5,
                         max_retries=2, retry_backoff=1.5),
}


def get_faults(name: str) -> FaultConfig:
    """Look up a named fault preset (see :data:`FAULTS`)."""
    if name not in FAULTS:
        raise ValueError(
            f"unknown fault preset {name!r}; choose from {sorted(FAULTS)}")
    return FAULTS[name]
