"""Scenario engine: device heterogeneity, client sampling and mobility.

The paper's headline result is *wall-clock time to a target accuracy*
(§6, Figs. 5–6) on heterogeneous mobile devices, but the static
``make_w_schedule`` assumes every device trains every round in a fixed,
equal-size cluster. A :class:`ScenarioEngine` lifts those assumptions one
global round at a time:

- **heterogeneity** — per-device speed multipliers drawn once from a
  uniform / lognormal / bimodal distribution (all mean ≈ 1 so profiles
  stay comparable to the homogeneous §6.1 constants);
- **client sampling** — each round every cluster draws a
  ⌈fraction·|cluster|⌉ cohort of its members, thinned by straggler
  dropout; non-participants neither compute nor upload, and the
  V/A/H-operators are renormalized over the cohort
  (``topology.masked_*``);
- **mobility** — each device re-associates to a uniformly random other
  edge with probability ``move_prob`` per round (never emptying its
  current cluster), re-drawing the assignment matrix B_t and therefore
  the W_intra/W_inter pair for unequal, time-varying clusters.

``ScenarioEngine.step()`` returns a :class:`RoundPlan` whose operators
``FLSimulator`` feeds to its jitted round; ``core.clock.EventClock``
charges the plan's cohort for wall time. When the scenario is trivial
(full participation, no mobility) every plan reproduces the static
``make_w_schedule`` operators exactly — the parity regime asserted in
``tests/test_scenario.py``.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import numpy as np

from repro.config import FLConfig, ScenarioConfig
from repro.core import topology as topo


def sample_speed_multipliers(sc: ScenarioConfig, n: int,
                             rng: np.random.Generator) -> np.ndarray:
    """Per-device relative speeds c_k / c̄ for the scenario's distribution.

    Multipliers are positive and have mean ≈ 1, so the homogeneous
    hardware profile's ``device_flops`` stays the fleet average."""
    if sc.speed_dist == "homogeneous":
        return np.ones(n)
    if sc.speed_dist == "uniform":
        lo, hi = 1.0 - sc.speed_spread, 1.0 + sc.speed_spread
        return rng.uniform(lo, hi, n)
    if sc.speed_dist == "lognormal":
        sigma = sc.speed_spread
        return rng.lognormal(mean=-0.5 * sigma * sigma, sigma=sigma, size=n)
    if sc.speed_dist == "bimodal":
        slow = rng.random(n) < sc.slow_fraction
        return np.where(slow, sc.slow_factor, 1.0)
    raise ValueError(sc.speed_dist)


@dataclasses.dataclass(frozen=True)
class RoundPlan:
    """One global round's realized scenario: who participates, where each
    device lives, and the mixing operators those two facts induce."""
    round_index: int
    num_clusters: int         # m
    labels: np.ndarray        # (n,) cluster id per device (B_t rows)
    mask: np.ndarray          # (n,) float 0/1 participation
    W_intra: np.ndarray       # (n,n) masked/unequal intra-cluster operator
    W_inter: np.ndarray       # (n,n) masked/unequal inter-cluster operator

    @property
    def active(self) -> np.ndarray:
        """Boolean participation (the cohort the clock charges)."""
        return self.mask > 0

    @property
    def cohort(self) -> np.ndarray:
        """Indices of the participating devices — the rows the ModelBank
        engine gathers into its compacted (k_pad, T) batch."""
        return np.nonzero(self.mask > 0)[0]

    @property
    def cluster_sizes(self) -> np.ndarray:
        """Device count per cluster under this round's B_t."""
        return np.bincount(self.labels, minlength=self.num_clusters)


def make_masked_w(fl: FLConfig, labels: np.ndarray, mask: np.ndarray,
                  H: np.ndarray,
                  pi: Optional[int] = None
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Per-round (W_intra, W_inter) for the algorithm under assignment
    ``labels`` and participation ``mask`` — the time-varying eq. 11.
    ``pi`` overrides the gossip depth of the inter operator (time-varying
    π_t schedules, ``core.program.InterGossip``); default ``fl.pi``.

    Reduces to :func:`repro.core.cefedavg.make_w_schedule`'s operators
    when ``labels`` is the contiguous equal-cluster assignment and
    ``mask`` is all-ones."""
    n = labels.shape[0]
    pi = fl.pi if pi is None else pi
    eye = np.eye(n)
    B = topo.assignment_matrix(labels, fl.num_clusters)
    if fl.algorithm == "ce_fedavg":
        return (topo.masked_intra_operator(B, mask),
                topo.masked_inter_operator(B, H, pi, mask))
    if fl.algorithm == "hier_favg":
        return (topo.masked_intra_operator(B, mask),
                topo.masked_global_average(n, mask))
    if fl.algorithm == "fedavg":
        return eye, topo.masked_global_average(n, mask)
    if fl.algorithm == "local_edge":
        V = topo.masked_intra_operator(B, mask)
        return V, V
    if fl.algorithm == "dec_local_sgd":
        Hp = np.linalg.matrix_power(H, pi)
        return eye, topo.renormalize_rows(Hp, mask)
    raise ValueError(fl.algorithm)


class ScenarioEngine:
    """Stateful per-round realization of a :class:`ScenarioConfig`.

    Deterministic given ``sc.seed``: two engines with the same config
    produce the same speed draw, cohort sequence and mobility trace, so
    different algorithms can be compared under identical conditions.

    Every per-round draw is *keyed*, not sequential: mobility and
    sampling read counter-based generators seeded by
    ``(seed, round_idx, stream, cluster_id)`` (:meth:`_round_rng`), so
    a round's realized randomness never depends on how many draws any
    other round — or any other cluster — consumed before it. That is
    what keeps async bounded-staleness execution (clusters advancing
    out of lockstep, ``FLSimulator.step_round_async``) on exactly the
    same cohort/mobility trace as the barrier run."""

    #: stream tags for :meth:`_round_rng` (distinct per draw purpose)
    _STREAM_MOBILITY = 1
    _STREAM_SAMPLING = 2

    def __init__(self, sc: ScenarioConfig, fl: FLConfig):
        sc.validate()
        fl.validate()
        self.sc, self.fl = sc, fl
        # one-time draws only (the per-device speed multipliers); every
        # per-round draw goes through the keyed _round_rng streams
        self.rng = np.random.default_rng(sc.seed)
        self.labels = np.repeat(np.arange(fl.num_clusters),
                                fl.devices_per_cluster)
        # tier-1 backhaul graph, block-diagonal under a depth>2 hierarchy
        # (same construction as cefedavg.make_w_schedule)
        hier = topo.Hierarchy.from_config(fl)
        adj = hier.adjacency(1, fl.topology, fl)
        self.H = topo.mixing_matrix(adj, fl.mixing)
        self.speed_multipliers = sample_speed_multipliers(sc, fl.n, self.rng)
        self.round_index = 0

    # -- per-round draws -----------------------------------------------------
    def _round_rng(self, round_idx: int, stream: int,
                   cluster: int = 0) -> np.random.Generator:
        """Counter-based generator keyed by
        ``(seed, round_idx, stream, cluster)``: the same (round,
        cluster) always sees the same randomness regardless of draw
        order, interleaving, or extra draws elsewhere."""
        return np.random.default_rng(np.random.SeedSequence(
            [int(self.sc.seed), int(round_idx), int(stream), int(cluster)]))

    def _step_mobility(self) -> None:
        """Re-associate each device w.p. ``move_prob`` to a uniform other
        edge. A move that would empty the source cluster is skipped: an
        edge with no attached devices has no model to gossip, and the
        operator algebra (and the paper's B_t) assume nonempty clusters.

        Draws are keyed per (round, source cluster) and applied in fixed
        cluster order, so the re-drawn B_t is identical whether the
        engine is driven by a barrier or an async round."""
        m = self.fl.num_clusters
        if self.sc.move_prob <= 0.0 or m < 2:
            return
        labels = self.labels.copy()
        sizes = np.bincount(labels, minlength=m)
        for c in range(m):
            members = np.nonzero(self.labels == c)[0]
            if members.size == 0:
                continue
            rng = self._round_rng(self.round_index, self._STREAM_MOBILITY, c)
            moves = rng.random(members.size) < self.sc.move_prob
            dsts = rng.integers(0, m - 1, members.size)
            for k, moved, dst in zip(members, moves, dsts):
                if not moved or sizes[labels[k]] <= 1:
                    continue
                dst = int(dst)
                if dst >= labels[k]:
                    dst += 1
                sizes[labels[k]] -= 1
                sizes[dst] += 1
                labels[k] = dst
        self.labels = labels

    def _draw_mask(self) -> np.ndarray:
        """Per-cluster stratified cohort: each cluster samples
        ⌈fraction·|cluster|⌉ of its members, thinned by straggler
        dropout, from a generator keyed by (round, cluster). Reduces to
        the global ⌈fraction·n⌉ cardinality for equal clusters, and
        guarantees at least one surviving device overall (pathological
        dropout keeps the first sampled device)."""
        n = self.fl.n
        mask = np.zeros(n)
        first = None
        for c in range(self.fl.num_clusters):
            members = np.nonzero(self.labels == c)[0]
            if members.size == 0:
                continue
            rng = self._round_rng(self.round_index, self._STREAM_SAMPLING, c)
            k = max(1, int(np.ceil(self.sc.sample_fraction * members.size)))
            cohort = members[rng.choice(members.size, size=k, replace=False)]
            if first is None:
                first = int(cohort[0])
            kept = cohort[rng.random(k) >= self.sc.dropout_prob]
            mask[kept] = 1.0
        if mask.sum() == 0:
            mask[first] = 1.0  # pathological dropout: keep one device
        return mask

    def step(self) -> RoundPlan:
        """Advance one global round: mobility, then sampling, then the
        induced (W_intra, W_inter)."""
        self._step_mobility()
        mask = self._draw_mask()
        W_intra, W_inter = make_masked_w(self.fl, self.labels, mask, self.H)
        plan = RoundPlan(self.round_index, self.fl.num_clusters,
                         self.labels.copy(), mask, W_intra, W_inter)
        self.round_index += 1
        return plan

    def active_speeds(self, plan: RoundPlan) -> np.ndarray:
        """Speed multipliers of the plan's participating devices.

        Convenience accessor for external analyses; the wall-clock
        harness itself passes the full ``speed_multipliers`` vector plus
        the plan's mask to ``EventClock.charge_program``, which needs
        per-device alignment with adaptive ``tau_dev`` cutoffs."""
        return self.speed_multipliers[plan.active]


# ---------------------------------------------------------------------------
# named presets (the scenarios the benchmarks and CLI expose)
# ---------------------------------------------------------------------------

SCENARIOS: Dict[str, ScenarioConfig] = {
    "homogeneous": ScenarioConfig(name="homogeneous"),
    "uniform": ScenarioConfig(
        name="uniform", speed_dist="uniform", speed_spread=0.5),
    "lognormal": ScenarioConfig(
        name="lognormal", speed_dist="lognormal", speed_spread=0.6),
    "bimodal": ScenarioConfig(
        name="bimodal", speed_dist="bimodal", slow_fraction=0.25,
        slow_factor=0.2),
    "sampled": ScenarioConfig(
        name="sampled", sample_fraction=0.5, dropout_prob=0.1),
    "mobility": ScenarioConfig(
        name="mobility", speed_dist="lognormal", speed_spread=0.6,
        move_prob=0.25),
    "mobile_sampled": ScenarioConfig(
        name="mobile_sampled", speed_dist="lognormal", speed_spread=0.6,
        sample_fraction=0.8, dropout_prob=0.05, move_prob=0.25),
}


def get_scenario(name: str) -> ScenarioConfig:
    """Look up a named preset (see :data:`SCENARIOS`)."""
    if name not in SCENARIOS:
        raise ValueError(
            f"unknown scenario {name!r}; choose from {sorted(SCENARIOS)}")
    return SCENARIOS[name]
