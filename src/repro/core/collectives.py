"""Version-portable collectives for the sparse aggregation backends.

``jax.shard_map`` only exists as a top-level export (with a ``check_vma``
kwarg) on newer JAX; the pinned 0.4.x line ships it as
``jax.experimental.shard_map.shard_map`` (with ``check_rep``). Every sparse
backend routes through :func:`shard_map` here so the version split lives in
exactly one place.

The helpers below also treat the mesh's replica axes (``pod`` × ``data``)
as ONE flattened logical axis: JAX collectives accept a tuple of axis names,
with the flat index being ``pod_idx * data_size + data_idx`` — exactly the
replica numbering of ``ReplicaGeometry``. Working on the flat axis lets a
single ``ppermute`` express any replica permutation, including multi-pod
edge crossings, with no per-topology special cases.
"""
from __future__ import annotations

import inspect
from typing import Any, Callable, Sequence, Tuple

import jax
from jax.sharding import Mesh


def _resolve_shard_map() -> Tuple[Callable, str]:
    """(shard_map callable, name of its replication-check kwarg)."""
    fn = getattr(jax, "shard_map", None)
    if fn is None:
        from jax.experimental.shard_map import shard_map as fn  # type: ignore
    params = inspect.signature(fn).parameters
    for kw in ("check_vma", "check_rep"):
        if kw in params:
            return fn, kw
    return fn, ""


_SHARD_MAP, _CHECK_KW = _resolve_shard_map()


def shard_map(f: Callable, mesh: Mesh, in_specs: Any, out_specs: Any,
              check: bool = False) -> Callable:
    """``jax.shard_map`` on new JAX, ``jax.experimental.shard_map`` on old."""
    kw = {_CHECK_KW: check} if _CHECK_KW else {}
    return _SHARD_MAP(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      **kw)


def replica_axis_names(mesh: Mesh) -> Tuple[str, ...]:
    """Mesh axes carrying federated replicas, major-to-minor."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def flat_axis_size(mesh: Mesh) -> int:
    out = 1
    for a in replica_axis_names(mesh):
        out *= mesh.shape[a]
    return out


def flat_axis_index(mesh: Mesh) -> jax.Array:
    """Flattened replica index inside a shard_map body.

    Equals ``pod_idx * data_size + data_idx`` on a multi-pod mesh, i.e. the
    global replica id of ``ReplicaGeometry``.
    """
    names = replica_axis_names(mesh)
    idx = None
    for a in names:
        i = jax.lax.axis_index(a)
        idx = i if idx is None else idx * mesh.shape[a] + i
    assert idx is not None, "mesh has no replica axes"
    return idx


def ppermute(x: jax.Array, mesh: Mesh,
             perm: Sequence[Tuple[int, int]]) -> jax.Array:
    """Permute over the flat replica axis; unmatched receivers get zeros."""
    return jax.lax.ppermute(x, replica_axis_names(mesh), perm=list(perm))


def rotate_perm(mesh: Mesh, shift: int = 1) -> Tuple[Tuple[int, int], ...]:
    """Cyclic (src, dst) pairs on the flat replica axis: after one
    application of the returned perm, device d holds what device
    ``(d + shift) % R`` held — the building block of the weighted-rotation
    mixes (``core.gossip.dense_mix_rows`` and the ``ringweight`` backend).
    """
    R = flat_axis_size(mesh)
    return tuple(((d + shift) % R, d) for d in range(R))


def psum_groups(x: jax.Array, mesh: Mesh,
                groups: Sequence[Sequence[int]]) -> jax.Array:
    """Grouped psum over the flat replica axis (flat replica ids)."""
    return jax.lax.psum(x, replica_axis_names(mesh),
                        axis_index_groups=[list(g) for g in groups])
