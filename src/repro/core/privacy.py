"""Privacy substrates the paper claims compatibility with (§4.1):

- **Secure aggregation** (Bonawitz et al. [26]): pairwise additive masks
  that cancel in the intra-cluster sum, so the edge server learns only
  Σ_k x_k — implementable here because CE-FedAvg's W_t operators only ever
  consume sums (eq. 6/7).
- **(Local) differential privacy** ([28]–[30]): per-device L2 clipping +
  Gaussian noise on the uploaded update, with the standard Gaussian-
  mechanism accountant for a single release.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, List

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# secure aggregation (pairwise masking)
# ---------------------------------------------------------------------------

def _pair_key(seed: int, i: int, j: int) -> jax.Array:
    return jax.random.PRNGKey(seed * 1_000_003 + i * 1009 + j)


def mask_update(tree: Any, device: int, cluster: List[int], *,
                seed: int = 0, scale: float = 1.0) -> Any:
    """Add pairwise-cancelling masks: device k adds +PRG(k,j) for j>k and
    -PRG(j,k) for j<k (within its cluster). Σ over the cluster is exact."""
    def mask_leaf(path_idx, leaf):
        m = jnp.zeros_like(leaf, jnp.float32)
        for j in cluster:
            if j == device:
                continue
            lo, hi = min(device, j), max(device, j)
            k = jax.random.fold_in(_pair_key(seed, lo, hi), path_idx)
            noise = jax.random.normal(k, leaf.shape) * scale
            m = m + noise if device < j else m - noise
        return (leaf.astype(jnp.float32) + m).astype(leaf.dtype)
    leaves, treedef = jax.tree.flatten(tree)
    return jax.tree.unflatten(
        treedef, [mask_leaf(i, l) for i, l in enumerate(leaves)])


def masked_cluster_sum(trees: List[Any], cluster: List[int], *,
                       seed: int = 0, scale: float = 1.0) -> Any:
    """What the edge server computes: Σ of masked updates (== true Σ)."""
    masked = [mask_update(t, dev, cluster, seed=seed, scale=scale)
              for t, dev in zip(trees, cluster)]
    return jax.tree.map(lambda *ls: sum(
        l.astype(jnp.float32) for l in ls), *masked)


# ---------------------------------------------------------------------------
# differential privacy
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class DPConfig:
    clip_norm: float = 1.0
    noise_multiplier: float = 0.0   # sigma = noise_multiplier * clip_norm

    @property
    def enabled(self) -> bool:
        return self.noise_multiplier > 0.0


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def clip_by_global_norm(tree: Any, max_norm: float) -> Any:
    n = global_norm(tree)
    factor = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-12))
    return jax.tree.map(lambda l: (l.astype(jnp.float32) * factor
                                   ).astype(l.dtype), tree)


def privatize_update(tree: Any, dp: DPConfig, key: jax.Array) -> Any:
    """Clip to clip_norm, then add N(0, (noise_multiplier*clip)^2)."""
    clipped = clip_by_global_norm(tree, dp.clip_norm)
    if not dp.enabled:
        return clipped
    sigma = dp.noise_multiplier * dp.clip_norm
    leaves, treedef = jax.tree.flatten(clipped)
    keys = jax.random.split(key, len(leaves))
    noisy = [
        (l.astype(jnp.float32)
         + sigma * jax.random.normal(k, l.shape)).astype(l.dtype)
        for l, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, noisy)


def privatize_update_flat(vec: jax.Array, dp: DPConfig,
                          key: jax.Array) -> jax.Array:
    """Flat-domain :func:`privatize_update` for the ModelBank engine.

    The L2 norm of the (T,) flattened update IS the tree's global norm,
    so clipping is bit-identical to the pytree path; the Gaussian noise
    is one (T,) draw instead of per-leaf draws — same mechanism and
    calibration, different pseudorandom stream."""
    norm = jnp.sqrt(jnp.sum(jnp.square(vec.astype(jnp.float32))))
    factor = jnp.minimum(1.0, dp.clip_norm / jnp.maximum(norm, 1e-12))
    clipped = (vec.astype(jnp.float32) * factor).astype(vec.dtype)
    if not dp.enabled:
        return clipped
    sigma = dp.noise_multiplier * dp.clip_norm
    noise = sigma * jax.random.normal(key, vec.shape)
    return (clipped.astype(jnp.float32) + noise).astype(vec.dtype)


def gaussian_epsilon(noise_multiplier: float, delta: float = 1e-5) -> float:
    """Single-release Gaussian-mechanism bound: eps = sqrt(2 ln(1.25/δ))/σ."""
    if noise_multiplier <= 0:
        return float("inf")
    return float(np.sqrt(2.0 * np.log(1.25 / delta)) / noise_multiplier)
