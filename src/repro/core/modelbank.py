"""Flat ModelBank: the simulation engine's resident state as (n, T) buffers.

The paper-faithful engine materializes all n device models (eq. 10 stacks
them row-wise). Keeping that stack as a *pytree* of (n, ...) leaves makes
every mixing boundary L per-leaf contractions — each parameter block
re-read from HBM once per leaf — and forces ``gossip_mix_tree`` callers
to rebuild a concat/split plan per invocation. The ModelBank instead
keeps params, momentum and the error-feedback residual as single
contiguous ``(n, T)`` float32 buffers for the whole run; pytree views are
materialized only inside the per-device ``apply_fn`` call and at
checkpoint/eval edges, and every mixing boundary is one streaming pass of
:func:`repro.kernels.gossip_mix.gossip_mix_rows` (Pallas on TPU, a single
XLA gemm on CPU/GPU).

Cohort compaction (client sampling, ``core/scenario.py``): when only k of
n devices participate, the gradient/momentum work runs on a dense
``(k_pad, T)`` gather of the participating rows instead of a full-n vmap
with ``where``-frozen masked devices. ``k_pad`` is the cohort size
rounded up to a static bucket (:func:`cohort_buckets`) so the jitted
round compiles once per bucket, not once per cohort size; padding lanes
are filled with *distinct non-participating* rows and masked inactive, so
the scatter back into the bank writes disjoint rows deterministically.
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.gossip_mix import FlatLayout, gossip_mix_rows


class ModelBank:
    """Params / momentum / EF-residual of all n devices as (n, T) buffers.

    ``layout`` is the :class:`repro.kernels.gossip_mix.FlatLayout` of one
    device model; ``params``/``mom``/``residual`` are the flat buffers
    (``residual`` is None unless error-feedback compression is on). The
    buffers are plain attributes so the jitted round can donate them and
    the caller reassigns the outputs — peak memory stays ~1× the bank.
    """

    def __init__(self, layout: FlatLayout, n: int, params_row: jax.Array,
                 *, with_residual: bool = False):
        self.layout = layout
        self.n = n
        self.params = jnp.tile(params_row[None, :], (n, 1))
        self.mom = jnp.zeros((n, layout.total), jnp.float32)
        self.residual = (jnp.zeros((n, layout.total), jnp.float32)
                         if with_residual else None)

    @classmethod
    def from_model(cls, one_model, n: int, *,
                   with_residual: bool = False) -> "ModelBank":
        """Broadcast a single init model to all n rows (Algorithm 1's
        shared init, as the pytree engine does)."""
        layout = FlatLayout.for_tree(one_model)
        return cls(layout, n, layout.flatten_one(one_model),
                   with_residual=with_residual)

    @classmethod
    def from_model_sharded(cls, one_model, n: int, sharding, *,
                           with_residual: bool = False) -> "ModelBank":
        """Shared-init bank built per-shard via
        ``jax.make_array_from_callback``: each device fills only its own
        ``(rows_per_device, T)`` slice by broadcasting the host-side init
        row, so the full (n, T) bank is NEVER materialized on one device
        — the multi-host-correct init path (the old build-then-``place``
        route allocates the whole bank on the default device first)."""
        layout = FlatLayout.for_tree(one_model)
        self = cls.__new__(cls)
        self.layout = layout
        self.n = n
        T = layout.total
        row = np.asarray(layout.flatten_one(one_model), np.float32)

        def shard_rows(idx):
            nrows = len(range(*idx[0].indices(n)))
            return np.broadcast_to(row[idx[1]], (nrows,) + row[idx[1]].shape)

        def shard_zeros(idx):
            nrows = len(range(*idx[0].indices(n)))
            ncols = len(range(*idx[1].indices(T)))
            return np.zeros((nrows, ncols), np.float32)

        self.params = jax.make_array_from_callback((n, T), sharding,
                                                   shard_rows)
        self.mom = jax.make_array_from_callback((n, T), sharding,
                                                shard_zeros)
        self.residual = (jax.make_array_from_callback((n, T), sharding,
                                                      shard_zeros)
                         if with_residual else None)
        return self

    @classmethod
    def from_rows(cls, layout: FlatLayout, params_rows: np.ndarray,
                  mom_rows: np.ndarray, *, sharding=None) -> "ModelBank":
        """Wrap host-paged (S, T) rows as a hot slab bank (the streamed
        engine's per-round working set, ``core/clientstore.py``). With a
        ``sharding``, rows are placed per-shard via
        ``jax.make_array_from_callback`` so no single device ever holds
        the whole slab."""
        params_rows = np.asarray(params_rows, np.float32)
        mom_rows = np.asarray(mom_rows, np.float32)
        S, T = params_rows.shape
        assert T == layout.total and mom_rows.shape == (S, T)
        self = cls.__new__(cls)
        self.layout = layout
        self.n = S
        if sharding is None:
            self.params = jnp.asarray(params_rows)
            self.mom = jnp.asarray(mom_rows)
        else:
            self.params = jax.make_array_from_callback(
                (S, T), sharding, lambda idx: params_rows[idx])
            self.mom = jax.make_array_from_callback(
                (S, T), sharding, lambda idx: mom_rows[idx])
        self.residual = None
        return self

    @property
    def resident_nbytes(self) -> int:
        """Accelerator-resident bytes of the bank's buffers."""
        total = self.params.nbytes + self.mom.nbytes
        if self.residual is not None:
            total += self.residual.nbytes
        return int(total)

    def load_rows(self, params: np.ndarray, mom: np.ndarray,
                  residual=None) -> None:
        """Overwrite the resident (n, T) buffers from host arrays via
        per-shard placement: each device fills only its own row slice
        through ``jax.make_array_from_callback`` against the CURRENT
        buffer shardings, so a sharded bank restore
        (``RunCheckpoint``) never materializes the full bank on one
        device — the restore-side mirror of :meth:`from_model_sharded`.
        ``residual`` is required iff the bank carries one."""
        def put(host, like):
            a = np.asarray(host, np.float32)
            assert a.shape == like.shape, (a.shape, like.shape)
            return jax.make_array_from_callback(
                a.shape, like.sharding, lambda idx: a[idx])
        self.params = put(params, self.params)
        self.mom = put(mom, self.mom)
        if self.residual is not None:
            assert residual is not None, "bank carries an EF residual"
            self.residual = put(residual, self.residual)

    # -- placement -----------------------------------------------------------
    def place(self, sharding) -> None:
        """Re-place the resident buffers onto ``sharding`` — e.g. the
        sharded engine's row sharding ``NamedSharding(mesh, P(replica,
        None))``, under which each device holds its own contiguous
        ``(rows_per_device, T)`` bank shard for the whole run."""
        self.params = jax.device_put(self.params, sharding)
        self.mom = jax.device_put(self.mom, sharding)
        if self.residual is not None:
            self.residual = jax.device_put(self.residual, sharding)

    # -- pytree edges --------------------------------------------------------
    def params_tree(self):
        """Materialize the (n, ...)-leaved pytree view (eval/ckpt edge)."""
        return self.layout.unflatten_stack(self.params)

    def mean_model(self):
        """Device-average model as a pytree (the global model x̄)."""
        return self.layout.unflatten_one(jnp.mean(self.params, 0))

    def project(self, P):
        """Row-apply a rectangular (m, n) operator to the bank and
        materialize the resulting m models as a pytree — the edge-model
        projection P of eq. 11 in one streaming pass."""
        return self.layout.unflatten_stack(
            gossip_mix_rows(jnp.asarray(P, jnp.float32), self.params))


# ---------------------------------------------------------------------------
# cohort compaction: static bucket sizes + padded gather plans
# ---------------------------------------------------------------------------

def cohort_buckets(n: int) -> Tuple[int, ...]:
    """Static cohort capacities: powers of two up to n, plus n itself.

    The compacted round is traced once per bucket (shapes are static
    under jit), so a scenario whose cohort size wanders round-to-round
    compiles at most ``len(cohort_buckets(n))`` variants instead of one
    per distinct cohort size."""
    assert n >= 1
    out = []
    b = 1
    while b < n:
        out.append(b)
        b <<= 1
    out.append(n)
    return tuple(out)


def bucket_for(k: int, buckets: Tuple[int, ...]) -> int:
    """Smallest bucket capacity >= k."""
    for b in buckets:
        if b >= k:
            return b
    raise ValueError(f"cohort {k} exceeds largest bucket {buckets[-1]}")


@dataclasses.dataclass(frozen=True)
class CompactPlan:
    """Padded gather plan for one round's cohort.

    ``idx`` holds ``k_pad`` *distinct* device rows: the k participants
    first, then non-participants as inert padding; ``lane`` marks the
    real cohort lanes. Distinctness makes the scatter back into the bank
    (``bank.at[idx].set``) write disjoint rows — deterministic, and the
    padding lanes write back their untouched values."""
    idx: np.ndarray     # (k_pad,) int32, distinct
    lane: np.ndarray    # (k_pad,) bool
    k: int              # true cohort size
    k_pad: int          # bucket capacity


def compact_plan(mask: np.ndarray,
                 buckets: Optional[Tuple[int, ...]] = None) -> CompactPlan:
    """Build the padded cohort gather plan for a 0/1 participation mask."""
    mask = np.asarray(mask)
    n = mask.shape[0]
    if buckets is None:
        buckets = cohort_buckets(n)
    cohort = np.nonzero(mask > 0)[0]
    k = int(cohort.shape[0])
    assert k >= 1, "compact_plan needs at least one participant"
    k_pad = bucket_for(k, buckets)
    pad = k_pad - k
    if pad:
        complement = np.nonzero(mask <= 0)[0]
        cohort = np.concatenate([cohort, complement[:pad]])
    lane = np.zeros(k_pad, bool)
    lane[:k] = True
    return CompactPlan(cohort.astype(np.int32), lane, k, k_pad)
