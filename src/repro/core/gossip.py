"""Topology-general sparse gossip schedules for the sharded trainer.

Replaces the ring-only ``sparse_gossip``/``cluster_ring_mix`` pair: for ANY
connected backhaul graph (ring, torus, star, complete, erdos_renyi, …) a
:class:`GossipSchedule` precomputes host-side a sequence of replica-level
``ppermute`` permutations plus per-cluster weight tables that realize either

- ``rounds`` (gossip_impl="sparse"): π applications of the mixing matrix H.
  The directed edge set of H is greedily colored into partial matchings
  (no two edges in a matching share a source or a destination), so each
  matching is a valid ``ppermute`` — unmatched receivers get zeros, which
  the weight table also zeroes. One gossip round is
  ``y_c = H[c,c]·x_c + Σ_k W_k[c]·recv_k(x)`` and moves deg(c)·|θ|
  neighbor bytes per replica.
- ``exact`` (gossip_impl="ringweight"): the exact operator H^π in M−1
  weighted cyclic rotations of the cluster models — each replica rotates
  its buffer one cluster step at a time and accumulates
  ``Σ_s H^π[(c+s)%M, c]·buf`` on the fly: (M−1)·|θ| neighbor bytes,
  bit-identical to the dense operator for any H (H^π is just a table).

Both run on the FLAT replica axis (``pod`` × ``data`` as one tuple axis, see
``core.collectives``), so multi-pod edge crossings need no special casing:
a cluster permutation is a replica permutation, wherever the replicas live.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.core import collectives as col


# ---------------------------------------------------------------------------
# host-side schedule construction
# ---------------------------------------------------------------------------

def staleness_mask(W: np.ndarray, labels: np.ndarray, phases: np.ndarray,
                   staleness: int, advancing: np.ndarray) -> np.ndarray:
    """Gate a dense (n, n) mixing operator for ONE async event.

    In bounded-staleness execution (``FLSimulator.step_round_async``) a
    mixing boundary fires per *cluster* as soon as that cluster's own
    block clears. ``advancing`` marks the clusters applying this
    boundary: every other device row becomes the identity (their models
    are frozen until their own boundary fires). ``phases`` counts blocks
    completed per cluster; advancing rows additionally drop columns of
    clusters whose phase lags (or leads) the advancing phase by more
    than ``staleness``, folding the removed mass onto the diagonal so
    rows stay stochastic — reading a neighbor within the bound is the
    whole point of async (a bounded-stale read), reading past it is
    forbidden.

    When every cluster advances at one common phase (the s = 0 barrier
    degeneracy) the operator is returned unchanged, bit for bit — the
    correctness anchor ``tests/test_async.py`` leans on."""
    labels = np.asarray(labels)
    phases = np.asarray(phases)
    adv = np.asarray(advancing, bool)
    if adv.all() and (phases == phases[0]).all():
        return np.asarray(W, np.float32)
    n = W.shape[0]
    Wm = np.array(W, np.float32, copy=True)
    p = int(phases[adv][0]) if adv.any() else 0
    keep_col = (np.abs(phases - p) <= staleness)[labels]     # (n,)
    row_adv = adv[labels]                                    # (n,)
    Wm = np.where(keep_col[None, :], Wm, 0.0)
    Wm[~row_adv] = np.eye(n, dtype=np.float32)[~row_adv]
    deficit = np.where(row_adv,
                       np.asarray(W, np.float64).sum(1) - Wm.sum(1), 0.0)
    Wm[np.arange(n), np.arange(n)] += deficit.astype(np.float32)
    return Wm


def fault_gate(W: np.ndarray, labels: np.ndarray,
               cluster_down: np.ndarray) -> np.ndarray:
    """Gate a dense (n, n) mixing operator for edge-server outages.

    ``cluster_down`` marks clusters whose edge server is dark this
    round (``FaultModel.outage windows``): their device rows become the
    identity (the cluster's models are frozen until it recovers) and
    every surviving row drops the dark clusters' columns, folding the
    removed mass onto its diagonal — exactly the
    :func:`staleness_mask` construction with the dark clusters pushed
    out of the staleness bound, so the result is row-stochastic by the
    same argument. With no cluster down the operator is returned
    unchanged, bit for bit (the fault-free parity anchor).

    Recovery needs no special casing: a cluster that comes back simply
    stops being gated and rejoins the next boundary (in async mode,
    through the existing staleness-bounded catch-up path)."""
    down = np.asarray(cluster_down, bool)
    if not down.any():
        return np.asarray(W, np.float32)
    phases = np.where(down, -1, 0)
    return staleness_mask(W, labels, phases, staleness=0,
                          advancing=~down)


def color_edges(adj: np.ndarray) -> List[Dict[int, int]]:
    """Partition the directed edge set into partial matchings.

    Greedy bipartite edge coloring: each color (matching) maps dst -> src
    with all sources distinct and all destinations distinct, so it lowers
    to one ``ppermute``. Uses at most 2·Δ−1 colors (König's bound is Δ;
    greedy is within 2×, which only affects the *number* of ppermutes, not
    the bytes moved — every directed edge appears exactly once overall).
    """
    m = adj.shape[0]
    edges = [(i, j) for i in range(m) for j in range(m)
             if i != j and adj[i, j]]
    colors: List[Dict[int, int]] = []   # dst -> src
    used_src: List[set] = []
    for (i, j) in edges:
        for k in range(len(colors)):
            if i not in used_src[k] and j not in colors[k]:
                colors[k][j] = i
                used_src[k].add(i)
                break
        else:
            colors.append({j: i})
            used_src.append({i})
    return colors


def _replica_perm(matching: Dict[int, int], dpc: int
                  ) -> Tuple[Tuple[int, int], ...]:
    """Cluster-level matching -> flat replica-level (src, dst) pairs."""
    return tuple((src * dpc + t, dst * dpc + t)
                 for dst, src in sorted(matching.items())
                 for t in range(dpc))


@dataclasses.dataclass(frozen=True)
class GossipSchedule:
    """Host-precomputed permutation + weight plan for one (H, π, geometry)."""
    mode: str                         # "rounds" | "exact"
    num_clusters: int                 # M
    devices_per_cluster: int          # dpc
    pi: int
    w_self: np.ndarray                # (M,)  diag of H            [rounds]
    perms: Tuple[Tuple[Tuple[int, int], ...], ...]  # K replica perms [rounds]
    weights: np.ndarray               # (K, M) weight per dst cluster[rounds]
    h_pi: np.ndarray                  # (M, M) H^π                  [exact]
    degrees: np.ndarray               # (M,) backhaul degree per cluster

    @staticmethod
    def build(H: np.ndarray, pi: int, devices_per_cluster: int,
              mode: str = "rounds") -> "GossipSchedule":
        assert mode in ("rounds", "exact"), mode
        H = np.asarray(H, np.float64)
        M = H.shape[0]
        adj = (np.abs(H) > 1e-12) & ~np.eye(M, dtype=bool)
        assert np.allclose(H, H.T), "mixing matrix must be symmetric"
        matchings = color_edges(adj)
        K = len(matchings)
        weights = np.zeros((max(K, 1), M))
        for k, mt in enumerate(matchings):
            for dst, src in mt.items():
                weights[k, dst] = H[src, dst]
        perms = tuple(_replica_perm(mt, devices_per_cluster)
                      for mt in matchings)
        return GossipSchedule(
            mode=mode, num_clusters=M,
            devices_per_cluster=devices_per_cluster, pi=pi,
            w_self=np.diag(H).copy(), perms=perms, weights=weights,
            h_pi=np.linalg.matrix_power(H, pi),
            degrees=adj.sum(1).astype(np.int64))

    # -- traffic accounting (used by benchmarks and the runtime model) ------
    @property
    def num_matchings(self) -> int:
        return len(self.perms)

    def models_received_per_replica(self) -> int:
        """Worst-case neighbor models received by one replica per
        inter-cluster aggregation (the |θ| multiplier)."""
        if self.num_clusters == 1:
            return 0
        if self.mode == "exact":
            return self.num_clusters - 1
        return int(self.pi * self.degrees.max())

    def models_received_total(self, num_replicas: int) -> int:
        """Network-wide models moved per inter-cluster aggregation."""
        if self.num_clusters == 1:
            return 0
        dpc = self.devices_per_cluster
        if self.mode == "exact":
            return (self.num_clusters - 1) * num_replicas
        return int(self.pi * self.degrees.sum() * dpc)

    # -- reference reconstruction (tested host-side) ------------------------
    def dense_equivalent(self) -> np.ndarray:
        """The M×M cluster operator this schedule applies (for parity
        tests): H for one round of ``rounds`` mode, H^π for ``exact``."""
        M = self.num_clusters
        if self.mode == "exact":
            return self.h_pi.copy()
        op = np.diag(self.w_self)
        for k, perm_k in enumerate(self.perms):
            for src_r, dst_r in perm_k:
                src_c = src_r // self.devices_per_cluster
                dst_c = dst_r // self.devices_per_cluster
                if src_r % self.devices_per_cluster == 0:
                    op[src_c, dst_c] += self.weights[k, dst_c]
        return op


# ---------------------------------------------------------------------------
# device-side application (inside an existing shard_map body or standalone)
# ---------------------------------------------------------------------------

def _unrolled() -> bool:
    from repro.flags import analysis_mode
    return analysis_mode()


def gossip_in_body(sched: GossipSchedule, mesh: Mesh, p):
    """Apply the schedule to the LOCAL shard ``p`` (pytree of f32 leaves)
    inside an existing ``shard_map`` body.

    This is the reusable core of :func:`apply_gossip`: the pytree trainer
    wraps it in its own shard_map, and the sharded ModelBank engine
    (``core.sharded.ShardedBankCEFedAvg``) calls it on bank-row shards to
    fuse the π gossip rounds into the same pass as the intra-cluster psum
    — O(π·deg·|row|) neighbor ``ppermute`` traffic, the full bank never
    materialized on one device."""
    M = sched.num_clusters
    if M == 1:
        return p
    dpc = sched.devices_per_cluster
    R = col.flat_axis_size(mesh)
    assert R == M * dpc, (R, M, dpc)

    if sched.mode == "exact":
        h_pi = jnp.asarray(sched.h_pi, jnp.float32)
        rot = col.rotate_perm(mesh, dpc)
        c = col.flat_axis_index(mesh) // dpc
        buf = p
        acc = jax.tree.map(lambda b: h_pi[c, c] * b, buf)
        for s in range(1, M):
            buf = jax.tree.map(
                lambda b: col.ppermute(b, mesh, rot), buf)
            w = h_pi[(c + s) % M, c]
            acc = jax.tree.map(lambda a, b: a + w * b, acc, buf)
        return acc

    w_self = jnp.asarray(sched.w_self, jnp.float32)
    w_tbl = jnp.asarray(sched.weights, jnp.float32)
    perms = sched.perms
    c = col.flat_axis_index(mesh) // dpc
    ws = w_self[c]
    wk = w_tbl[:, c]

    def gossip_step(_, q):
        def leaf(xf):
            acc = ws * xf
            for k, perm_k in enumerate(perms):
                acc = acc + wk[k] * col.ppermute(xf, mesh, perm_k)
            return acc
        return jax.tree.map(leaf, q)

    if _unrolled():   # unroll so cost_analysis counts every step
        q = p
        for i in range(sched.pi):
            q = gossip_step(i, q)
        return q
    return jax.lax.fori_loop(0, sched.pi, gossip_step, p)


def apply_gossip(sched: GossipSchedule, params, specs, mesh: Mesh):
    """Apply the schedule to replica-stacked params (leading axis R)."""
    if sched.num_clusters == 1:
        return params

    def body(p):
        q = gossip_in_body(
            sched, mesh, jax.tree.map(lambda x: x.astype(jnp.float32), p))
        return jax.tree.map(lambda x, o: o.astype(x.dtype), p, q)

    return col.shard_map(body, mesh, (specs,), specs)(params)


def group_mean_in_body(mesh: Mesh, p, groups):
    """Mean of the LOCAL f32 shard over arbitrary equal-size replica
    groups inside an existing ``shard_map`` body: one grouped psum per
    leaf over the flat replica axis. ``groups`` is a partition of the
    replica ids (e.g. a :class:`repro.core.groups.TierGroups` member
    list); each group averages over its own members only."""
    size = len(groups[0])
    if size == 1:
        return p
    groups = [list(g) for g in groups]
    inv = 1.0 / size
    return jax.tree.map(
        lambda x: col.psum_groups(x, mesh, groups) * inv, p)


def cluster_mean_in_body(mesh: Mesh, p, num_clusters: int,
                         devices_per_cluster: int):
    """Intra-cluster averaging of the LOCAL f32 shard inside an existing
    ``shard_map`` body: one grouped psum per leaf over the flat replica
    axis (eq. 11's V restricted to this shard). Shared by
    :func:`apply_cluster_mean` and the sharded ModelBank engine's fused
    τ/qτ boundary. Thin wrapper over :func:`group_mean_in_body` with the
    contiguous per-cluster partition."""
    dpc = devices_per_cluster
    if dpc == 1:
        return p
    groups = [tuple(range(c * dpc, (c + 1) * dpc))
              for c in range(num_clusters)]
    return group_mean_in_body(mesh, p, groups)


def apply_cluster_mean(params, specs, mesh: Mesh, num_clusters: int,
                       devices_per_cluster: int):
    """Intra-cluster averaging via grouped psum on the flat replica axis."""
    if devices_per_cluster == 1:
        return params

    def body(p):
        q = cluster_mean_in_body(
            mesh, jax.tree.map(lambda x: x.astype(jnp.float32), p),
            num_clusters, devices_per_cluster)
        return jax.tree.map(lambda x, o: o.astype(x.dtype), p, q)
    return col.shard_map(body, mesh, (specs,), specs)(params)


def dense_mix_rows(W: jax.Array, x: jax.Array, mesh: Mesh) -> jax.Array:
    """Row-apply an ARBITRARY dense (R, R) operator to per-device bank
    rows inside an existing ``shard_map`` body, without ever gathering the
    (R, T) bank: R−1 weighted cyclic rotations accumulate
    ``y_d = Σ_j W[d, j]·x_j`` on the fly (the ``ringweight`` lowering
    generalized to asymmetric row-stochastic operators — the
    masked/mobility W_t of ``core.scenario``). ``x`` is this device's f32
    row(s) ``(1, T)``; ``W`` is replicated. Traffic: (R−1)·|row| neighbor
    bytes per device per boundary."""
    R = col.flat_axis_size(mesh)
    my = col.flat_axis_index(mesh)
    if R == 1:
        return W[0, 0] * x
    rot = col.rotate_perm(mesh, 1)
    Wf = W.astype(jnp.float32)

    def step(s, carry):
        acc, buf = carry
        buf = col.ppermute(buf, mesh, rot)
        acc = acc + Wf[my, (my + s) % R] * buf
        return acc, buf

    init = (Wf[my, my] * x, x)
    if _unrolled():
        acc, buf = init
        for s in range(1, R):
            acc, buf = step(s, (acc, buf))
    else:
        acc, buf = jax.lax.fori_loop(1, R, step, init)
    return acc
