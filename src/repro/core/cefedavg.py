"""CE-FedAvg (Algorithm 1) — operator algebra + the simulation engine.

The paper's update rule (eq. 10):  X_{t+1} = (X_t − η G_t) W_t, with
W_t ∈ {I, V, B^T diag(c) H^π B} depending on the iteration (eq. 11).
``make_w_schedule`` builds those operators for CE-FedAvg and for every
baseline (Table 1 / §4.3 special cases); ``FLSimulator`` runs the literal
matrix form with all n device models materialized (vmap) — the
paper-faithful engine used for the Figure 2–6 reproductions and for
unit-testing the sharded production trainer against.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FLConfig
from repro.core import topology as topo


@dataclass
class WSchedule:
    """Mixing operators applied at iteration boundaries (eq. 11)."""
    W_intra: np.ndarray      # applied when (t+1) % tau == 0 (and not inter)
    W_inter: np.ndarray      # applied when (t+1) % (q*tau) == 0
    H: np.ndarray            # m x m backhaul mixing matrix
    zeta: float
    cluster_sizes: List[int]
    adj: np.ndarray          # m x m backhaul adjacency (bool)

    @property
    def n(self) -> int:
        return self.W_intra.shape[0]

    @property
    def degrees(self) -> np.ndarray:
        """Backhaul degree of each cluster (traffic accounting)."""
        return self.adj.sum(1).astype(np.int64)


def make_w_schedule(fl: FLConfig) -> WSchedule:
    """Static mixing schedule (eq. 11 / Table 1): W_intra applied at
    τ-boundaries, W_inter at qτ-boundaries, specialized per algorithm via
    the §4.3 reductions (Hier-FAvg, FedAvg, Local-Edge, dec. local SGD).
    Assumes equal clusters and full participation; the scenario engine
    (core/scenario.py) builds the time-varying masked generalization."""
    fl.validate()
    m, n = fl.num_clusters, fl.n
    sizes = [fl.devices_per_cluster] * m
    V = topo.intra_cluster_operator(sizes)
    A = np.ones((n, n)) / n
    eye = np.eye(n)
    adj = topo.build_adjacency(fl.topology, m, fl)
    H = topo.mixing_matrix(adj, fl.mixing)
    if fl.algorithm == "ce_fedavg":
        W_intra, W_inter = V, topo.inter_cluster_operator(sizes, H, fl.pi)
    elif fl.algorithm == "hier_favg":
        W_intra, W_inter = V, A
    elif fl.algorithm == "fedavg":
        W_intra, W_inter = eye, A
    elif fl.algorithm == "local_edge":
        W_intra, W_inter = V, V
    elif fl.algorithm == "dec_local_sgd":
        # n == m: every device is its own cluster, neighbors gossip
        assert fl.devices_per_cluster == 1, "dec_local_sgd requires n == m"
        W_intra = eye
        W_inter = np.linalg.matrix_power(H, fl.pi)
    else:
        raise ValueError(fl.algorithm)
    return WSchedule(W_intra, W_inter, H, topo.zeta(H), sizes, adj)


def mix(W, params):
    """Apply a mixing operator over the leading device axis of every leaf:
    x_k ← Σ_j W[k,j]·x_j (row application).

    The paper's eq. 10 operators are symmetric doubly stochastic, where
    row and column application coincide; the masked/unequal-cluster
    generalizations (core/scenario.py) are only row-stochastic, so the
    row form is the correct one for both."""
    Wj = jnp.asarray(W, jnp.float32)

    def one(leaf):
        out = jnp.tensordot(Wj, leaf.astype(jnp.float32), axes=[[1], [0]])
        return out.astype(leaf.dtype)
    return jax.tree.map(one, params)


# ---------------------------------------------------------------------------
# Simulation engine (paper-faithful, laptop scale)
# ---------------------------------------------------------------------------

class FLSimulator:
    """Runs Algorithm 1 with n materialized device models.

    init_fn(key) -> params;  apply_fn(params, x) -> logits.
    data: dict with xs (n, N, ...), ys (n, N) — per-device training shards;
          test_x, test_y — the common test set.
    scenario: optional config.ScenarioConfig — per-round client sampling,
          straggler dropout and device mobility (core/scenario.py); pair
          with core.clock.run_wall_clock for time-to-accuracy curves.
    """

    def __init__(self, init_fn: Callable, apply_fn: Callable, fl: FLConfig,
                 data: Dict[str, Any], *, lr: float = 0.05,
                 momentum: float = 0.9, batch_size: int = 50, seed: int = 0,
                 compression=None, dp=None, scenario=None):
        self.fl = fl
        self.apply_fn = apply_fn
        self.sched = make_w_schedule(fl)
        n = self.sched.n
        assert data["xs"].shape[0] == n
        self.data = data
        self.lr, self.momentum, self.batch = lr, momentum, batch_size
        self.compression = compression  # core.compress.CompressionConfig
        self.dp = dp                    # core.privacy.DPConfig
        # wall-clock scenario (config.ScenarioConfig): per-round sampling,
        # mobility and heterogeneity — None keeps the static schedule
        if scenario is not None:
            from repro.core.scenario import ScenarioEngine
            self.engine = ScenarioEngine(scenario, fl)
        else:
            self.engine = None
        # current cluster assignment B_t (mobility re-draws it per round)
        self.labels = np.repeat(np.arange(fl.num_clusters),
                                fl.devices_per_cluster)
        self._W_intra_j = jnp.asarray(self.sched.W_intra, jnp.float32)
        self._W_inter_j = jnp.asarray(self.sched.W_inter, jnp.float32)
        self._full_mask = jnp.ones((n,), jnp.float32)
        # Algorithm 1 initializes every device from its edge model y_{0,0};
        # we use one shared init (common FL practice), so params are
        # cluster-uniform from the start.
        one = init_fn(jax.random.PRNGKey(seed))
        self.params = jax.tree.map(
            lambda l: jnp.broadcast_to(l, (n,) + l.shape).copy(), one)
        self.mom = jax.tree.map(jnp.zeros_like, self.params)
        self.residual = (jax.tree.map(jnp.zeros_like, self.params)
                         if compression is not None and
                         compression.error_feedback else None)
        self.key = jax.random.PRNGKey(seed + 1)
        self._round = self._build_round()

    # -- loss --------------------------------------------------------------
    def _loss(self, p, x, y):
        logits = self.apply_fn(p, x)
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        return jnp.mean(lse - picked)

    # -- one global round, jitted ------------------------------------------
    def _build_round(self):
        """The jitted global round. W_intra/W_inter/mask are *arguments*
        (not closure constants) so the scenario engine can re-draw them
        between rounds without recompiling: masked devices take no local
        steps (their params and momentum are frozen via ``where``) and the
        operators are whatever (possibly unequal/masked) matrices the
        caller passes — the static schedule with a full mask reproduces
        the original fixed-schedule round bit-for-bit."""
        fl = self.fl
        n = self.sched.n
        N = self.data["xs"].shape[1]
        grad_fn = jax.grad(self._loss)

        def bcast(act, leaf):
            return act.reshape((-1,) + (1,) * (leaf.ndim - 1))

        def make_local_step(act):
            def local_step(carry, key):
                params, mom = carry
                idx = jax.random.randint(key, (n, self.batch), 0, N)
                xb = jax.vmap(lambda x, i: x[i])(self.data["xs"], idx)
                yb = jax.vmap(lambda y, i: y[i])(self.data["ys"], idx)
                grads = jax.vmap(grad_fn)(params, xb, yb)
                mom = jax.tree.map(
                    lambda v, g: jnp.where(bcast(act, v),
                                           self.momentum * v + g, v),
                    mom, grads)
                params = jax.tree.map(
                    lambda p, v: jnp.where(bcast(act, p),
                                           p - self.lr * v, p),
                    params, mom)
                return (params, mom), None
            return local_step

        comp, dp = self.compression, self.dp

        def upload_transform(delta, residual, key):
            """Device-side: (optional) DP then compression of the delta."""
            if dp is not None and dp.enabled:
                from repro.core.privacy import privatize_update
                keys = jax.random.split(key, n)
                delta = jax.vmap(
                    lambda d, k: privatize_update(d, dp, k))(
                        delta, keys)
            if comp is not None and comp.kind != "none":
                from repro.core.compress import compress_tree
                keys = jax.random.split(jax.random.fold_in(key, 1), n)
                delta, residual = jax.vmap(
                    lambda d, r, k: compress_tree(comp, d, r, k)
                )(delta, residual, keys)
            return delta, residual

        def make_edge_round(W_intra, act):
            local_step = make_local_step(act)

            def edge_round(carry, key):
                params0, mom, residual = carry
                keys = jax.random.split(key, fl.tau)
                (params, mom), _ = jax.lax.scan(local_step, (params0, mom),
                                                keys)
                if comp is None and dp is None:
                    params = mix(W_intra, params)
                else:
                    # devices upload (privatized/compressed) deltas; the edge
                    # reconstructs x_start + V·delta (exact when both are off)
                    delta = jax.tree.map(lambda a, b: a - b, params, params0)
                    delta, residual = upload_transform(
                        delta, residual, jax.random.fold_in(key, 7))
                    params = jax.tree.map(
                        lambda p0, d: p0 + d, params0, mix(W_intra, delta))
                return (params, mom, residual), None
            return edge_round

        @jax.jit
        def global_round(params, mom, residual, key, W_intra, W_inter,
                         mask):
            act = mask > 0.5
            edge_round = make_edge_round(W_intra, act)
            keys = jax.random.split(key, fl.q)
            (params, mom, residual), _ = jax.lax.scan(
                edge_round, (params, mom, residual), keys)
            params = mix(W_inter, params)
            return params, mom, residual

        return global_round

    # -- driver -------------------------------------------------------------
    def step_round(self):
        """Advance ONE global round.

        With a scenario attached, first realizes this round's plan
        (mobility re-draws B_t, sampling draws the cohort) and feeds the
        induced masked operators to the jitted round; otherwise replays
        the static schedule with full participation. Returns the
        ``RoundPlan`` (or None without a scenario) so callers — e.g. the
        wall-clock harness in core/clock.py — can charge the cohort."""
        if self.engine is not None:
            plan = self.engine.step()
            self.labels = plan.labels
            W_intra = jnp.asarray(plan.W_intra, jnp.float32)
            W_inter = jnp.asarray(plan.W_inter, jnp.float32)
            mask = jnp.asarray(plan.mask, jnp.float32)
        else:
            plan = None
            W_intra, W_inter = self._W_intra_j, self._W_inter_j
            mask = self._full_mask
        self.key, k = jax.random.split(self.key)
        self.params, self.mom, self.residual = self._round(
            self.params, self.mom, self.residual, k, W_intra, W_inter,
            mask)
        return plan

    def run(self, rounds: int, eval_every: int = 1,
            eval_batch: int = 512) -> Dict[str, List[float]]:
        hist: Dict[str, List[float]] = {"round": [], "acc": [], "loss": []}
        for r in range(rounds):
            self.step_round()
            if (r + 1) % eval_every == 0:
                acc, loss = self.evaluate(eval_batch)
                hist["round"].append(r + 1)
                hist["acc"].append(acc)
                hist["loss"].append(loss)
        return hist

    def edge_models(self):
        """Cluster-averaged (edge) models y_t — what the paper evaluates.
        Uses the CURRENT assignment B_t (mobility moves devices between
        clusters, so membership is re-read every call)."""
        B = topo.assignment_matrix(self.labels, self.fl.num_clusters)
        # mix() row-applies, so a rectangular (m, n) averaging operator
        # maps the n device models straight to the m edge models
        return mix(topo.masked_cluster_average(B), self.params)

    def global_model(self):
        return jax.tree.map(lambda l: jnp.mean(l, 0), self.params)

    def evaluate(self, eval_batch: int = 512):
        """Mean test accuracy of the m edge models on the common test set."""
        em = self.edge_models()
        tx = self.data["test_x"][:eval_batch]
        ty = self.data["test_y"][:eval_batch]

        def one(p):
            logits = self.apply_fn(p, tx)
            acc = jnp.mean((jnp.argmax(logits, -1) == ty).astype(jnp.float32))
            lse = jax.nn.logsumexp(logits, axis=-1)
            picked = jnp.take_along_axis(logits, ty[:, None], -1)[:, 0]
            return acc, jnp.mean(lse - picked)
        accs, losses = jax.vmap(one)(em)
        return float(jnp.mean(accs)), float(jnp.mean(losses))
