"""CE-FedAvg (Algorithm 1) — operator algebra + the simulation engine.

The paper's update rule (eq. 10):  X_{t+1} = (X_t − η G_t) W_t, with
W_t ∈ {I, V, B^T diag(c) H^π B} depending on the iteration (eq. 11).
``make_w_schedule`` builds those operators for CE-FedAvg and for every
baseline (Table 1 / §4.3 special cases); ``FLSimulator`` runs the literal
matrix form with all n device models materialized (vmap) — the
paper-faithful engine used for the Figure 2–6 reproductions and for
unit-testing the sharded production trainer against.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FLConfig
from repro.core import topology as topo


@dataclass
class WSchedule:
    """Mixing operators applied at iteration boundaries (eq. 11)."""
    W_intra: np.ndarray      # applied when (t+1) % tau == 0 (and not inter)
    W_inter: np.ndarray      # applied when (t+1) % (q*tau) == 0
    H: np.ndarray            # m x m backhaul mixing matrix
    zeta: float
    cluster_sizes: List[int]
    adj: np.ndarray          # m x m backhaul adjacency (bool)

    @property
    def n(self) -> int:
        return self.W_intra.shape[0]

    @property
    def degrees(self) -> np.ndarray:
        """Backhaul degree of each cluster (traffic accounting)."""
        return self.adj.sum(1).astype(np.int64)


def make_w_schedule(fl: FLConfig) -> WSchedule:
    fl.validate()
    m, n = fl.num_clusters, fl.n
    sizes = [fl.devices_per_cluster] * m
    V = topo.intra_cluster_operator(sizes)
    A = np.ones((n, n)) / n
    eye = np.eye(n)
    adj = topo.build_adjacency(fl.topology, m, fl)
    H = topo.mixing_matrix(adj, fl.mixing)
    if fl.algorithm == "ce_fedavg":
        W_intra, W_inter = V, topo.inter_cluster_operator(sizes, H, fl.pi)
    elif fl.algorithm == "hier_favg":
        W_intra, W_inter = V, A
    elif fl.algorithm == "fedavg":
        W_intra, W_inter = eye, A
    elif fl.algorithm == "local_edge":
        W_intra, W_inter = V, V
    elif fl.algorithm == "dec_local_sgd":
        # n == m: every device is its own cluster, neighbors gossip
        assert fl.devices_per_cluster == 1, "dec_local_sgd requires n == m"
        W_intra = eye
        W_inter = np.linalg.matrix_power(H, fl.pi)
    else:
        raise ValueError(fl.algorithm)
    return WSchedule(W_intra, W_inter, H, topo.zeta(H), sizes, adj)


def mix(W, params):
    """Apply a mixing matrix over the leading device axis of every leaf."""
    Wj = jnp.asarray(W, jnp.float32)

    def one(leaf):
        out = jnp.tensordot(Wj, leaf.astype(jnp.float32), axes=[[0], [0]])
        return out.astype(leaf.dtype)
    return jax.tree.map(one, params)


# ---------------------------------------------------------------------------
# Simulation engine (paper-faithful, laptop scale)
# ---------------------------------------------------------------------------

class FLSimulator:
    """Runs Algorithm 1 with n materialized device models.

    init_fn(key) -> params;  apply_fn(params, x) -> logits.
    data: dict with xs (n, N, ...), ys (n, N) — per-device training shards;
          test_x, test_y — the common test set.
    """

    def __init__(self, init_fn: Callable, apply_fn: Callable, fl: FLConfig,
                 data: Dict[str, Any], *, lr: float = 0.05,
                 momentum: float = 0.9, batch_size: int = 50, seed: int = 0,
                 compression=None, dp=None):
        self.fl = fl
        self.apply_fn = apply_fn
        self.sched = make_w_schedule(fl)
        n = self.sched.n
        assert data["xs"].shape[0] == n
        self.data = data
        self.lr, self.momentum, self.batch = lr, momentum, batch_size
        self.compression = compression  # core.compress.CompressionConfig
        self.dp = dp                    # core.privacy.DPConfig
        # Algorithm 1 initializes every device from its edge model y_{0,0};
        # we use one shared init (common FL practice), so params are
        # cluster-uniform from the start.
        one = init_fn(jax.random.PRNGKey(seed))
        self.params = jax.tree.map(
            lambda l: jnp.broadcast_to(l, (n,) + l.shape).copy(), one)
        self.mom = jax.tree.map(jnp.zeros_like, self.params)
        self.residual = (jax.tree.map(jnp.zeros_like, self.params)
                         if compression is not None and
                         compression.error_feedback else None)
        self.key = jax.random.PRNGKey(seed + 1)
        self._round = self._build_round()

    # -- loss --------------------------------------------------------------
    def _loss(self, p, x, y):
        logits = self.apply_fn(p, x)
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        return jnp.mean(lse - picked)

    # -- one global round, jitted ------------------------------------------
    def _build_round(self):
        fl = self.fl
        W_intra = jnp.asarray(self.sched.W_intra, jnp.float32)
        W_inter = jnp.asarray(self.sched.W_inter, jnp.float32)
        n = self.sched.n
        N = self.data["xs"].shape[1]
        grad_fn = jax.grad(self._loss)

        def local_step(carry, key):
            params, mom = carry
            idx = jax.random.randint(key, (n, self.batch), 0, N)
            xb = jax.vmap(lambda x, i: x[i])(self.data["xs"], idx)
            yb = jax.vmap(lambda y, i: y[i])(self.data["ys"], idx)
            grads = jax.vmap(grad_fn)(params, xb, yb)
            mom = jax.tree.map(
                lambda v, g: self.momentum * v + g, mom, grads)
            params = jax.tree.map(
                lambda p, v: p - self.lr * v, params, mom)
            return (params, mom), None

        comp, dp = self.compression, self.dp

        def upload_transform(delta, residual, key):
            """Device-side: (optional) DP then compression of the delta."""
            if dp is not None and dp.enabled:
                from repro.core.privacy import privatize_update
                keys = jax.random.split(key, n)
                delta = jax.vmap(
                    lambda d, k: privatize_update(d, dp, k))(
                        delta, keys)
            if comp is not None and comp.kind != "none":
                from repro.core.compress import compress_tree
                keys = jax.random.split(jax.random.fold_in(key, 1), n)
                delta, residual = jax.vmap(
                    lambda d, r, k: compress_tree(comp, d, r, k)
                )(delta, residual, keys)
            return delta, residual

        def edge_round(carry, key):
            params0, mom, residual = carry
            keys = jax.random.split(key, fl.tau)
            (params, mom), _ = jax.lax.scan(local_step, (params0, mom),
                                            keys)
            if comp is None and dp is None:
                params = mix(W_intra, params)
            else:
                # devices upload (privatized/compressed) deltas; the edge
                # reconstructs x_start + V·delta (exact when both are off)
                delta = jax.tree.map(lambda a, b: a - b, params, params0)
                delta, residual = upload_transform(
                    delta, residual, jax.random.fold_in(key, 7))
                params = jax.tree.map(
                    lambda p0, d: p0 + d, params0, mix(W_intra, delta))
            return (params, mom, residual), None

        @jax.jit
        def global_round(params, mom, residual, key):
            keys = jax.random.split(key, fl.q)
            (params, mom, residual), _ = jax.lax.scan(
                edge_round, (params, mom, residual), keys)
            params = mix(W_inter, params)
            return params, mom, residual

        return global_round

    # -- driver -------------------------------------------------------------
    def run(self, rounds: int, eval_every: int = 1,
            eval_batch: int = 512) -> Dict[str, List[float]]:
        hist: Dict[str, List[float]] = {"round": [], "acc": [], "loss": []}
        for r in range(rounds):
            self.key, k = jax.random.split(self.key)
            self.params, self.mom, self.residual = self._round(
                self.params, self.mom, self.residual, k)
            if (r + 1) % eval_every == 0:
                acc, loss = self.evaluate(eval_batch)
                hist["round"].append(r + 1)
                hist["acc"].append(acc)
                hist["loss"].append(loss)
        return hist

    def edge_models(self):
        """Cluster-averaged (edge) models — what the paper evaluates."""
        V = topo.intra_cluster_operator(self.sched.cluster_sizes)
        mixed = mix(V, self.params)
        # one representative per cluster (first device of each)
        starts = np.cumsum([0] + self.sched.cluster_sizes[:-1])
        return jax.tree.map(lambda l: l[starts], mixed)

    def global_model(self):
        return jax.tree.map(lambda l: jnp.mean(l, 0), self.params)

    def evaluate(self, eval_batch: int = 512):
        """Mean test accuracy of the m edge models on the common test set."""
        em = self.edge_models()
        tx = self.data["test_x"][:eval_batch]
        ty = self.data["test_y"][:eval_batch]

        def one(p):
            logits = self.apply_fn(p, tx)
            acc = jnp.mean((jnp.argmax(logits, -1) == ty).astype(jnp.float32))
            lse = jax.nn.logsumexp(logits, axis=-1)
            picked = jnp.take_along_axis(logits, ty[:, None], -1)[:, 0]
            return acc, jnp.mean(lse - picked)
        accs, losses = jax.vmap(one)(em)
        return float(jnp.mean(accs)), float(jnp.mean(losses))
