"""CE-FedAvg (Algorithm 1) — operator algebra + the simulation engine.

The paper's update rule (eq. 10):  X_{t+1} = (X_t − η G_t) W_t, with
W_t ∈ {I, V, B^T diag(c) H^π B} depending on the iteration (eq. 11).
``make_w_schedule`` builds those operators for CE-FedAvg and for every
baseline (Table 1 / §4.3 special cases); ``FLSimulator`` runs the literal
matrix form with all n device models materialized — the paper-faithful
engine used for the Figure 2–6 reproductions and for unit-testing the
sharded production trainer against.

Two interchangeable engines live behind the same ``FLSimulator`` API:

- **ModelBank (default, ``bank=True``)** — params, momentum and the
  error-feedback residual are single contiguous ``(n, T)`` float32
  buffers (``core/modelbank.py``); pytree views exist only inside the
  per-device ``apply_fn`` and at eval/checkpoint edges. Every mixing
  boundary is ONE streaming pass of the fused gossip kernel
  (``kernels/gossip_mix.gossip_mix_rows``), the coincident τ/qτ boundary
  is folded into a single pass with the precomputed operator
  ``W_inter @ W_intra``, the jitted round donates its buffers (peak
  memory ~1× the bank), and scenario rounds with partial participation
  run their gradient work on a compacted ``(k_pad, T)`` cohort gather
  (static bucketed sizes, ``modelbank.cohort_buckets``).
- **legacy pytree (``bank=False``)** — per-leaf ``tensordot`` mixing and
  full-n ``where``-frozen local steps; kept as the bit-faithful parity
  reference (``tests/test_modelbank.py``).

Both engines (and the sharded bank in ``core/sharded.py``) execute one
shared declarative schedule: a :class:`repro.core.program.RoundProgram`.
The static τ/q/π knobs compile to the canonical program
(``program.canonical_program``); ``_lower_legacy`` / ``_lower_flat`` /
``_lower_compact`` are *compilers* from any validated program to that
engine's jitted round, and a ``schedule=`` hook (a name from
``program.SCHEDULES``, a ``ScheduleFn``, or a fixed ``RoundProgram``)
swaps in non-canonical schedules — adaptive per-cluster τ_k,
time-varying π_t — without touching engine code.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FLConfig
from repro.core import program as prg
from repro.core import topology as topo
from repro.core.modelbank import (ModelBank, bucket_for, cohort_buckets,
                                  compact_plan)
from repro.kernels.gossip_mix import FlatLayout, gossip_mix_rows


@dataclass
class WSchedule:
    """Mixing operators applied at iteration boundaries (eq. 11)."""
    W_intra: np.ndarray      # applied when (t+1) % tau == 0 (and not inter)
    W_inter: np.ndarray      # applied when (t+1) % (q*tau) == 0
    H: np.ndarray            # m x m backhaul mixing matrix
    zeta: float
    cluster_sizes: List[int]
    adj: np.ndarray          # m x m backhaul adjacency (bool)

    @property
    def n(self) -> int:
        return self.W_intra.shape[0]

    @property
    def degrees(self) -> np.ndarray:
        """Backhaul degree of each cluster (traffic accounting)."""
        return self.adj.sum(1).astype(np.int64)


def make_w_schedule(fl: FLConfig) -> WSchedule:
    """Static mixing schedule (eq. 11 / Table 1): W_intra applied at
    τ-boundaries, W_inter at qτ-boundaries, specialized per algorithm via
    the §4.3 reductions (Hier-FAvg, FedAvg, Local-Edge, dec. local SGD).
    Assumes equal clusters and full participation; the scenario engine
    (core/scenario.py) builds the time-varying masked generalization."""
    fl.validate()
    m, n = fl.num_clusters, fl.n
    sizes = [fl.devices_per_cluster] * m
    V = topo.intra_cluster_operator(sizes)
    A = np.ones((n, n)) / n
    eye = np.eye(n)
    # tier-1 backhaul graph: one topology graph over all m edges at depth
    # 2 (the paper), block-diagonal per-parent graphs for deeper
    # hierarchies (kron(I, H_block) — see topology.Hierarchy)
    hier = topo.Hierarchy.from_config(fl)
    adj = hier.adjacency(1, fl.topology, fl)
    H = topo.mixing_matrix(adj, fl.mixing)
    if fl.algorithm == "ce_fedavg":
        W_intra, W_inter = V, topo.inter_cluster_operator(sizes, H, fl.pi)
    elif fl.algorithm == "hier_favg":
        W_intra, W_inter = V, A
    elif fl.algorithm == "fedavg":
        W_intra, W_inter = eye, A
    elif fl.algorithm == "local_edge":
        W_intra, W_inter = V, V
    elif fl.algorithm == "dec_local_sgd":
        # n == m: every device is its own cluster, neighbors gossip
        assert fl.devices_per_cluster == 1, "dec_local_sgd requires n == m"
        W_intra = eye
        W_inter = np.linalg.matrix_power(H, fl.pi)
    else:
        raise ValueError(fl.algorithm)
    return WSchedule(W_intra, W_inter, H, topo.zeta(H), sizes, adj)


def mix(W, params):
    """Apply a mixing operator over the leading device axis of every leaf:
    x_k ← Σ_j W[k,j]·x_j (row application).

    The paper's eq. 10 operators are symmetric doubly stochastic, where
    row and column application coincide; the masked/unequal-cluster
    generalizations (core/scenario.py) are only row-stochastic, so the
    row form is the correct one for both."""
    Wj = jnp.asarray(W, jnp.float32)

    def one(leaf):
        out = jnp.tensordot(Wj, leaf.astype(jnp.float32), axes=[[1], [0]])
        return out.astype(leaf.dtype)
    return jax.tree.map(one, params)


# ---------------------------------------------------------------------------
# Simulation engine (paper-faithful, laptop scale)
# ---------------------------------------------------------------------------

class FLSimulator:
    """Runs Algorithm 1 with n materialized device models.

    init_fn(key) -> params;  apply_fn(params, x) -> logits.
    data: dict with xs (n, N, ...), ys (n, N) — per-device training shards;
          test_x, test_y — the common test set.
    scenario: optional config.ScenarioConfig — per-round client sampling,
          straggler dropout and device mobility (core/scenario.py); pair
          with core.clock.run_wall_clock for time-to-accuracy curves.
    schedule: optional round schedule override — a name from
          ``program.SCHEDULES`` ("static", "adaptive_tau", "pi_decay"),
          a ``program.ScheduleFn``, or a fixed ``program.RoundProgram``.
          None runs the canonical program compiled from fl's τ/q/π.
    bank: True (default) runs the flat ModelBank engine; False the legacy
          per-leaf pytree engine (parity/debug escape hatch). ``params``,
          ``mom`` and ``residual`` read/write as pytrees in both modes.
    streaming: True pages client state through a
          :class:`repro.core.clientstore.ClientStore` instead of a
          resident (n, T) bank — only each round's working set (cohort
          + one cold representative per cluster) is materialized as the
          hot slab. Implied (and required) when the scenario carries a
          ``PopulationConfig``; at enumerated n it reproduces the
          resident trajectory to float tolerance (the gemm shapes — and
          so the fp summation order — of the restricted operators
          differ; everything keyed is identical).
    codec: cold-row codec for the streamed store ("f32"/"f16"/"int8");
          a population scenario's ``PopulationConfig.codec`` wins.
    pipeline: True overlaps streamed paging with compute (ISSUE 10):
          the cold codec runs on device (``kernels/cold_codec.py``), the
          cluster references stay device-resident, round t's page-out
          drains asynchronously while round t+1 computes, and — every
          engine draw being a pure function of (seed, round) — round
          t+1's cohort is peeked and its cold rows staged/H2D'd during
          round t. Matches the serial streamed driver bit-identically
          at f32 (to codec tolerance at f16/int8); requires streaming.
    """

    def __init__(self, init_fn: Callable, apply_fn: Callable, fl: FLConfig,
                 data: Dict[str, Any], *, lr: float = 0.05,
                 momentum: float = 0.9, batch_size: int = 50, seed: int = 0,
                 compression=None, dp=None, scenario=None, schedule=None,
                 bank: bool = True, streaming: bool = False,
                 codec: str = "f32", store_shards: int = 1,
                 slab_sharding=None, min_bucket: int = 1,
                 pipeline: bool = False):
        self.fl = fl
        self.apply_fn = apply_fn
        self.sched = make_w_schedule(fl)
        n = self.sched.n
        assert data["xs"].shape[0] == n
        self.data = data
        self.lr, self.momentum, self.batch = lr, momentum, batch_size
        self.compression = compression  # core.compress.CompressionConfig
        self.dp = dp                    # core.privacy.DPConfig
        # wall-clock scenario (config.ScenarioConfig): per-round sampling,
        # mobility and heterogeneity — None keeps the static schedule. A
        # scenario with a PopulationConfig swaps in the PopulationEngine
        # (virtual clients, keyed cohort draws) and forces streaming.
        self.pop = None
        if scenario is not None and scenario.population is not None:
            from repro.core.scenario import PopulationEngine
            self.engine = PopulationEngine(scenario, fl)
            self.pop = self.engine
            streaming = True
        elif scenario is not None:
            from repro.core.scenario import ScenarioEngine
            self.engine = ScenarioEngine(scenario, fl)
        else:
            self.engine = None
        # current cluster assignment B_t (mobility re-draws it per round)
        self.labels = np.repeat(np.arange(fl.num_clusters),
                                fl.devices_per_cluster)
        self._full_mask = jnp.ones((n,), jnp.float32)
        with_residual = (compression is not None
                         and compression.error_feedback)
        # Algorithm 1 initializes every device from its edge model y_{0,0};
        # we use one shared init (common FL practice), so params are
        # cluster-uniform from the start.
        one = init_fn(jax.random.PRNGKey(seed))
        self._layout = FlatLayout.for_tree(one)
        self.bank: Optional[ModelBank] = None
        self.store = None  # clientstore.ClientStore (streamed mode only)
        self._streamed = bool(streaming)
        # cohort compaction gathers bank rows into a dense (k_pad, T) slab;
        # the sharded engine (core.sharded.ShardedBankCEFedAvg) pins rows
        # to devices and disables it, running mask-frozen full rows instead
        self._compact_enabled = True
        if self._streamed:
            assert bank, "the streaming client store is a bank engine"
            assert compression is None and dp is None, \
                "streamed rounds run plain programs (no upload transforms)"
            assert fl.algorithm != "dec_local_sgd", \
                "dec_local_sgd ties devices to clusters (n == m) — " \
                "no cold rows to stream"
            from repro.core.clientstore import ClientStore
            if self.pop is not None:
                codec = scenario.population.codec
            self.store = ClientStore(
                self._layout, fl.num_clusters,
                np.asarray(self._layout.flatten_one(one), np.float32),
                codec=codec, num_shards=store_shards)
            self._slab_sharding = slab_sharding
            # slab capacity: the cohort cap plus one representative per
            # cluster, bucketed like compaction (power-of-two retrace
            # bound); min_bucket keeps every bucket divisible by the
            # sharded engine's row-shard count
            cap = (self.engine.cohort_cap if self.pop is not None
                   else n + fl.num_clusters)
            cap = -(-max(cap, min_bucket) // min_bucket) * min_bucket
            self._buckets = tuple(
                b for b in cohort_buckets(cap) if b % min_bucket == 0)
            # params of a cold client = its cluster's reference at its
            # LAST sync — track each enumerated device's label as of the
            # previous round's trailing boundary (page-in value source)
            self._page_labels = self.labels.copy()
            self._peak_slab = 0
            self.last_paging = None
            # overlapped driver state (ISSUE 10): device refs, the
            # in-flight page-out, the prefetched next working set
            self._pipe = None
            self._pipe_fns = None
        elif bank:
            self.bank = self._make_bank(one, n, with_residual)
            self._buckets = cohort_buckets(n)
        else:
            self._params = jax.tree.map(
                lambda l: jnp.broadcast_to(l, (n,) + l.shape).copy(), one)
            self._mom = jax.tree.map(jnp.zeros_like, self._params)
            self._residual = (jax.tree.map(jnp.zeros_like, self._params)
                              if with_residual else None)
        self._pipeline = bool(pipeline)
        assert not self._pipeline or self._streamed, \
            "pipeline=True overlaps *paging* with compute — it requires " \
            "the streamed engine (streaming=True or a population scenario)"
        # cumulative host seconds spent paging (staging/fetch/commit/
        # drain); clock.run_wall_clock splits eval windows into
        # page_s/compute_s from deltas of this counter
        self._page_seconds = 0.0
        self.last_bucket = n   # cohort capacity used by the latest round
        # -- round schedule (RoundProgram IR) -------------------------------
        # every engine round is a lowering of a RoundProgram; the static
        # τ/q/π knobs compile to the canonical program once, and a
        # schedule hook may swap in a different program each round
        faulted = (self.engine is not None
                   and self.engine.faults is not None)
        self._canonical = prg.canonical_program(
            fl, privatize=dp is not None, compress=compression is not None,
            faults=faulted)
        if schedule is None:
            self._schedule_fn: Optional[prg.ScheduleFn] = None
        elif isinstance(schedule, str):
            self._schedule_fn = prg.make_schedule(
                schedule, fl, engine=self.engine,
                privatize=dp is not None, compress=compression is not None,
                faults=faulted, sim=self)
        elif isinstance(schedule, prg.RoundProgram):
            def _fixed(r, plan, _program=schedule):
                return _program
            self._schedule_fn = _fixed
        else:
            self._schedule_fn = schedule
        if self.pop is not None:
            assert schedule is None, \
                "round schedules are not supported with a virtual " \
                "population (tau_dev/speed vectors are per enumerated " \
                "device)"
        self.round_index = 0
        self.last_program: Optional[prg.RoundProgram] = None
        self._lowered: Dict = {}       # (engine kind, signature) -> jitted
        self._static_mats: Dict = {}   # (fuse, signature) -> resolved mats
        self._inter_static: Dict = {fl.pi: self.sched.W_inter}
        # depth>2 tiers: static TierMix operators / H_ℓ, cached per level
        self._hier = topo.Hierarchy.from_config(fl)
        self._tier_static: Dict = {}
        self._static_labels = self.labels.copy()
        self.key = jax.random.PRNGKey(seed + 1)
        self._eval_fn = self._build_eval()

    def _make_bank(self, one, n: int, with_residual: bool) -> ModelBank:
        """Bank construction hook: the single-process engine broadcasts
        the shared init on the default device; the sharded engine
        (core/sharded.py) overrides this with per-shard init via
        ``ModelBank.from_model_sharded``."""
        return ModelBank.from_model(one, n, with_residual=with_residual)

    # -- state as pytrees (both engines) ------------------------------------
    @property
    def params(self):
        """Device-stacked model pytree; in bank mode a materialized view
        of the flat (n, T) buffer (fresh arrays, safe across rounds)."""
        if self.bank is not None:
            return self.bank.params_tree()
        if self._streamed:
            raise AttributeError(
                "the streamed engine keeps no resident per-client "
                "params — read sim.store.cluster_params / edge_models()")
        return self._params

    @params.setter
    def params(self, tree):
        if self.bank is not None:
            self.bank.params = self.bank.layout.flatten_stack(tree)
        else:
            self._params = tree

    @property
    def mom(self):
        """Device-stacked momentum pytree (see ``params``)."""
        if self.bank is not None:
            return self.bank.layout.unflatten_stack(self.bank.mom)
        if self._streamed:
            raise AttributeError(
                "the streamed engine keeps no resident momentum — "
                "cold rows live in sim.store")
        return self._mom

    @mom.setter
    def mom(self, tree):
        if self.bank is not None:
            self.bank.mom = self.bank.layout.flatten_stack(tree)
        else:
            self._mom = tree

    @property
    def residual(self):
        """Error-feedback residual pytree, or None when compression with
        error feedback is off."""
        if self.bank is not None:
            if self.bank.residual is None:
                return None
            return self.bank.layout.unflatten_stack(self.bank.residual)
        if self._streamed:
            return None  # streamed rounds reject upload/EF programs
        return self._residual

    @residual.setter
    def residual(self, tree):
        if self.bank is not None:
            self.bank.residual = (
                None if tree is None
                else self.bank.layout.flatten_stack(tree))
        else:
            self._residual = tree

    # -- loss --------------------------------------------------------------
    def _loss(self, p, x, y):
        logits = self.apply_fn(p, x)
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        return jnp.mean(lse - picked)

    # -- program lowering: legacy pytree engine -----------------------------
    def _lower_legacy(self, program: prg.RoundProgram):
        """Compile a RoundProgram to the legacy pytree round (fuse=False:
        one per-leaf ``mix`` contraction per mix op, the paper-literal
        sequential form). Operators/mask are *arguments* so the scenario
        engine can re-draw them between rounds without recompiling:
        masked devices (and, past their ``tau_dev`` cutoff, adaptive
        devices) are frozen via ``where``; the canonical program with a
        full mask reproduces the original fixed-schedule round."""
        n = self.sched.n
        N = self.data["xs"].shape[1]
        grad_fn = jax.grad(self._loss)
        comp, dp = self.compression, self.dp
        plans = prg.lowering_plan(program, fuse=False)
        runs = prg.block_runs(plans)
        nblocks = len(plans)

        def bcast(act, leaf):
            return act.reshape((-1,) + (1,) * (leaf.ndim - 1))

        def make_local_step(op, act, tau_dev):
            lr = self.lr * op.lr_scale

            def local_step(carry, xs_):
                if op.adaptive:
                    key, s = xs_
                    stepact = act & (s < tau_dev)
                else:
                    key, stepact = xs_, act
                params, mom = carry
                idx = jax.random.randint(key, (n, self.batch), 0, N)
                xb = jax.vmap(lambda x, i: x[i])(self.data["xs"], idx)
                yb = jax.vmap(lambda y, i: y[i])(self.data["ys"], idx)
                grads = jax.vmap(grad_fn)(params, xb, yb)
                mom = jax.tree.map(
                    lambda v, g: jnp.where(bcast(stepact, v),
                                           self.momentum * v + g, v),
                    mom, grads)
                params = jax.tree.map(
                    lambda p, v: jnp.where(bcast(stepact, p),
                                           p - lr * v, p),
                    params, mom)
                return (params, mom), None
            return local_step

        def train_block(params, mom, key, op, act, tau_dev):
            local_step = make_local_step(op, act, tau_dev)
            keys = jax.random.split(key, op.tau)
            xs_ = (keys, jnp.arange(op.tau)) if op.adaptive else keys
            (params, mom), _ = jax.lax.scan(local_step, (params, mom), xs_)
            return params, mom

        def upload_transform(delta, residual, key, bp):
            """Device-side: (optional) DP then compression of the delta."""
            if bp.privatize and dp is not None and dp.enabled:
                from repro.core.privacy import privatize_update
                keys = jax.random.split(key, n)
                delta = jax.vmap(
                    lambda d, k: privatize_update(d, dp, k))(
                        delta, keys)
            if bp.compress and comp is not None and comp.kind != "none":
                from repro.core.compress import compress_tree
                keys = jax.random.split(jax.random.fold_in(key, 1), n)
                delta, residual = jax.vmap(
                    lambda d, r, k: compress_tree(comp, d, r, k)
                )(delta, residual, keys)
            return delta, residual

        def run_block(bp, gm, params, mom, residual, k1, act, tau_dev):
            if not bp.upload:
                params, mom = train_block(params, mom, k1, bp.local, act,
                                          tau_dev)
                for W in gm:
                    params = mix(W, params)
                return params, mom, residual
            # devices upload (privatized/compressed) deltas; the edge
            # reconstructs x_start + V·delta (exact when both are off)
            params0 = params
            params, mom = train_block(params, mom, k1, bp.local, act,
                                      tau_dev)
            delta = jax.tree.map(lambda a, b: a - b, params, params0)
            delta, residual = upload_transform(
                delta, residual, jax.random.fold_in(k1, 7), bp)
            params = jax.tree.map(
                lambda p0, d: p0 + d, params0, mix(gm[0], delta))
            for W in gm[1:]:
                params = mix(W, params)
            return params, mom, residual

        @jax.jit
        def global_round(params, mom, residual, key, args, mask):
            act = mask > 0.5
            tau_dev = args.tau_dev
            keys = jax.random.split(key, nblocks)
            mi = ki = 0
            for bp, count in runs:
                gm = args.mats[mi:mi + len(bp.groups)]
                mi += len(bp.groups)
                bkeys = keys[ki:ki + count]
                ki += count
                if count > 1:
                    def body(carry, k1, bp=bp, gm=gm):
                        p, m, r = carry
                        p, m, r = run_block(bp, gm, p, m, r, k1, act,
                                            tau_dev)
                        return (p, m, r), None
                    (params, mom, residual), _ = jax.lax.scan(
                        body, (params, mom, residual), bkeys)
                else:
                    params, mom, residual = run_block(
                        bp, gm, params, mom, residual, bkeys[0], act,
                        tau_dev)
            return params, mom, residual

        return global_round

    # -- program lowering: flat ModelBank engine ----------------------------
    def _flat_helpers(self):
        """Local-step factory shared by the flat rounds; the per-row grad
        closure materializes pytree views only inside the apply call."""
        n = self.sched.n
        N = self.data["xs"].shape[1]
        layout = self._layout

        def loss_row(row, x, y):
            return self._loss(layout.unflatten_one(row), x, y)
        grad_row = jax.grad(loss_row)

        def make_local_step(xs, ys, act2d, gather=None, tau_dev=None,
                            lr_scale=1.0, fold_ids=None):
            """One SGD+momentum step on a (rows, T) slab. ``gather``
            (compaction) maps the full-n batch-index draw onto the slab's
            rows so the cohort sees the same batches as the full path;
            ``fold_ids`` (virtual populations, streamed rounds) instead
            draws each row's batch from the step key folded with its
            client id — O(rows) draws independent of the population
            size, and a client redrawn in a later round with the same
            key would see the same batches regardless of cohort
            composition; ``tau_dev`` (adaptive programs) freezes each
            row past its per-device step cutoff."""
            lr = self.lr * lr_scale

            def local_step(carry, xs_):
                if tau_dev is not None:
                    key, s = xs_
                    act = act2d & (s < tau_dev[:, None])
                else:
                    key, act = xs_, act2d
                Y, M = carry
                if fold_ids is not None:
                    idx = jax.vmap(lambda i: jax.random.randint(
                        jax.random.fold_in(key, i),
                        (self.batch,), 0, N))(fold_ids)
                else:
                    idx = jax.random.randint(key, (n, self.batch), 0, N)
                    if gather is not None:
                        idx = idx[gather]
                xb = jax.vmap(lambda x, i: x[i])(xs, idx)
                yb = jax.vmap(lambda y, i: y[i])(ys, idx)
                G = jax.vmap(grad_row)(Y, xb, yb)
                M = jnp.where(act, self.momentum * M + G, M)
                Y = jnp.where(act, Y - lr * M, Y)
                return (Y, M), None
            return local_step

        return make_local_step

    @staticmethod
    def _train_scan(local_step, Y, M, key, op):
        """τ local steps of one block: scan over the block's step keys
        (plus the step index when the op is adaptive)."""
        keys = jax.random.split(key, op.tau)
        xs_ = (keys, jnp.arange(op.tau)) if op.adaptive else keys
        (Y, M), _ = jax.lax.scan(local_step, (Y, M), xs_)
        return Y, M

    def _lower_flat(self, program: prg.RoundProgram,
                    block_keyed: bool = False):
        """Compile a RoundProgram to the flat global round: all state
        stays (n, T); each MixGroup is one streaming pass
        (``gossip_mix_rows``) of its fused operator — for the canonical
        program the final τ-boundary coincides with the qτ-boundary and
        arrives pre-fused as ``W_inter @ W_intra`` (the delta/upload
        path keeps the first mix separate, where the fold is invalid).
        Identical consecutive blocks compile to ONE ``lax.scan``;
        buffers are donated so peak memory stays ~1× the bank.

        ``block_keyed`` lowers a SINGLE-block program that consumes the
        passed key directly instead of splitting it — the async event
        executor (:meth:`step_round_async`) splits the round key into
        per-block keys on the host (``jax.random.split`` is
        deterministic in or out of jit) and replays one block per
        event, so each device sees exactly the barrier key schedule."""
        n = self.sched.n
        comp, dp = self.compression, self.dp
        xs, ys = self.data["xs"], self.data["ys"]
        make_local_step = self._flat_helpers()
        segments = self._layout.segments
        plans = prg.lowering_plan(program, fuse=True)
        runs = prg.block_runs(plans)
        nblocks = len(plans)
        assert not block_keyed or nblocks == 1, \
            "block_keyed lowers single-block programs"

        def upload(delta, R, key, bp):
            """Flat-domain device uploads: DP then compression, row-wise
            (same per-device/per-leaf key schedule as the pytree path)."""
            if bp.privatize and dp is not None and dp.enabled:
                from repro.core.privacy import privatize_update_flat
                keys = jax.random.split(key, n)
                delta = jax.vmap(
                    lambda d, k: privatize_update_flat(d, dp, k))(
                        delta, keys)
            if bp.compress and comp is not None and comp.kind != "none":
                from repro.core.compress import compress_flat
                keys = jax.random.split(jax.random.fold_in(key, 1), n)
                delta, R = jax.vmap(
                    lambda d, r, k: compress_flat(comp, d, r, k, segments)
                )(delta, R, keys)
            return delta, R

        def run_block(bp, gm, Y, M, R, k1, act2d, tau_dev):
            op = bp.local
            local_step = make_local_step(
                xs, ys, act2d, tau_dev=tau_dev if op.adaptive else None,
                lr_scale=op.lr_scale)
            if not bp.upload:
                Y, M = self._train_scan(local_step, Y, M, k1, op)
                for W in gm:
                    Y = gossip_mix_rows(W, Y)
                return Y, M, R
            Y0 = Y
            Y, M = self._train_scan(local_step, Y, M, k1, op)
            delta = Y - Y0
            delta, R = upload(delta, R, jax.random.fold_in(k1, 7), bp)
            Y = Y0 + gossip_mix_rows(gm[0], delta)
            for W in gm[1:]:
                Y = gossip_mix_rows(W, Y)
            return Y, M, R

        @functools.partial(jax.jit, donate_argnums=(0, 1, 2))
        def global_round(Y, M, R, key, args, mask):
            act2d = (mask > 0.5)[:, None]
            tau_dev = args.tau_dev
            keys = (key[None] if block_keyed
                    else jax.random.split(key, nblocks))
            mi = ki = 0
            for bp, count in runs:
                gm = args.mats[mi:mi + len(bp.groups)]
                mi += len(bp.groups)
                bkeys = keys[ki:ki + count]
                ki += count
                if count > 1:
                    def body(carry, k1, bp=bp, gm=gm):
                        Y, M, R = carry
                        Y, M, R = run_block(bp, gm, Y, M, R, k1, act2d,
                                            tau_dev)
                        return (Y, M, R), None
                    (Y, M, R), _ = jax.lax.scan(body, (Y, M, R), bkeys)
                else:
                    Y, M, R = run_block(bp, gm, Y, M, R, bkeys[0], act2d,
                                        tau_dev)
            return Y, M, R

        return global_round

    def _lower_compact(self, program: prg.RoundProgram):
        """Compile a RoundProgram to the compacted scenario round:
        gradient/momentum work runs on a dense (k_pad, T) gather of the
        participating rows (``idx`` holds distinct rows — cohort first,
        inert padding after — so the scatter back is deterministic);
        mixing boundaries still stream the full bank, since masked
        operators move every device's row. Traced once per cohort bucket
        (static shapes under jit). Upload programs never dispatch here."""
        xs, ys = self.data["xs"], self.data["ys"]
        make_local_step = self._flat_helpers()
        plans = prg.lowering_plan(program, fuse=True)
        runs = prg.block_runs(plans)
        nblocks = len(plans)
        assert not program.has_upload, \
            "compacted rounds are for plain programs only"

        @functools.partial(jax.jit, donate_argnums=(0, 1))
        def compact_round(Y, M, key, idx, lane, args):
            lane2d = lane[:, None]
            xs_c, ys_c = xs[idx], ys[idx]
            tau_c = (None if args.tau_dev is None else args.tau_dev[idx])

            def train_edge(carry, k1, op):
                Y, M = carry
                P, Mc = Y[idx], M[idx]
                local_step = make_local_step(
                    xs_c, ys_c, lane2d, gather=idx,
                    tau_dev=tau_c if op.adaptive else None,
                    lr_scale=op.lr_scale)
                P, Mc = self._train_scan(local_step, P, Mc, k1, op)
                return Y.at[idx].set(P), M.at[idx].set(Mc)

            keys = jax.random.split(key, nblocks)
            mi = ki = 0
            for bp, count in runs:
                gm = args.mats[mi:mi + len(bp.groups)]
                mi += len(bp.groups)
                bkeys = keys[ki:ki + count]
                ki += count

                def one(carry, k1, bp=bp, gm=gm):
                    Y, M = train_edge(carry, k1, bp.local)
                    for W in gm:
                        Y = gossip_mix_rows(W, Y)
                    return Y, M
                if count > 1:
                    def body(carry, k1, one=one):
                        return one(carry, k1), None
                    (Y, M), _ = jax.lax.scan(body, (Y, M), bkeys)
                else:
                    Y, M = one((Y, M), bkeys[0])
            return Y, M

        return compact_round

    def _lower_streamed(self, program: prg.RoundProgram,
                        per_client: bool = False):
        """Compile a RoundProgram to the streamed working-set round
        (ISSUE 9): ALL state is the hot (S, T) slab — the paged-in
        cohort plus one cold representative lane per cluster — and the
        mixing operators arrive already restricted to the working set
        (exact, because every masked operator row reads participant
        columns only and is a function of the row's cluster label).
        ``didx`` maps each lane to its data shard, ``cids`` carries the
        lane's virtual client id, ``lane`` marks the trainers (cold
        representative/padding lanes are ``where``-frozen and only
        mixed). ``per_client`` switches the batch draw from the
        enumerated-n gather (bitwise parity with the compacted resident
        round) to the fold_in(client id) schedule of virtual
        populations. Traced once per slab bucket."""
        xs = jnp.asarray(self.data["xs"])
        ys = jnp.asarray(self.data["ys"])
        make_local_step = self._flat_helpers()
        plans = prg.lowering_plan(program, fuse=True)
        runs = prg.block_runs(plans)
        nblocks = len(plans)
        assert not program.has_upload, \
            "streamed rounds are for plain programs only"
        assert plans[-1].groups, \
            "streamed rounds need a trailing mixing boundary (page-out " \
            "reads cluster-synced rows back as the references)"

        @functools.partial(jax.jit, donate_argnums=(0, 1))
        def streamed_round(Y, M, key, didx, cids, lane, args):
            lane2d = lane[:, None]
            xs_c, ys_c = xs[didx], ys[didx]
            tau_c = (None if args.tau_dev is None else args.tau_dev[didx])

            def train_slab(carry, k1, op):
                Y, M = carry
                local_step = make_local_step(
                    xs_c, ys_c, lane2d,
                    gather=None if per_client else didx,
                    fold_ids=cids if per_client else None,
                    tau_dev=tau_c if op.adaptive else None,
                    lr_scale=op.lr_scale)
                return self._train_scan(local_step, Y, M, k1, op)

            keys = jax.random.split(key, nblocks)
            mi = ki = 0
            for bp, count in runs:
                gm = args.mats[mi:mi + len(bp.groups)]
                mi += len(bp.groups)
                bkeys = keys[ki:ki + count]
                ki += count

                def one(carry, k1, bp=bp, gm=gm):
                    Y, M = train_slab(carry, k1, bp.local)
                    for W in gm:
                        Y = gossip_mix_rows(W, Y)
                    return Y, M
                if count > 1:
                    def body(carry, k1, one=one):
                        return one(carry, k1), None
                    (Y, M), _ = jax.lax.scan(body, (Y, M), bkeys)
                else:
                    Y, M = one((Y, M), bkeys[0])
            return Y, M

        return streamed_round

    # -- per-round program machinery ----------------------------------------
    def _get_round(self, kind: str, program: prg.RoundProgram):
        """The jitted lowering of ``program`` for one engine, compiled
        once per program *structure* (``program.signature``)."""
        key = (kind, program.signature)
        fn = self._lowered.get(key)
        if fn is None:
            lower = {"legacy": self._lower_legacy,
                     "flat": self._lower_flat,
                     "flat_block": functools.partial(self._lower_flat,
                                                     block_keyed=True),
                     "compact": self._lower_compact,
                     "streamed": self._lower_streamed,
                     "streamed_pop": functools.partial(
                         self._lower_streamed, per_client=True)}[kind]
            fn = lower(program)
            self._lowered[key] = fn
        return fn

    @property
    def _round(self):
        """Canonical-program legacy round (kept for tests/debugging)."""
        return self._get_round("legacy", self._canonical)

    @property
    def _round_flat(self):
        """Canonical-program flat round (kept for tests/debugging)."""
        return self._get_round("flat", self._canonical)

    @property
    def _round_compact(self):
        """Canonical-program compacted round (kept for tests)."""
        return self._get_round("compact", self._canonical)

    def _scenario_h(self, plan=None):
        if plan is not None and plan.H_eff is not None:
            return plan.H_eff  # link-loss-degraded backhaul (FaultModel)
        return self.engine.H if self.engine is not None else self.sched.H

    def _inter_operator(self, pi: int, plan, renorm: bool) -> np.ndarray:
        """The (n, n) inter-cluster operator at gossip depth ``pi`` for
        this round — the static schedule's W_inter when possible, else
        the (masked) time-varying eq. 11 form at the requested depth,
        built over the plan's surviving backhaul under link faults."""
        from repro.core.scenario import make_masked_w
        if plan is None:
            W = self._inter_static.get(pi)
            if W is None:
                W = make_masked_w(self.fl, self._static_labels,
                                  np.ones(self.sched.n), self.sched.H,
                                  pi=pi)[1]
                self._inter_static[pi] = W
            return W
        if renorm:
            if pi == self.fl.pi:
                return plan.W_inter
            return make_masked_w(self.fl, plan.labels, plan.mask,
                                 self._scenario_h(plan), pi=pi)[1]
        return make_masked_w(self.fl, plan.labels,
                             np.ones(plan.labels.shape[0]),
                             self._scenario_h(plan), pi=pi)[1]

    def _tier_operator(self, op: prg.TierMix, plan, renorm: bool):
        """The (n, n) dense operator of any ``TierMix`` this round.

        Levels 0/1 delegate to the existing intra/inter resolvers (the
        paper's two tiers, including the masked scenario forms). Deeper
        tiers build B_ℓ^T diag(c) H_ℓ^π B_ℓ from the hierarchy: static
        rounds cache the contiguous-assignment operator per (level, pi);
        scenario rounds recompose it from the plan's device→edge labels
        lifted to tier-ℓ nodes (mobility composes, participation masks
        renormalize)."""
        hier = self._hier
        if not (0 <= op.level < hier.depth):
            raise ValueError(
                f"TierMix level {op.level} outside hierarchy of depth "
                f"{hier.depth} (tiers {hier.levels})")
        if op.level == 0:
            if plan is None:
                return self.sched.W_intra
            if renorm:
                return plan.W_intra
            from repro.core.scenario import make_masked_w
            return make_masked_w(self.fl, plan.labels,
                                 np.ones(plan.labels.shape[0]),
                                 self._scenario_h(plan))[0]
        if op.level == 1:
            return self._inter_operator(op.pi, plan, renorm)
        ck = ("H", op.level)
        H_l = self._tier_static.get(ck)
        if H_l is None:
            H_l = hier.mixing(op.level, self.fl.topology, self.fl.mixing,
                              self.fl)
            self._tier_static[ck] = H_l
        if plan is None:
            key = (op.level, op.pi)
            W = self._tier_static.get(key)
            if W is None:
                W = hier.tier_operator(op.level, op.pi, self.fl.topology,
                                       self.fl.mixing, self.fl)
                self._tier_static[key] = W
            return W
        B = topo.assignment_matrix(
            hier.node_labels(op.level, plan.labels),
            hier.num_nodes(op.level))
        return topo.masked_inter_operator(
            B, H_l, op.pi, plan.mask if renorm else None)

    def _fault_gate(self, program: prg.RoundProgram, plan):
        """Per-op operator gate for the plan's realized faults: under a
        ``FaultGate`` directive with dark clusters, every resolved
        operator gets :func:`repro.core.gossip.fault_gate` applied
        *before* any fusion — gate(A)·gate(B) is what both the fused
        and unfused lowerings execute, keeping engine parity under
        faults. Identity otherwise."""
        if (program.fault_gate and plan is not None
                and plan.fault is not None
                and plan.fault.cluster_down.any()):
            from repro.core import gossip as gsp
            down = plan.fault.cluster_down
            labels = plan.labels
            return lambda W: gsp.fault_gate(W, labels, down)
        return lambda W: W

    def _resolve_args(self, program: prg.RoundProgram, plan,
                      fuse: bool) -> prg.RoundArgs:
        """Concrete runtime operands (mixing matrices + adaptive step
        cutoffs) for one round of ``program`` under ``plan``. Static
        rounds cache their matrices per program structure."""
        plans = prg.lowering_plan(program, fuse=fuse)
        renorm = program.mask_renorm
        if plan is None:
            ck = (fuse, program.signature)
            mats = self._static_mats.get(ck)
            if mats is None:
                mats = tuple(jnp.asarray(m) for m in prg.resolve_matrices(
                    plans, self.sched.W_intra,
                    lambda pi: self._inter_operator(pi, None, renorm),
                    tier_of=lambda op: self._tier_operator(
                        op, None, renorm)))
                self._static_mats[ck] = mats
        else:
            gate = self._fault_gate(program, plan)
            if renorm:
                W_intra = plan.W_intra
            else:
                from repro.core.scenario import make_masked_w
                W_intra = make_masked_w(self.fl, plan.labels,
                                        np.ones(plan.labels.shape[0]),
                                        self._scenario_h(plan))[0]
            mats = tuple(jnp.asarray(m) for m in prg.resolve_matrices(
                plans, gate(W_intra),
                lambda pi: gate(self._inter_operator(pi, plan, renorm)),
                tier_of=lambda op: gate(
                    self._tier_operator(op, plan, renorm))))
        tau_dev = (jnp.asarray(program.tau_dev, jnp.int32)
                   if program.adaptive else None)
        return prg.RoundArgs(mats, tau_dev)

    # -- driver -------------------------------------------------------------
    def step_round(self):
        """Advance ONE global round.

        With a scenario attached, first realizes this round's plan
        (mobility re-draws B_t, sampling draws the cohort); the schedule
        hook (or the canonical program) then decides this round's
        :class:`repro.core.program.RoundProgram`, whose resolved
        operators feed the program's lowered round for the active
        engine. In bank mode a partial cohort of a plain program
        dispatches to the compacted lowering (``last_bucket`` records
        the capacity used). Returns the ``RoundPlan`` (or None without a
        scenario); ``last_program`` records the executed program so
        callers — e.g. the wall-clock harness in core/clock.py — can
        charge the cohort per op."""
        if self._streamed:
            if self._pipeline:
                return self._step_round_streamed_pipelined()
            return self._step_round_streamed()
        if self.engine is not None:
            plan = self.engine.step()
            self.labels = plan.labels
            mask_np = plan.mask
        else:
            plan = None
            mask_np = None
        r = self.round_index
        self.round_index += 1
        program = (self._schedule_fn(r, plan)
                   if self._schedule_fn is not None else self._canonical)
        self.last_program = program
        mask = (jnp.asarray(mask_np, jnp.float32)
                if mask_np is not None else self._full_mask)
        self.key, k = jax.random.split(self.key)
        if self.bank is None:
            args = self._resolve_args(program, plan, fuse=False)
            fn = self._get_round("legacy", program)
            self._params, self._mom, self._residual = fn(
                self._params, self._mom, self._residual, k, args, mask)
            return plan
        b = self.bank
        args = self._resolve_args(program, plan, fuse=True)
        k_active = b.n if mask_np is None else int(mask_np.sum())
        # 0 < k_active: a fully-dark fault round (empty cohort) cannot
        # compact — it runs the flat path, where the zero mask freezes
        # training and the fault-gated operators are the identity
        if (not program.has_upload and 0 < k_active < b.n
                and self._compact_enabled):
            cp = compact_plan(mask_np, self._buckets)
            self.last_bucket = cp.k_pad
            fn = self._get_round("compact", program)
            b.params, b.mom = fn(b.params, b.mom, k, jnp.asarray(cp.idx),
                                 jnp.asarray(cp.lane), args)
            return plan
        self.last_bucket = b.n
        fn = self._get_round("flat", program)
        b.params, b.mom, b.residual = fn(b.params, b.mom, b.residual, k,
                                         args, mask)
        return plan

    def _step_round_streamed(self):
        """One streamed global round: page in the working set, run the
        slab-restricted program, page out (ISSUE 9).

        The working set is the round's cohort plus one cold
        representative lane per cluster; its params page in from the
        store's per-cluster references (each lane reads the reference
        of its cluster *at its last sync* — tracked by ``_page_labels``
        at enumerated n, the current attachment with a virtual
        population, where attaching IS downloading the edge's model),
        its momentum from the cold rows (zeros on first touch). After
        the round the trailing cluster-level boundary has synced every
        lane of a cluster to one value, so page-out reads one lane per
        cluster back as its reference (skipping fault-dark clusters,
        whose gated rows never mixed) and re-encodes the cohort's
        momentum. Known, documented approximations vs the resident
        engine: a cluster left without any working-set lane (possible
        only under visit mobility + full sampling) keeps a stale
        reference for the round."""
        from repro.core.modelbank import ModelBank as MB
        st = self.store
        m = self.fl.num_clusters
        if self.engine is not None:
            plan = self.engine.step()
        else:
            plan = None
        r = self.round_index
        self.round_index += 1
        program = (self._schedule_fn(r, plan)
                   if self._schedule_fn is not None else self._canonical)
        self.last_program = program
        assert not program.has_upload, \
            "streamed rounds reject upload programs (EF residual and " \
            "DP noise are per-device state the store does not page)"
        assert program.mask_renorm, \
            "streamed rounds need mask-renormalized operators — " \
            "unrenormalized rows weight absent cold members"
        ws = self._working_set(plan)
        if self.pop is None:
            self.labels = ws["labels_now"]
        k, S = ws["k"], ws["S"]
        clients, ws_labels = ws["clients"], ws["ws_labels"]
        H_t = self._scenario_h(plan)
        from repro.core.scenario import RoundPlan, make_masked_w
        W_i, W_e = make_masked_w(self.fl, ws_labels, ws["mask_slab"], H_t)
        splan = RoundPlan(r, m, ws_labels, ws["mask_slab"], W_i, W_e,
                          fault=ws["fault"], H_eff=ws["h_eff"])
        args = self._resolve_args(program, splan, fuse=True)
        # page-in: params from each lane's last-sync cluster reference,
        # momentum decoded for the trainers only (cold lanes never step)
        t0 = time.perf_counter()
        params_rows = st.cluster_params[ws["src_labels"]]
        mom_rows = np.zeros((S, self._layout.total), np.float32)
        if k:
            mom_rows[:k] = st.fetch(clients[:k])
        slab = MB.from_rows(self._layout, params_rows, mom_rows,
                            sharding=self._slab_sharding)
        self._page_seconds += time.perf_counter() - t0
        self.key, k_ = jax.random.split(self.key)
        fn = self._get_round(
            "streamed_pop" if self.pop is not None else "streamed",
            program)
        Y, M = fn(slab.params, slab.mom, k_,
                  jnp.asarray(ws["didx"], jnp.int32),
                  jnp.asarray(clients, jnp.int32),
                  jnp.asarray(ws["lane"]), args)
        jax.block_until_ready((Y, M))
        t0 = time.perf_counter()
        Yh = np.asarray(jax.device_get(Y), np.float32)
        Mh = np.asarray(jax.device_get(M), np.float32)
        # page-out: last lane of each cluster (representatives win over
        # participants by position) carries the synced reference
        fault = ws["fault"]
        ref_lane = np.full(m, -1, np.int64)
        ref_lane[ws_labels] = np.arange(S)
        down = (fault.cluster_down if fault is not None else None)
        refs = st.cluster_params.copy()
        for c in range(m):
            j = int(ref_lane[c])
            if j < 0 or (down is not None and down[c]):
                continue  # no working-set lane / dark cluster: stale ref
            refs[c] = Yh[j]
        st.update_clusters(refs)
        if k:
            st.commit(clients[:k], Mh[:k])
        self._page_seconds += time.perf_counter() - t0
        if self.pop is None:
            # next round's page-in reads the reference of the cluster a
            # device sat in NOW: the trailing boundary synced every row
            self._page_labels = self.labels.copy()
        self.last_bucket = S
        self._peak_slab = max(self._peak_slab,
                              2 * 4 * S * self._layout.total)
        # paging = device↔edge traffic: each trainer downloads its row
        # and uploads it back (references live at the edge already)
        self.last_paging = {"rows_in": k, "rows_out": k,
                            "bits_per_row": st.bits_per_row}
        return plan

    def _working_set(self, plan):
        """Assemble one streamed round's working set from its plan —
        shared verbatim by the serial and pipelined drivers (identical
        assembly is half of their bit-identity). Pure w.r.t. engine and
        store state; reads ``self._page_labels`` (enumerated mode), so
        the pipelined prefetch must call it *after* the previous round
        updated the labels."""
        m = self.fl.num_clusters
        if self.pop is not None:
            # virtual population: cohort ids from the keyed engine, one
            # cold representative per (not fully sampled) cluster; a
            # lane's data shard is its id mod the enumerated shard count
            cohort = np.asarray(plan.clients, np.int64)
            reps = self.engine.representatives(cohort)
            clients = np.concatenate([cohort, reps])
            ws_labels = np.concatenate(
                [np.asarray(plan.labels, np.int64),
                 self.engine.home_cluster(reps)])
            src_labels = ws_labels
            didx = clients % self.data["xs"].shape[0]
            labels_now, h_eff = None, None
        else:
            # enumerated n: the scenario plan's cohort (or everyone)
            if plan is not None:
                labels_now = np.asarray(plan.labels, np.int64)
                mask_np = np.asarray(plan.mask)
                h_eff = plan.H_eff
            else:
                labels_now = self.labels
                mask_np = np.ones(self.sched.n)
                h_eff = None
            cold = mask_np <= 0
            cohort = np.nonzero(~cold)[0].astype(np.int64)
            reps = np.asarray(
                [np.nonzero(cold & (labels_now == c))[0][0]
                 for c in range(m)
                 if (cold & (labels_now == c)).any()], np.int64)
            clients = np.concatenate([cohort, reps])
            ws_labels = labels_now[clients]
            src_labels = self._page_labels[clients]
            didx = clients
        k = int(cohort.shape[0])
        S_raw = int(clients.shape[0])
        S = bucket_for(S_raw, self._buckets)
        pad = S - S_raw
        if pad:
            # padding duplicates lane 0 wholesale (client id, labels,
            # data shard) with lane=False: a frozen extra cold member of
            # lane 0's cluster, whose post-round row is that cluster's
            # synced value — safe even as a page-out read
            clients = np.concatenate([clients, np.repeat(clients[:1], pad)])
            ws_labels = np.concatenate(
                [ws_labels, np.repeat(ws_labels[:1], pad)])
            src_labels = np.concatenate(
                [src_labels, np.repeat(src_labels[:1], pad)])
            didx = np.concatenate([didx, np.repeat(didx[:1], pad)])
        lane = np.zeros(S, bool)
        lane[:k] = True
        return {"cohort": cohort, "clients": clients,
                "ws_labels": ws_labels, "src_labels": src_labels,
                "didx": didx, "k": k, "S": S, "lane": lane,
                "mask_slab": lane.astype(float),
                "labels_now": labels_now, "h_eff": h_eff,
                "fault": getattr(plan, "fault", None)}

    # -- overlapped streamed driver (ISSUE 10) -------------------------------
    def _peek_plan(self):
        """Compute the NEXT round's plan without advancing the engine.

        Sound because every engine draw is keyed by (seed, round,
        stream, entity) — ``step()`` only *reassigns* ``round_index`` /
        ``labels`` / ``speed_multipliers`` (and FaultModel is stateless)
        — so saving those references, stepping, and restoring them
        leaves the engine bit-identical while yielding the plan the
        real ``step()`` will reproduce next round (asserted there)."""
        eng = self.engine
        if eng is None:
            return None
        saved = [(a, getattr(eng, a))
                 for a in ("round_index", "labels", "speed_multipliers")
                 if hasattr(eng, a)]
        try:
            plan = eng.step()
        finally:
            for a, v in saved:
                setattr(eng, a, v)
        return plan

    @staticmethod
    def _plans_match(a, b) -> bool:
        """Prefetch-invariant check: the peeked plan equals the real one
        (keyed draws make this structural; a mismatch means engine state
        was perturbed between rounds)."""
        if a is None or b is None:
            return a is b
        for f in ("clients", "labels", "mask"):
            va, vb = getattr(a, f, None), getattr(b, f, None)
            if (va is None) != (vb is None):
                return False
            if va is not None and not np.array_equal(np.asarray(va),
                                                     np.asarray(vb)):
                return False
        return True

    def _make_pipe_helpers(self):
        """The pipelined round's pre/post jits. The CORE round stays the
        serial driver's own compiled lowering (``_get_round``) — f32
        bit-identity holds by construction because the same executable
        sees the same input bits. pre/post only gather, scatter and run
        the cold codec, all bit-exact at f32:

        - pre: page-in on device — params from the resident cluster
          references, momentum decoded from the staged encoded rows
          after scattering in forwarded rows (clients sampled in
          consecutive rounds, whose newest momentum exists only as the
          previous round's device-side page-out);
        - post: page-out on device — fold each updated cluster's synced
          lane into the references, encode the slab's momentum so the
          D2H transfer carries codec-width bytes."""
        from repro.kernels import cold_codec
        codec, segs = self.store.codec, self._layout.segments
        shard = self._slab_sharding

        # q_in/s_in are staged fresh every round and consumed only
        # here: donating them makes the forwarding scatter in-place
        # (CPU ignores donation and warns, so only donate off-CPU)
        donate = (() if jax.default_backend() == "cpu" else (2, 3))

        @functools.partial(jax.jit, donate_argnums=donate)
        def pre(refs, src_labels, q_in, s_in, q_prev, s_prev, src, dst):
            q = q_in.at[dst].set(q_prev[src], mode="drop")
            s = s_in.at[dst].set(s_prev[src], mode="drop")
            Y0 = refs[src_labels]
            M0 = cold_codec.decode_rows(q, s, codec, segs)
            if shard is not None:
                Y0 = jax.lax.with_sharding_constraint(Y0, shard)
                M0 = jax.lax.with_sharding_constraint(M0, shard)
            return Y0, M0

        # refs must NOT be donated: the previous round's pending
        # page-out still holds this buffer until the next drain
        @jax.jit
        def post(Y, M, refs, upd, lanes):
            refs_new = jnp.where(upd[:, None], Y[lanes], refs)
            q_out, s_out = cold_codec.encode_rows(M, codec, segs)
            return refs_new, q_out, s_out

        return pre, post

    def _stage_pipelined(self, plan, r: int):
        """Stage round ``r``'s page-in: assemble its working set, gather
        the cohort's *encoded* cold rows (commits ≤ r-2 from the store;
        the r-1 delta arrives by device-side forwarding at dispatch) and
        start their H2D transfer — all while round r-1 computes."""
        ws = self._working_set(plan)
        k, S = ws["k"], ws["S"]
        qc, sc = self.store.fetch_encoded(ws["cohort"])
        # rep/pad lanes page in zero momentum, exactly like the serial
        # driver's zero-fill beyond [:k] (zero q + zero scale decode to
        # exact zeros under every codec). The host buffers are cached
        # per bucket — device_put/asarray below copies them out, so the
        # next stage may safely overwrite; only [k:] needs re-zeroing.
        bufs = getattr(self, "_stage_bufs", None)
        if bufs is None:
            bufs = self._stage_bufs = {}
        if S not in bufs:
            bufs[S] = (np.zeros((S, self._layout.total), qc.dtype),
                       np.zeros((S, sc.shape[1]), np.float32))
        q, s = bufs[S]
        q[:k] = qc
        q[k:] = 0
        s[:k] = sc
        s[k:] = 0
        if self._slab_sharding is not None:
            ws["q"] = jax.device_put(q, self._slab_sharding)
            ws["s"] = jax.device_put(s, self._slab_sharding)
        else:
            ws["q"], ws["s"] = jnp.asarray(q), jnp.asarray(s)
        ws["plan"], ws["r"] = plan, r
        return ws

    def _drain_pipeline(self):
        """Land the in-flight page-out (if any) in the host store:
        blocks on the async D2H of the last dispatched round, then
        commits its encoded momentum and mirrors the cluster references.
        Called by the next round (overlapped by that round's compute)
        and by every store reader — eval, checkpoint capture — so
        observable host state is always round-complete."""
        p = getattr(self, "_pipe", None)
        if not p or p.get("pending") is None:
            return
        pend, p["pending"] = p["pending"], None
        st = self.store
        st.update_clusters(np.asarray(pend["refs"], np.float32))
        k = pend["k"]
        if k:
            st.commit_encoded(pend["cohort"],
                              np.asarray(pend["q"])[:k],
                              np.asarray(pend["s"], np.float32)[:k])

    def _step_round_streamed_pipelined(self):
        """One overlapped streamed round (ISSUE 10 tentpole).

        Vs the serial driver, per dispatched round t the host only (a)
        drains round t-1's encoded page-out and (b) stages round t+1's
        page-in from the peeked plan — both overlapped by round t's
        device compute, so steady-state round time approaches
        max(compute, page) instead of compute + page. The cluster
        references live on device across rounds (params never ride the
        link per round; only the (m, T) mirror comes back), and the
        momentum link traffic is codec-width both ways.

        Delayed-commit bookkeeping: when round t+1 is staged, the store
        holds commits ≤ t-1 (t is still in flight), so clients sampled
        in both rounds t and t+1 get their newest momentum forwarded
        on device from round t's encoded page-out — covering exactly
        the missing delta. The store itself is only read by staging,
        never by the round, so eval/checkpoint drains stay cheap."""
        from repro.core.scenario import RoundPlan, make_masked_w
        st = self.store
        m = self.fl.num_clusters
        if self._pipe is None:
            self._pipe = {"refs": None, "pending": None,
                          "staged": None, "prev": None}
        if self._pipe_fns is None:
            self._pipe_fns = self._make_pipe_helpers()
        p = self._pipe
        if p["refs"] is None:
            p["refs"] = jnp.asarray(st.cluster_params, jnp.float32)
        pre_fn, post_fn = self._pipe_fns
        plan = self.engine.step() if self.engine is not None else None
        r = self.round_index
        self.round_index += 1
        program = (self._schedule_fn(r, plan)
                   if self._schedule_fn is not None else self._canonical)
        self.last_program = program
        assert not program.has_upload, \
            "streamed rounds reject upload programs (EF residual and " \
            "DP noise are per-device state the store does not page)"
        assert program.mask_renorm, \
            "streamed rounds need mask-renormalized operators — " \
            "unrenormalized rows weight absent cold members"
        staged, p["staged"] = p["staged"], None
        if staged is not None:
            assert staged["r"] == r and \
                self._plans_match(staged["plan"], plan), \
                "prefetched plan diverged from the engine's real draw " \
                "(engine state was perturbed between rounds)"
            ws = staged
        else:
            # cold start (first round / right after restore): stage now
            t0 = time.perf_counter()
            ws = self._stage_pipelined(plan, r)
            self._page_seconds += time.perf_counter() - t0
        if self.pop is None:
            self.labels = ws["labels_now"]
            self._page_labels = ws["labels_now"].copy()
        k, S = ws["k"], ws["S"]
        H_t = self._scenario_h(plan)
        W_i, W_e = make_masked_w(self.fl, ws["ws_labels"],
                                 ws["mask_slab"], H_t)
        splan = RoundPlan(r, m, ws["ws_labels"], ws["mask_slab"], W_i,
                          W_e, fault=ws["fault"], H_eff=ws["h_eff"])
        args = self._resolve_args(program, splan, fuse=True)
        # device-side forwarding: rows of the previous cohort sampled
        # again now (their commit is still in flight); padded to a
        # static length, OOB dst entries drop
        src = np.zeros(S, np.int64)
        dst = np.full(S, S, np.int64)
        prev = p["prev"]
        if prev is not None:
            _, si, di = np.intersect1d(prev["cohort"], ws["cohort"],
                                       assume_unique=True,
                                       return_indices=True)
            src[:si.shape[0]] = si
            dst[:di.shape[0]] = di
            q_prev, s_prev = prev["q"], prev["s"]
        else:
            q_prev = jnp.zeros((1,) + ws["q"].shape[1:], ws["q"].dtype)
            s_prev = jnp.zeros((1,) + ws["s"].shape[1:], jnp.float32)
        Y0, M0 = pre_fn(p["refs"],
                        jnp.asarray(ws["src_labels"], jnp.int32),
                        ws["q"], ws["s"], q_prev, s_prev,
                        jnp.asarray(src, jnp.int32),
                        jnp.asarray(dst, jnp.int32))
        self.key, k_ = jax.random.split(self.key)
        fn = self._get_round(
            "streamed_pop" if self.pop is not None else "streamed",
            program)
        Y, M = fn(Y0, M0, k_,
                  jnp.asarray(ws["didx"], jnp.int32),
                  jnp.asarray(ws["clients"], jnp.int32),
                  jnp.asarray(ws["lane"]), args)
        # page-out on device; D2H starts now, lands at the next drain
        fault = ws["fault"]
        ref_lane = np.full(m, -1, np.int64)
        ref_lane[ws["ws_labels"]] = np.arange(S)
        down = (np.asarray(fault.cluster_down, bool)
                if fault is not None else np.zeros(m, bool))
        upd = (ref_lane >= 0) & ~down
        lanes = np.where(ref_lane >= 0, ref_lane, 0)
        refs_new, q_out, s_out = post_fn(Y, M, p["refs"],
                                         jnp.asarray(upd),
                                         jnp.asarray(lanes, jnp.int32))
        p["refs"] = refs_new
        for a in (q_out, s_out, refs_new):
            a.copy_to_host_async()
        # drain round r-1 (its D2H overlapped round r's dispatch) and
        # only then stage r+1, so staging sees commits ≤ r-1 and the
        # forwarding delta is exactly cohort r
        t0 = time.perf_counter()
        self._drain_pipeline()
        p["pending"] = {"cohort": ws["cohort"], "k": k,
                        "q": q_out, "s": s_out, "refs": refs_new}
        p["prev"] = {"cohort": ws["cohort"], "q": q_out, "s": s_out}
        p["staged"] = self._stage_pipelined(self._peek_plan(), r + 1)
        self._page_seconds += time.perf_counter() - t0
        self.last_bucket = S
        self._peak_slab = max(self._peak_slab,
                              2 * 4 * S * self._layout.total)
        self.last_paging = {"rows_in": k, "rows_out": k,
                            "bits_per_row": st.bits_per_row}
        return plan

    @property
    def peak_slab_bytes(self) -> int:
        """Largest hot slab (params + momentum) any streamed round
        materialized — the O(cohort) resident bound the scale bench
        guards; 0 before the first round / for resident engines."""
        return int(getattr(self, "_peak_slab", 0))

    def step_round_async(self, staleness: int, rt, *,
                         uplink_ratio: float = 1.0):
        """Advance ONE global round in async bounded-staleness mode.

        Instead of one barrier round, the round's blocks execute as a
        per-cluster *event sequence*:
        :func:`repro.core.clock.async_program_timeline` schedules when
        each cluster clears each block under the wait rule (own previous
        block done AND every dependency neighbor within ``staleness``
        blocks), and each event replays that block for its advancing
        clusters only — local steps masked to their devices, the block's
        fused mixing operator gated by
        :func:`repro.core.gossip.staleness_mask` so a boundary never
        reads a model more than ``staleness`` blocks away. At
        ``staleness == 0`` every event advances all clusters in lockstep
        with the unmodified operator and the barrier key schedule,
        reproducing ``step_round``'s flat path (the parity anchor
        ``tests/test_async.py`` fuzzes).

        ``rt`` is the :class:`repro.core.runtime.RuntimeModel` whose
        compute/comm pricing orders the events (the model state only
        depends on the event *order*, not the absolute times). Only
        plain programs are supported — upload blocks carry
        error-feedback residual state that is not staleness-safe — and
        only the bank engines. Returns the round's ``RoundPlan`` (or
        None without a scenario) and records ``last_async`` with the
        timeline, the staleness bound, the cumulative per-cluster phase
        vector, and a per-event trace (pre-advance phases + realized
        cross-cluster gossip edges of the masked operator)."""
        assert not self._streamed, \
            "async bounded-staleness execution needs resident rows " \
            "(blocks replay against the full bank, not a paged slab)"
        assert self.bank is not None, \
            "async bounded-staleness execution requires a bank engine"
        from repro.core import clock as clk
        from repro.core import gossip as gsp
        if self.engine is not None:
            plan = self.engine.step()
            self.labels = plan.labels
            mask_np = plan.mask
        else:
            plan = None
            mask_np = None
        r = self.round_index
        self.round_index += 1
        program = (self._schedule_fn(r, plan)
                   if self._schedule_fn is not None else self._canonical)
        assert not program.has_upload, \
            "async mode supports plain programs only (no upload/EF state)"
        self.last_program = program
        m = self.fl.num_clusters
        mult = (None if self.engine is None
                else np.asarray(self.engine.speed_multipliers, float))
        fleet = None if mult is None else mult * rt.hw.device_flops
        # per-cluster timeline carried across rounds — same evolution as
        # EventClock.charge_program_async's, so the executor's event
        # order matches the charged timeline; s=0 is a pure barrier, so
        # it forgets any staggered front a previous async round left
        carry = (None if staleness == 0
                 else getattr(self, "_async_carry", None))
        tl = clk.async_program_timeline(
            rt, self.fl, program, fleet, mask_np, self.labels,
            staleness, uplink_ratio, carry=carry)
        self._async_carry = None if staleness == 0 else tl["carry_out"]
        bprogs = prg.block_programs(program)
        nblocks = len(bprogs)
        base_args = [self._resolve_args(bp, plan, fuse=True)
                     for bp in bprogs]
        cohort = (np.ones(self.sched.n) if mask_np is None
                  else np.asarray(mask_np, float))
        self.key, k = jax.random.split(self.key)
        # host-side split == the barrier round's in-jit split of k
        bkeys = jax.random.split(k, nblocks)
        b = self.bank
        self.last_bucket = b.n
        phases = np.zeros(m, dtype=int)
        trace: List[Dict[str, Any]] = []
        for ev in tl["events"]:
            adv = np.zeros(m, dtype=bool)
            adv[list(ev.clusters)] = True
            assert (phases[adv] == ev.block).all(), "phase skew"
            base = base_args[ev.block]
            assert len(base.mats) == 1  # fused plain block: one MixGroup
            Wm = gsp.staleness_mask(np.asarray(base.mats[0]),
                                    self.labels, phases, staleness, adv)
            ev_mask = jnp.asarray(cohort * adv[self.labels], jnp.float32)
            args = prg.RoundArgs((jnp.asarray(Wm),), base.tau_dev)
            fn = self._get_round("flat_block", bprogs[ev.block])
            b.params, b.mom, b.residual = fn(
                b.params, b.mom, b.residual, bkeys[ev.block], args,
                ev_mask)
            cross = ((np.asarray(Wm) != 0)
                     & (self.labels[:, None] != self.labels[None, :]))
            ii, jj = np.nonzero(cross)
            edges = sorted({(int(a), int(c)) for a, c in
                            zip(self.labels[ii], self.labels[jj])})
            trace.append({"time": ev.time, "block": ev.block,
                          "clusters": ev.clusters,
                          "phases": phases.copy(), "edges": edges})
            phases[adv] += 1
        assert (phases == nblocks).all(), "round left clusters mid-phase"
        self._async_phases = (getattr(self, "_async_phases",
                                      np.zeros(m, dtype=int)) + phases)
        self.last_async = {"timeline": tl, "trace": trace,
                           "staleness": int(staleness),
                           "phases": self._async_phases.copy()}
        return plan

    def run(self, rounds: int, eval_every: int = 1,
            eval_batch: int = 512) -> Dict[str, List[float]]:
        hist: Dict[str, List[float]] = {"round": [], "acc": [], "loss": []}
        for r in range(rounds):
            self.step_round()
            if (r + 1) % eval_every == 0:
                acc, loss = self.evaluate(eval_batch)
                hist["round"].append(r + 1)
                hist["acc"].append(acc)
                hist["loss"].append(loss)
        return hist

    def edge_models(self):
        """Cluster-averaged (edge) models y_t — what the paper evaluates.
        Uses the CURRENT assignment B_t (mobility moves devices between
        clusters, so membership is re-read every call). In bank mode the
        (m, n) projection streams the flat bank once."""
        if self._streamed:
            # the streamed store's per-cluster references ARE y_t
            # (pipelined: land the in-flight round's refs first)
            self._drain_pipeline()
            return self._layout.unflatten_stack(
                jnp.asarray(self.store.cluster_params))
        B = topo.assignment_matrix(self.labels, self.fl.num_clusters)
        P = topo.masked_cluster_average(B)
        if self.bank is not None:
            return self.bank.project(P)
        # mix() row-applies, so a rectangular (m, n) averaging operator
        # maps the n device models straight to the m edge models
        return mix(P, self._params)

    def global_model(self):
        """Device-average model x̄ as a single pytree."""
        if self._streamed:
            self._drain_pipeline()
            # end-of-round rows are cluster-uniform, so the device
            # average is the cluster-size-weighted reference average
            sizes = (self.pop.sizes.astype(np.float64)
                     if self.pop is not None
                     else np.bincount(self.labels,
                                      minlength=self.fl.num_clusters)
                     .astype(np.float64))
            w = sizes / sizes.sum()
            row = (np.asarray(self.store.cluster_params, np.float64)
                   * w[:, None]).sum(0).astype(np.float32)
            return self._layout.unflatten_one(jnp.asarray(row))
        if self.bank is not None:
            return self.bank.mean_model()
        return jax.tree.map(lambda l: jnp.mean(l, 0), self._params)

    def _build_eval(self):
        """One jitted eval closure for the simulator's lifetime; jit's
        shape cache makes each distinct (m, eval_batch) trace once
        instead of re-tracing the vmapped closure per ``evaluate`` call."""
        def eval_impl(em, tx, ty):
            def one(p):
                logits = self.apply_fn(p, tx)
                acc = jnp.mean(
                    (jnp.argmax(logits, -1) == ty).astype(jnp.float32))
                return acc, self._loss(p, tx, ty)
            accs, losses = jax.vmap(one)(em)
            return jnp.mean(accs), jnp.mean(losses)
        return jax.jit(eval_impl)

    def evaluate(self, eval_batch: int = 512):
        """Mean test accuracy of the m edge models on the common test set."""
        em = self.edge_models()
        tx = self.data["test_x"][:eval_batch]
        ty = self.data["test_y"][:eval_batch]
        acc, loss = self._eval_fn(em, tx, ty)
        return float(acc), float(loss)
