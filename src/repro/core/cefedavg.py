"""CE-FedAvg (Algorithm 1) — operator algebra + the simulation engine.

The paper's update rule (eq. 10):  X_{t+1} = (X_t − η G_t) W_t, with
W_t ∈ {I, V, B^T diag(c) H^π B} depending on the iteration (eq. 11).
``make_w_schedule`` builds those operators for CE-FedAvg and for every
baseline (Table 1 / §4.3 special cases); ``FLSimulator`` runs the literal
matrix form with all n device models materialized — the paper-faithful
engine used for the Figure 2–6 reproductions and for unit-testing the
sharded production trainer against.

Two interchangeable engines live behind the same ``FLSimulator`` API:

- **ModelBank (default, ``bank=True``)** — params, momentum and the
  error-feedback residual are single contiguous ``(n, T)`` float32
  buffers (``core/modelbank.py``); pytree views exist only inside the
  per-device ``apply_fn`` and at eval/checkpoint edges. Every mixing
  boundary is ONE streaming pass of the fused gossip kernel
  (``kernels/gossip_mix.gossip_mix_rows``), the coincident τ/qτ boundary
  is folded into a single pass with the precomputed operator
  ``W_inter @ W_intra``, the jitted round donates its buffers (peak
  memory ~1× the bank), and scenario rounds with partial participation
  run their gradient work on a compacted ``(k_pad, T)`` cohort gather
  (static bucketed sizes, ``modelbank.cohort_buckets``).
- **legacy pytree (``bank=False``)** — per-leaf ``tensordot`` mixing and
  full-n ``where``-frozen local steps; kept as the bit-faithful parity
  reference (``tests/test_modelbank.py``).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FLConfig
from repro.core import topology as topo
from repro.core.modelbank import ModelBank, cohort_buckets, compact_plan
from repro.kernels.gossip_mix import gossip_mix_rows


@dataclass
class WSchedule:
    """Mixing operators applied at iteration boundaries (eq. 11)."""
    W_intra: np.ndarray      # applied when (t+1) % tau == 0 (and not inter)
    W_inter: np.ndarray      # applied when (t+1) % (q*tau) == 0
    H: np.ndarray            # m x m backhaul mixing matrix
    zeta: float
    cluster_sizes: List[int]
    adj: np.ndarray          # m x m backhaul adjacency (bool)

    @property
    def n(self) -> int:
        return self.W_intra.shape[0]

    @property
    def degrees(self) -> np.ndarray:
        """Backhaul degree of each cluster (traffic accounting)."""
        return self.adj.sum(1).astype(np.int64)


def make_w_schedule(fl: FLConfig) -> WSchedule:
    """Static mixing schedule (eq. 11 / Table 1): W_intra applied at
    τ-boundaries, W_inter at qτ-boundaries, specialized per algorithm via
    the §4.3 reductions (Hier-FAvg, FedAvg, Local-Edge, dec. local SGD).
    Assumes equal clusters and full participation; the scenario engine
    (core/scenario.py) builds the time-varying masked generalization."""
    fl.validate()
    m, n = fl.num_clusters, fl.n
    sizes = [fl.devices_per_cluster] * m
    V = topo.intra_cluster_operator(sizes)
    A = np.ones((n, n)) / n
    eye = np.eye(n)
    adj = topo.build_adjacency(fl.topology, m, fl)
    H = topo.mixing_matrix(adj, fl.mixing)
    if fl.algorithm == "ce_fedavg":
        W_intra, W_inter = V, topo.inter_cluster_operator(sizes, H, fl.pi)
    elif fl.algorithm == "hier_favg":
        W_intra, W_inter = V, A
    elif fl.algorithm == "fedavg":
        W_intra, W_inter = eye, A
    elif fl.algorithm == "local_edge":
        W_intra, W_inter = V, V
    elif fl.algorithm == "dec_local_sgd":
        # n == m: every device is its own cluster, neighbors gossip
        assert fl.devices_per_cluster == 1, "dec_local_sgd requires n == m"
        W_intra = eye
        W_inter = np.linalg.matrix_power(H, fl.pi)
    else:
        raise ValueError(fl.algorithm)
    return WSchedule(W_intra, W_inter, H, topo.zeta(H), sizes, adj)


def mix(W, params):
    """Apply a mixing operator over the leading device axis of every leaf:
    x_k ← Σ_j W[k,j]·x_j (row application).

    The paper's eq. 10 operators are symmetric doubly stochastic, where
    row and column application coincide; the masked/unequal-cluster
    generalizations (core/scenario.py) are only row-stochastic, so the
    row form is the correct one for both."""
    Wj = jnp.asarray(W, jnp.float32)

    def one(leaf):
        out = jnp.tensordot(Wj, leaf.astype(jnp.float32), axes=[[1], [0]])
        return out.astype(leaf.dtype)
    return jax.tree.map(one, params)


# ---------------------------------------------------------------------------
# Simulation engine (paper-faithful, laptop scale)
# ---------------------------------------------------------------------------

class FLSimulator:
    """Runs Algorithm 1 with n materialized device models.

    init_fn(key) -> params;  apply_fn(params, x) -> logits.
    data: dict with xs (n, N, ...), ys (n, N) — per-device training shards;
          test_x, test_y — the common test set.
    scenario: optional config.ScenarioConfig — per-round client sampling,
          straggler dropout and device mobility (core/scenario.py); pair
          with core.clock.run_wall_clock for time-to-accuracy curves.
    bank: True (default) runs the flat ModelBank engine; False the legacy
          per-leaf pytree engine (parity/debug escape hatch). ``params``,
          ``mom`` and ``residual`` read/write as pytrees in both modes.
    """

    def __init__(self, init_fn: Callable, apply_fn: Callable, fl: FLConfig,
                 data: Dict[str, Any], *, lr: float = 0.05,
                 momentum: float = 0.9, batch_size: int = 50, seed: int = 0,
                 compression=None, dp=None, scenario=None,
                 bank: bool = True):
        self.fl = fl
        self.apply_fn = apply_fn
        self.sched = make_w_schedule(fl)
        n = self.sched.n
        assert data["xs"].shape[0] == n
        self.data = data
        self.lr, self.momentum, self.batch = lr, momentum, batch_size
        self.compression = compression  # core.compress.CompressionConfig
        self.dp = dp                    # core.privacy.DPConfig
        # wall-clock scenario (config.ScenarioConfig): per-round sampling,
        # mobility and heterogeneity — None keeps the static schedule
        if scenario is not None:
            from repro.core.scenario import ScenarioEngine
            self.engine = ScenarioEngine(scenario, fl)
        else:
            self.engine = None
        # current cluster assignment B_t (mobility re-draws it per round)
        self.labels = np.repeat(np.arange(fl.num_clusters),
                                fl.devices_per_cluster)
        self._W_intra_j = jnp.asarray(self.sched.W_intra, jnp.float32)
        self._W_inter_j = jnp.asarray(self.sched.W_inter, jnp.float32)
        # the coincident τ/qτ boundary folded into one operator — the
        # fused single-pass form the ModelBank engine applies
        self._W_comb_j = jnp.asarray(
            self.sched.W_inter @ self.sched.W_intra, jnp.float32)
        self._full_mask = jnp.ones((n,), jnp.float32)
        with_residual = (compression is not None
                         and compression.error_feedback)
        # Algorithm 1 initializes every device from its edge model y_{0,0};
        # we use one shared init (common FL practice), so params are
        # cluster-uniform from the start.
        one = init_fn(jax.random.PRNGKey(seed))
        self.bank: Optional[ModelBank] = None
        # cohort compaction gathers bank rows into a dense (k_pad, T) slab;
        # the sharded engine (core.sharded.ShardedBankCEFedAvg) pins rows
        # to devices and disables it, running mask-frozen full rows instead
        self._compact_enabled = True
        if bank:
            self.bank = ModelBank.from_model(one, n,
                                             with_residual=with_residual)
            self._buckets = cohort_buckets(n)
            self._round_flat = self._build_round_flat()
            self._round_compact = self._build_round_compact()
        else:
            self._params = jax.tree.map(
                lambda l: jnp.broadcast_to(l, (n,) + l.shape).copy(), one)
            self._mom = jax.tree.map(jnp.zeros_like, self._params)
            self._residual = (jax.tree.map(jnp.zeros_like, self._params)
                              if with_residual else None)
            self._round = self._build_round()
        self.last_bucket = n   # cohort capacity used by the latest round
        self.key = jax.random.PRNGKey(seed + 1)
        self._eval_fn = self._build_eval()

    # -- state as pytrees (both engines) ------------------------------------
    @property
    def params(self):
        """Device-stacked model pytree; in bank mode a materialized view
        of the flat (n, T) buffer (fresh arrays, safe across rounds)."""
        if self.bank is not None:
            return self.bank.params_tree()
        return self._params

    @params.setter
    def params(self, tree):
        if self.bank is not None:
            self.bank.params = self.bank.layout.flatten_stack(tree)
        else:
            self._params = tree

    @property
    def mom(self):
        """Device-stacked momentum pytree (see ``params``)."""
        if self.bank is not None:
            return self.bank.layout.unflatten_stack(self.bank.mom)
        return self._mom

    @mom.setter
    def mom(self, tree):
        if self.bank is not None:
            self.bank.mom = self.bank.layout.flatten_stack(tree)
        else:
            self._mom = tree

    @property
    def residual(self):
        """Error-feedback residual pytree, or None when compression with
        error feedback is off."""
        if self.bank is not None:
            if self.bank.residual is None:
                return None
            return self.bank.layout.unflatten_stack(self.bank.residual)
        return self._residual

    @residual.setter
    def residual(self, tree):
        if self.bank is not None:
            self.bank.residual = (
                None if tree is None
                else self.bank.layout.flatten_stack(tree))
        else:
            self._residual = tree

    # -- loss --------------------------------------------------------------
    def _loss(self, p, x, y):
        logits = self.apply_fn(p, x)
        lse = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        return jnp.mean(lse - picked)

    # -- one global round, jitted (legacy pytree engine) --------------------
    def _build_round(self):
        """The legacy jitted global round. W_intra/W_inter/mask are
        *arguments* (not closure constants) so the scenario engine can
        re-draw them between rounds without recompiling: masked devices
        take no local steps (their params and momentum are frozen via
        ``where``) and the operators are whatever (possibly
        unequal/masked) matrices the caller passes — the static schedule
        with a full mask reproduces the original fixed-schedule round
        bit-for-bit."""
        fl = self.fl
        n = self.sched.n
        N = self.data["xs"].shape[1]
        grad_fn = jax.grad(self._loss)

        def bcast(act, leaf):
            return act.reshape((-1,) + (1,) * (leaf.ndim - 1))

        def make_local_step(act):
            def local_step(carry, key):
                params, mom = carry
                idx = jax.random.randint(key, (n, self.batch), 0, N)
                xb = jax.vmap(lambda x, i: x[i])(self.data["xs"], idx)
                yb = jax.vmap(lambda y, i: y[i])(self.data["ys"], idx)
                grads = jax.vmap(grad_fn)(params, xb, yb)
                mom = jax.tree.map(
                    lambda v, g: jnp.where(bcast(act, v),
                                           self.momentum * v + g, v),
                    mom, grads)
                params = jax.tree.map(
                    lambda p, v: jnp.where(bcast(act, p),
                                           p - self.lr * v, p),
                    params, mom)
                return (params, mom), None
            return local_step

        comp, dp = self.compression, self.dp

        def upload_transform(delta, residual, key):
            """Device-side: (optional) DP then compression of the delta."""
            if dp is not None and dp.enabled:
                from repro.core.privacy import privatize_update
                keys = jax.random.split(key, n)
                delta = jax.vmap(
                    lambda d, k: privatize_update(d, dp, k))(
                        delta, keys)
            if comp is not None and comp.kind != "none":
                from repro.core.compress import compress_tree
                keys = jax.random.split(jax.random.fold_in(key, 1), n)
                delta, residual = jax.vmap(
                    lambda d, r, k: compress_tree(comp, d, r, k)
                )(delta, residual, keys)
            return delta, residual

        def make_edge_round(W_intra, act):
            local_step = make_local_step(act)

            def edge_round(carry, key):
                params0, mom, residual = carry
                keys = jax.random.split(key, fl.tau)
                (params, mom), _ = jax.lax.scan(local_step, (params0, mom),
                                                keys)
                if comp is None and dp is None:
                    params = mix(W_intra, params)
                else:
                    # devices upload (privatized/compressed) deltas; the edge
                    # reconstructs x_start + V·delta (exact when both are off)
                    delta = jax.tree.map(lambda a, b: a - b, params, params0)
                    delta, residual = upload_transform(
                        delta, residual, jax.random.fold_in(key, 7))
                    params = jax.tree.map(
                        lambda p0, d: p0 + d, params0, mix(W_intra, delta))
                return (params, mom, residual), None
            return edge_round

        @jax.jit
        def global_round(params, mom, residual, key, W_intra, W_inter,
                         mask):
            act = mask > 0.5
            edge_round = make_edge_round(W_intra, act)
            keys = jax.random.split(key, fl.q)
            (params, mom, residual), _ = jax.lax.scan(
                edge_round, (params, mom, residual), keys)
            params = mix(W_inter, params)
            return params, mom, residual

        return global_round

    # -- one global round, jitted (flat ModelBank engine) -------------------
    def _flat_helpers(self):
        """Local-step factory shared by the flat rounds; the per-row grad
        closure materializes pytree views only inside the apply call."""
        n = self.sched.n
        N = self.data["xs"].shape[1]
        layout = self.bank.layout

        def loss_row(row, x, y):
            return self._loss(layout.unflatten_one(row), x, y)
        grad_row = jax.grad(loss_row)

        def make_local_step(xs, ys, act2d, gather=None):
            """One SGD+momentum step on a (rows, T) slab. ``gather``
            (compaction) maps the full-n batch-index draw onto the slab's
            rows so the cohort sees the same batches as the full path."""
            def local_step(carry, key):
                Y, M = carry
                idx = jax.random.randint(key, (n, self.batch), 0, N)
                if gather is not None:
                    idx = idx[gather]
                xb = jax.vmap(lambda x, i: x[i])(xs, idx)
                yb = jax.vmap(lambda y, i: y[i])(ys, idx)
                G = jax.vmap(grad_row)(Y, xb, yb)
                M = jnp.where(act2d, self.momentum * M + G, M)
                Y = jnp.where(act2d, Y - self.lr * M, Y)
                return (Y, M), None
            return local_step

        return make_local_step

    def _build_round_flat(self):
        """The flat global round: all state stays (n, T); each mixing
        boundary is one streaming pass (``gossip_mix_rows``); the final
        τ-boundary, which coincides with the qτ-boundary, is fused into
        a single pass with the precomputed ``W_final = W_inter @ W_intra``
        (the caller passes plain ``W_inter`` on the delta/upload path,
        where the two applications cannot be folded). Buffers are donated
        so peak memory stays ~1× the bank."""
        fl = self.fl
        n = self.sched.n
        comp, dp = self.compression, self.dp
        plain = comp is None and dp is None
        xs, ys = self.data["xs"], self.data["ys"]
        make_local_step = self._flat_helpers()
        segments = self.bank.layout.segments

        def train_tau(Y, M, key, act2d):
            local_step = make_local_step(xs, ys, act2d)
            keys = jax.random.split(key, fl.tau)
            (Y, M), _ = jax.lax.scan(local_step, (Y, M), keys)
            return Y, M

        def upload(delta, R, key):
            """Flat-domain device uploads: DP then compression, row-wise
            (same per-device/per-leaf key schedule as the pytree path)."""
            if dp is not None and dp.enabled:
                from repro.core.privacy import privatize_update_flat
                keys = jax.random.split(key, n)
                delta = jax.vmap(
                    lambda d, k: privatize_update_flat(d, dp, k))(
                        delta, keys)
            if comp is not None and comp.kind != "none":
                from repro.core.compress import compress_flat
                keys = jax.random.split(jax.random.fold_in(key, 1), n)
                delta, R = jax.vmap(
                    lambda d, r, k: compress_flat(comp, d, r, k, segments)
                )(delta, R, keys)
            return delta, R

        @functools.partial(jax.jit, donate_argnums=(0, 1, 2))
        def global_round(Y, M, R, key, W_intra, W_final, mask):
            act2d = (mask > 0.5)[:, None]
            keys = jax.random.split(key, fl.q)
            if plain:
                def body(carry, k1):
                    Y, M, R = carry
                    Y, M = train_tau(Y, M, k1, act2d)
                    Y = gossip_mix_rows(W_intra, Y)
                    return (Y, M, R), None
                if fl.q > 1:
                    (Y, M, R), _ = jax.lax.scan(body, (Y, M, R),
                                                keys[:-1])
                Y, M = train_tau(Y, M, keys[-1], act2d)
                Y = gossip_mix_rows(W_final, Y)   # fused τ∘qτ boundary
                return Y, M, R

            def body(carry, k1):
                Y0, M, R = carry
                Y, M = train_tau(Y0, M, k1, act2d)
                delta = Y - Y0
                delta, R = upload(delta, R, jax.random.fold_in(k1, 7))
                Y = Y0 + gossip_mix_rows(W_intra, delta)
                return (Y, M, R), None
            (Y, M, R), _ = jax.lax.scan(body, (Y, M, R), keys)
            Y = gossip_mix_rows(W_final, Y)       # W_inter on this path
            return Y, M, R

        return global_round

    def _build_round_compact(self):
        """The compacted scenario round: gradient/momentum work runs on a
        dense (k_pad, T) gather of the participating rows (``idx`` holds
        distinct rows — cohort first, inert padding after — so the
        scatter back is deterministic); mixing boundaries still stream
        the full bank, since masked operators move every device's row.
        Traced once per cohort bucket (static shapes under jit)."""
        fl = self.fl
        xs, ys = self.data["xs"], self.data["ys"]
        make_local_step = self._flat_helpers()

        @functools.partial(jax.jit, donate_argnums=(0, 1))
        def compact_round(Y, M, key, idx, lane, W_intra, W_comb):
            lane2d = lane[:, None]
            xs_c, ys_c = xs[idx], ys[idx]
            local_step = make_local_step(xs_c, ys_c, lane2d, gather=idx)

            def train_edge(carry, k1):
                Y, M = carry
                P, Mc = Y[idx], M[idx]
                keys = jax.random.split(k1, fl.tau)
                (P, Mc), _ = jax.lax.scan(local_step, (P, Mc), keys)
                return Y.at[idx].set(P), M.at[idx].set(Mc)

            keys = jax.random.split(key, fl.q)
            if fl.q > 1:
                def body(carry, k1):
                    Y, M = train_edge(carry, k1)
                    return (gossip_mix_rows(W_intra, Y), M), None
                (Y, M), _ = jax.lax.scan(body, (Y, M), keys[:-1])
            Y, M = train_edge((Y, M), keys[-1])
            Y = gossip_mix_rows(W_comb, Y)        # fused τ∘qτ boundary
            return Y, M

        return compact_round

    # -- driver -------------------------------------------------------------
    def step_round(self):
        """Advance ONE global round.

        With a scenario attached, first realizes this round's plan
        (mobility re-draws B_t, sampling draws the cohort) and feeds the
        induced masked operators to the jitted round; otherwise replays
        the static schedule with full participation. In bank mode a
        partial cohort dispatches to the compacted round (``last_bucket``
        records the capacity used). Returns the ``RoundPlan`` (or None
        without a scenario) so callers — e.g. the wall-clock harness in
        core/clock.py — can charge the cohort."""
        if self.engine is not None:
            plan = self.engine.step()
            self.labels = plan.labels
            W_intra = jnp.asarray(plan.W_intra, jnp.float32)
            W_inter = jnp.asarray(plan.W_inter, jnp.float32)
            mask_np = plan.mask
        else:
            plan = None
            W_intra, W_inter = self._W_intra_j, self._W_inter_j
            mask_np = None
        self.key, k = jax.random.split(self.key)
        if self.bank is None:
            mask = (jnp.asarray(mask_np, jnp.float32)
                    if mask_np is not None else self._full_mask)
            self._params, self._mom, self._residual = self._round(
                self._params, self._mom, self._residual, k, W_intra,
                W_inter, mask)
            return plan
        b = self.bank
        plain = self.compression is None and self.dp is None
        k_active = b.n if mask_np is None else int(mask_np.sum())
        if plain and k_active < b.n and self._compact_enabled:
            cp = compact_plan(mask_np, self._buckets)
            self.last_bucket = cp.k_pad
            W_comb = jnp.asarray(plan.W_inter @ plan.W_intra, jnp.float32)
            b.params, b.mom = self._round_compact(
                b.params, b.mom, k, jnp.asarray(cp.idx),
                jnp.asarray(cp.lane), W_intra, W_comb)
            return plan
        self.last_bucket = b.n
        if plan is None:
            W_final = self._W_comb_j if plain else self._W_inter_j
            mask = self._full_mask
        else:
            W_final = (jnp.asarray(plan.W_inter @ plan.W_intra, jnp.float32)
                       if plain else W_inter)
            mask = jnp.asarray(mask_np, jnp.float32)
        b.params, b.mom, b.residual = self._round_flat(
            b.params, b.mom, b.residual, k, W_intra, W_final, mask)
        return plan

    def run(self, rounds: int, eval_every: int = 1,
            eval_batch: int = 512) -> Dict[str, List[float]]:
        hist: Dict[str, List[float]] = {"round": [], "acc": [], "loss": []}
        for r in range(rounds):
            self.step_round()
            if (r + 1) % eval_every == 0:
                acc, loss = self.evaluate(eval_batch)
                hist["round"].append(r + 1)
                hist["acc"].append(acc)
                hist["loss"].append(loss)
        return hist

    def edge_models(self):
        """Cluster-averaged (edge) models y_t — what the paper evaluates.
        Uses the CURRENT assignment B_t (mobility moves devices between
        clusters, so membership is re-read every call). In bank mode the
        (m, n) projection streams the flat bank once."""
        B = topo.assignment_matrix(self.labels, self.fl.num_clusters)
        P = topo.masked_cluster_average(B)
        if self.bank is not None:
            return self.bank.project(P)
        # mix() row-applies, so a rectangular (m, n) averaging operator
        # maps the n device models straight to the m edge models
        return mix(P, self._params)

    def global_model(self):
        """Device-average model x̄ as a single pytree."""
        if self.bank is not None:
            return self.bank.mean_model()
        return jax.tree.map(lambda l: jnp.mean(l, 0), self._params)

    def _build_eval(self):
        """One jitted eval closure for the simulator's lifetime; jit's
        shape cache makes each distinct (m, eval_batch) trace once
        instead of re-tracing the vmapped closure per ``evaluate`` call."""
        def eval_impl(em, tx, ty):
            def one(p):
                logits = self.apply_fn(p, tx)
                acc = jnp.mean(
                    (jnp.argmax(logits, -1) == ty).astype(jnp.float32))
                return acc, self._loss(p, tx, ty)
            accs, losses = jax.vmap(one)(em)
            return jnp.mean(accs), jnp.mean(losses)
        return jax.jit(eval_impl)

    def evaluate(self, eval_batch: int = 512):
        """Mean test accuracy of the m edge models on the common test set."""
        em = self.edge_models()
        tx = self.data["test_x"][:eval_batch]
        ty = self.data["test_y"][:eval_batch]
        acc, loss = self._eval_fn(em, tx, ty)
        return float(acc), float(loss)
