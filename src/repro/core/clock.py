"""Event clock: wall-clock time-to-accuracy accounting (paper §6, Figs. 5–6).

``FLSimulator`` measures accuracy per *round*; the paper's headline claim
is accuracy per *second*. :class:`EventClock` converts rounds to seconds
by charging each global round

    max over participating devices of  qτ·C/c_k      (compute, eq. 8)
  + the algorithm's communication terms               (RuntimeModel.comm_time)

so a straggler paces the round only when it actually participates, and
:func:`run_wall_clock` couples a (scenario-aware) simulator to that clock,
emitting ``(wall_time, acc)`` curves and :func:`time_to_accuracy`.
"""
from __future__ import annotations

import time
from typing import Dict, List, Optional, Sequence

from repro.config import FLConfig
from repro.core.runtime import RuntimeModel


class EventClock:
    """Accumulates simulated wall time, one global round at a time."""

    def __init__(self, rt: RuntimeModel, fl: FLConfig):
        self.rt, self.fl = rt, fl
        self.now = 0.0

    def charge_round(self, speeds: Optional[Sequence[float]] = None,
                     uplink_ratio: float = 1.0) -> float:
        """Advance the clock by one global round of ``fl.algorithm``.

        ``speeds`` are the FLOP/s of the devices that participated this
        round (the max_k rule runs over them only); omitted means the
        RuntimeModel's homogeneous/default speeds. Returns the new time.
        """
        fl = self.fl
        comp = self.rt.compute_time(fl.q * fl.tau, speeds)
        comm = self.rt.comm_time(fl.algorithm, fl.q, fl.pi, uplink_ratio)
        self.now += comp + comm
        return self.now


def run_wall_clock(sim, rt: RuntimeModel, rounds: int, *,
                   eval_every: int = 1, eval_batch: int = 512,
                   uplink_ratio: float = 1.0) -> Dict[str, List[float]]:
    """Drive ``sim`` (an FLSimulator) for ``rounds`` global rounds under
    the event clock, returning a history dict with ``round``,
    ``wall_time``, ``acc``, ``loss`` and ``participants`` columns.

    With a scenario attached to the simulator, each round's compute charge
    is paced by the slowest device in that round's realized cohort
    (``ScenarioEngine.active_speeds`` × the profile's device_flops);
    without one, by the RuntimeModel's own speeds.

    Besides the *simulated* wall clock, the history records the
    *simulator's own* per-eval-window host seconds (``sim_s``) — the
    perf-trajectory instrumentation the benchmarks read to verify that,
    e.g., a 50%-participation round really does less gradient work than a
    full one (ModelBank cohort compaction, docs/PERFORMANCE.md).
    """
    clock = EventClock(rt, sim.fl)
    hist: Dict[str, List[float]] = {
        "round": [], "wall_time": [], "acc": [], "loss": [],
        "participants": [], "sim_s": []}
    window_t0 = time.perf_counter()
    for r in range(rounds):
        plan = sim.step_round()
        if plan is not None:
            mult = sim.engine.active_speeds(plan)
            speeds = mult * rt.hw.device_flops
            participants = int(plan.mask.sum())
        else:
            speeds = None
            participants = sim.fl.n
        t = clock.charge_round(speeds, uplink_ratio)
        if (r + 1) % eval_every == 0:
            sim_s = time.perf_counter() - window_t0
            acc, loss = sim.evaluate(eval_batch)
            hist["round"].append(r + 1)
            hist["wall_time"].append(t)
            hist["acc"].append(acc)
            hist["loss"].append(loss)
            hist["participants"].append(participants)
            hist["sim_s"].append(sim_s)
            window_t0 = time.perf_counter()
    return hist


def time_to_accuracy(hist: Dict[str, List[float]],
                     target: float) -> Optional[float]:
    """First wall-clock time at which the evaluated accuracy reached
    ``target``, or None if the curve never got there."""
    for t, a in zip(hist["wall_time"], hist["acc"]):
        if a >= target:
            return float(t)
    return None


def summarize(hist: Dict[str, List[float]], target: float) -> str:
    """One-line human summary of a wall-clock curve."""
    tta = time_to_accuracy(hist, target)
    final = hist["acc"][-1] if hist["acc"] else float("nan")
    total = hist["wall_time"][-1] if hist["wall_time"] else 0.0
    reach = "never" if tta is None else f"{tta:,.0f}s"
    return (f"final_acc={final:.3f} total={total:,.0f}s "
            f"time_to_{target:.0%}={reach}")
