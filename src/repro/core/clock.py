"""Event clock: wall-clock time-to-accuracy accounting (paper §6, Figs. 5–6).

``FLSimulator`` measures accuracy per *round*; the paper's headline claim
is accuracy per *second*. :class:`EventClock` converts rounds to seconds
by charging each global round

    max over participating devices of  qτ·C/c_k      (compute, eq. 8)
  + the algorithm's communication terms               (RuntimeModel.comm_time)

so a straggler paces the round only when it actually participates, and
:func:`run_wall_clock` couples a (scenario-aware) simulator to that clock,
emitting ``(wall_time, acc)`` curves and :func:`time_to_accuracy`.

Rounds driven by a :class:`repro.core.program.RoundProgram` are charged
*per op* instead of by the static τ/q/π formula:
:func:`program_compute_time` prices each ``LocalSteps`` op by the
max-over-participants rule — with per-device ``tau_dev`` cutoffs for
adaptive programs, which is exactly why adaptive-τ_k shortens rounds —
and :func:`program_comm_time` prices each mixing boundary by tier
(``TierMix(0)``/``IntraMix`` → device→edge upload, ``TierMix(ℓ>=1, π)``
→ π exchanges over that tier's links — ``b_e2e`` for the backhaul,
``HardwareProfile.b_tiers`` overrides above it — specialized per
algorithm as in §6.1). The canonical program reproduces
``charge_round`` to the last term.

:func:`run_wall_clock` also closes the online-schedule feedback loop:
after charging a round it reports the realized per-device step counts
and compute seconds to the schedule's
:class:`repro.core.program.OnlineSpeedEstimator` (if the simulator's
schedule exposes one), which is how ``"adaptive_tau_online"`` learns
cluster speeds without oracle access.
"""
from __future__ import annotations

import time
from typing import Dict, List, NamedTuple, Optional, Sequence, Tuple

import numpy as np

from repro.config import FLConfig
from repro.core import program as prg
from repro.core import topology as topo
from repro.core.runtime import RuntimeModel


def program_compute_time(rt: RuntimeModel, program: "prg.RoundProgram",
                         speeds: Optional[Sequence[float]] = None,
                         mask: Optional[np.ndarray] = None) -> float:
    """Compute seconds of one programmed round: per ``LocalSteps`` op,
    max over participating devices of steps_d·C/c_d — where steps_d is
    the op's τ, or the device's ``tau_dev`` cutoff when adaptive.

    ``speeds`` are per-device FLOP/s aligned with ``mask`` (the full
    fleet vector); None means the RuntimeModel's homogeneous default.
    The canonical program reduces to ``rt.compute_time(q·τ, ·)``."""
    C = rt.wl.flops_per_step
    total = 0.0
    tau_dev = program.tau_dev
    for b in program.blocks():
        op = b.local
        if op.adaptive and tau_dev is not None:
            # cutoffs are bounded by the max adaptive tau across blocks;
            # THIS block executes at most its own op.tau steps
            steps = np.minimum(np.asarray(tau_dev, float), float(op.tau))
        else:
            steps = np.full(1 if speeds is None else len(speeds),
                            float(op.tau))
        if speeds is None:
            if rt.speeds:
                c = np.asarray(rt.speeds, float)[:len(steps)] \
                    if len(steps) > 1 else np.array([min(rt.speeds)])
            else:
                c = np.full(steps.shape, rt.hw.device_flops)
        else:
            c = np.asarray(speeds, float)
        if mask is not None and len(steps) == len(mask):
            active = np.asarray(mask) > 0
            if active.any():
                steps, c = steps[active], c[active]
        total += float(np.max(steps * C / c))
    return total


def program_device_steps(program: "prg.RoundProgram", n: int) -> np.ndarray:
    """(n,) local SGD steps each device executes in one round of
    ``program``: Σ over blocks of the block's τ, respecting per-device
    ``tau_dev`` cutoffs of adaptive blocks — the step counts the online
    speed estimator pairs with realized compute times."""
    steps = np.zeros(n)
    tau_dev = program.tau_dev
    for b in program.blocks():
        op = b.local
        if op.adaptive and tau_dev is not None:
            steps += np.minimum(np.asarray(tau_dev, float), float(op.tau))
        else:
            steps += float(op.tau)
    return steps


def program_device_times(rt: RuntimeModel, program: "prg.RoundProgram",
                         speeds: np.ndarray) -> np.ndarray:
    """(n,) compute seconds each device spends in one round of
    ``program`` at per-device FLOP/s ``speeds`` — what an EventClock
    observes per device (steps_d·C/c_d)."""
    return (program_device_steps(program, len(speeds))
            * rt.wl.flops_per_step / np.asarray(speeds, float))


def fault_compute_penalty(rt: RuntimeModel, program: "prg.RoundProgram",
                          fc, fault, speeds: Optional[np.ndarray] = None,
                          mask: Optional[np.ndarray] = None) -> float:
    """Extra compute seconds the straggler-timeout retry ladder costs a
    round beyond its max-over-survivors charge.

    ``fault`` is the round's realized ``scenario.FaultPlan`` and ``fc``
    the ``config.FaultConfig`` that produced it. A device that needed
    ``a`` aborted attempts waited through budgets
    ``timeout_factor · retry_backoff^i · t_ref`` for i < a (t_ref being
    the cohort-median device's compute this round), then — if it
    survived — ran its own compute; a dropped device pays only the
    exhausted ladder. The penalty is how far the slowest such ladder
    extends past the surviving cohort's ordinary max-over-participants
    charge; 0.0 when no attempt was aborted (the fault-free bitwise
    anchor)."""
    if fault is None or fc is None or not (fault.attempts > 0).any():
        return 0.0
    C = rt.wl.flops_per_step
    n = len(fault.attempts)
    c = (np.asarray(speeds, float) if speeds is not None
         else np.full(n, rt.hw.device_flops))
    steps = program_device_steps(program, n)
    ladder = np.asarray(fault.attempts, float)
    hit = ladder > 0
    # the budget basis: the cohort-median device's round compute
    t_ref = (float(np.median(steps[hit])) * C
             / (float(fault.ref_mult) * rt.hw.device_flops))
    geo = np.array([
        sum(fc.timeout_factor * fc.retry_backoff ** i
            for i in range(int(a))) for a in fault.attempts[hit]])
    own = np.where(fault.timed_out[hit], 0.0, steps[hit] * C / c[hit])
    worst = float(np.max(geo * t_ref + own))
    # compare against what charge_program already charged: the ordinary
    # max-over-participants compute of this round's surviving cohort
    base = program_compute_time(rt, program, speeds, mask)
    return max(0.0, worst - base)


def program_comm_time(rt: RuntimeModel, algorithm: str,
                      program: "prg.RoundProgram",
                      uplink_ratio: float = 1.0) -> float:
    """Communication seconds of one programmed round, priced per mixing
    op with the §6.1 per-algorithm adaptation (a mix is classified by
    its tier: level 0 = IntraMix, level >= 1 = inter-tier gossip):

    - ``ce_fedavg``: every TierMix(0) is a device→edge upload
      (W_u/b_d2e); every TierMix(ℓ>=1, π) is π exchanges over tier ℓ's
      links (π·W/tier_bandwidth(ℓ) — b_e2e for the backhaul,
      ``b_tiers`` overrides above it).
    - ``hier_favg``: an InterGossip is a device→cloud upload (W/b_d2c)
      that *replaces* the coincident intra upload in its block.
    - ``fedavg``: IntraMix is the identity (free); InterGossip is the
      cloud upload (W_u/b_d2c).
    - ``local_edge``: IntraMix uploads to the edge; InterGossip is V
      again — covered by the same upload (free).
    - ``dec_local_sgd``: no edges; InterGossip(π) costs π·W/b_e2e.

    The canonical program reduces to ``rt.comm_time(algorithm, q, π)``.
    """
    return float(sum(block_comm_times(rt, algorithm, program,
                                      uplink_ratio)))


def block_comm_times(rt: RuntimeModel, algorithm: str,
                     program: "prg.RoundProgram",
                     uplink_ratio: float = 1.0) -> List[float]:
    """Per-block communication seconds — the same §6.1 pricing that
    :func:`program_comm_time` sums, kept as a list so the async timeline
    (:func:`async_program_timeline`) can charge each block's boundary on
    its own cluster's timeline instead of once per barrier."""
    hw = rt.hw
    W = rt.wl.model_bits(hw)
    Wu = W * uplink_ratio
    out: List[float] = []
    for b in program.blocks():
        n_intra = sum(m.level == 0 for m in b.mixes)
        inters = [m for m in b.mixes if m.level >= 1]
        if algorithm == "ce_fedavg":
            t = n_intra * Wu / hw.b_d2e
            t += sum(m.pi * W / hw.tier_bandwidth(m.level)
                     for m in inters)
        elif algorithm == "hier_favg":
            # cloud hop carries the full model (uncompressed), matching
            # RuntimeModel.comm_time's (q-1)·Wu/b_d2e + W/b_d2c
            charged = max(0, n_intra - len(inters)) if inters else n_intra
            t = charged * Wu / hw.b_d2e + len(inters) * W / hw.b_d2c
        elif algorithm == "fedavg":
            t = len(inters) * Wu / hw.b_d2c
        elif algorithm == "local_edge":
            t = n_intra * Wu / hw.b_d2e
        elif algorithm == "dec_local_sgd":
            t = sum(m.pi for m in inters) * W / hw.b_e2e
        else:
            raise ValueError(algorithm)
        out.append(float(t))
    return out


def paging_comm_time(rt: RuntimeModel, rows_in: int, rows_out: int,
                     bits_per_row: int) -> float:
    """Communication seconds of one streamed round's client paging
    (``core/clientstore.py``): every paged-in row is a device→edge
    *download* of the client's model and every paged-out row the
    matching upload, both over the d2e link — the attach/detach traffic
    a virtual-population round adds on top of its program's §6.1 terms.
    Cold-codec compression (``PopulationConfig.codec``) shrinks
    ``bits_per_row`` and therefore this charge, the same lever as
    uplink compression on qW/b_d2e."""
    return float((int(rows_in) + int(rows_out)) * int(bits_per_row)
                 / rt.hw.b_d2e)


# ---------------------------------------------------------------------------
# async bounded-staleness timelines
# ---------------------------------------------------------------------------

def async_adjacency(fl: FLConfig) -> np.ndarray:
    """(m, m) boolean cluster-dependency graph of the async wait rule.

    Cluster i's block-``b`` boundary must wait on cluster j's phase
    exactly when j's model can reach i through that boundary:
    ``local_edge`` never crosses edges (identity); ``fedavg`` /
    ``hier_favg`` aggregate globally (complete); ``ce_fedavg`` /
    ``dec_local_sgd`` read backhaul neighbors (tier-1 adjacency ∪ self).
    Depth>2 hierarchies are treated conservatively as complete — a
    ``TierMix(ℓ>=2)`` spans sibling groups of edges."""
    m = fl.num_clusters
    eye = np.eye(m, dtype=bool)
    if fl.algorithm == "local_edge":
        return eye
    hier = topo.Hierarchy.from_config(fl)
    if fl.algorithm in ("fedavg", "hier_favg") or hier.depth > 2:
        return np.ones((m, m), dtype=bool)
    adj = np.asarray(hier.adjacency(1, fl.topology, fl)) > 0
    return adj | eye


class AsyncEvent(NamedTuple):
    """One async phase advance: at ``time``, the ``clusters`` listed
    apply block ``block``'s mixing boundary together (equal completion
    times coalesce into one event — at s=0 every block is exactly one
    all-cluster event, the barrier degeneracy)."""
    time: float
    block: int
    clusters: Tuple[int, ...]


def _cluster_block_compute(rt: RuntimeModel, program: "prg.RoundProgram",
                           speeds, mask, labels: np.ndarray,
                           m: int) -> np.ndarray:
    """(m, B) per-cluster compute seconds: per block, max over the
    cluster's *active* devices of steps_d·C/c_d, 0 when the whole
    cluster dropped out (it still phase-advances — see
    :func:`async_program_timeline`)."""
    C = rt.wl.flops_per_step
    n = len(labels)
    if speeds is None:
        if rt.speeds and len(rt.speeds) == n:
            speeds = np.asarray(rt.speeds, float)
        else:
            speeds = np.full(n, rt.hw.device_flops)
    speeds = np.asarray(speeds, float)
    active = (np.ones(n, dtype=bool) if mask is None
              else np.asarray(mask) > 0)
    blocks = program.blocks()
    comp = np.zeros((m, len(blocks)))
    tau_dev = program.tau_dev
    for bi, b in enumerate(blocks):
        op = b.local
        if op.adaptive and tau_dev is not None:
            steps = np.minimum(np.asarray(tau_dev, float), float(op.tau))
        else:
            steps = np.full(n, float(op.tau))
        tvec = steps * C / speeds
        for c in range(m):
            sel = active & (labels == c)
            comp[c, bi] = float(tvec[sel].max()) if sel.any() else 0.0
    return comp


def async_program_timeline(rt: RuntimeModel, fl: FLConfig,
                           program: "prg.RoundProgram",
                           speeds=None, mask=None, labels=None,
                           staleness: int = 0,
                           uplink_ratio: float = 1.0,
                           carry: Optional[Dict[str, object]] = None
                           ) -> Dict[str, object]:
    """Per-cluster event timeline of one async bounded-staleness round.

    Each cluster advances through the program's blocks on its own
    timeline: block b starts when the cluster's own block b−1 completed
    AND every dependency neighbor (:func:`async_adjacency`) has cleared
    block b−s, so a boundary only ever reads models at most ``s`` blocks
    stale. ``staleness == 0`` is the global barrier: every block is one
    all-cluster event and the makespan telescopes to the barrier sum
    Σ_b (max_c comp + comm). For s ≥ 1 the makespan is never larger
    than the barrier's (each start time is bounded by the barrier's, by
    induction over blocks) — fast clusters hide stragglers' compute.

    ``carry`` couples consecutive rounds into ONE continuous block
    sequence — the source of async's wall-clock win, since within a
    single common-start round the slowest cluster's serial chain equals
    the barrier sum whenever per-cluster compute is block-constant. It
    holds the previous round's per-cluster end times (``"T_end"``) and
    last ``s`` completion columns (``"cols"``), so block b < s of this
    round waits on neighbors' block B−s+b of the PREVIOUS round instead
    of a global round barrier: clusters flow through the round boundary
    bounded-stale the whole way, and the per-round bottleneck cluster
    (sampling/mobility re-draw it every round) no longer paces everyone
    else. ``staleness == 0`` still barriers at ``T_end.max()``.

    Returns ``{"T", "start", "comp", "comm", "events", "makespan",
    "adjacency", "carry_out"}`` where ``T``/``start``/``comp`` are
    (m, B) arrays, ``comm`` is (B,), ``events`` is the
    (time, block)-sorted :class:`AsyncEvent` list the executor replays,
    ``makespan`` is the absolute max end time, and ``carry_out`` feeds
    the next round."""
    m = fl.num_clusters
    if labels is None:
        labels = np.repeat(np.arange(m), fl.devices_per_cluster)
    labels = np.asarray(labels)
    blocks = program.blocks()
    B = len(blocks)
    comm = np.asarray(block_comm_times(rt, fl.algorithm, program,
                                       uplink_ratio))
    comp = _cluster_block_compute(rt, program, speeds, mask, labels, m)
    adj = async_adjacency(fl)
    # a block only couples clusters when its boundary actually crosses
    # them: intra-only blocks (every mix at level 0) impose no
    # cross-cluster wait — their operators are cluster-block-diagonal,
    # so neighbors' phases are irrelevant until the next gossip block
    eye_m = np.eye(m, dtype=bool)
    block_adj = [adj if any(mx.level >= 1 for mx in blk.mixes) else eye_m
                 for blk in blocks]
    s = int(staleness)
    if carry is not None:
        t0 = np.asarray(carry["T_end"], float)
        cols = [np.asarray(c, float) for c in carry.get("cols", [])]
    else:
        t0 = np.zeros(m)
        cols = []
    T = np.zeros((m, B))
    start = np.zeros((m, B))
    for b in range(B):
        prev = T[:, b - 1] if b else t0
        if s == 0:
            start[:, b] = prev.max()
            T[:, b] = (start[:, b] + comp[:, b] + comm[b]).max()
        else:
            if b - s >= 0:
                ref = T[:, b - s]
            else:
                # reach back into the previous round's trailing columns
                gi = len(cols) + b - s
                ref = cols[gi] if 0 <= gi < len(cols) else None
            if ref is None:
                wait = np.zeros(m)
            else:
                ab = block_adj[b]
                wait = np.array([ref[ab[i]].max() for i in range(m)])
            start[:, b] = np.maximum(prev, wait)
            T[:, b] = start[:, b] + comp[:, b] + comm[b]
    events: List[AsyncEvent] = []
    for b in range(B):
        for t in np.unique(T[:, b]):
            cl = tuple(int(c) for c in np.nonzero(T[:, b] == t)[0])
            events.append(AsyncEvent(float(t), b, cl))
    # (time, block) ascending: simultaneous completions apply the
    # earlier block first, which is what bounds the realized phase gap
    # by s even under zero-compute ties
    events.sort(key=lambda e: (e.time, e.block))
    cols_out = (cols + [T[:, b].copy() for b in range(B)])[-max(s, 1):]
    return {"T": T, "start": start, "comp": comp, "comm": comm,
            "events": events, "makespan": float(T[:, -1].max()),
            "adjacency": adj,
            "carry_out": {"T_end": T[:, -1].copy(), "cols": cols_out}}


class EventClock:
    """Accumulates simulated wall time, one global round at a time."""

    def __init__(self, rt: RuntimeModel, fl: FLConfig):
        self.rt, self.fl = rt, fl
        self.now = 0.0
        # per-cluster async timeline carried across charge_program_async
        # rounds (None until the first async charge)
        self._async_carry: Optional[Dict[str, object]] = None

    def charge_round(self, speeds: Optional[Sequence[float]] = None,
                     uplink_ratio: float = 1.0) -> float:
        """Advance the clock by one global round of ``fl.algorithm``.

        ``speeds`` are the FLOP/s of the devices that participated this
        round (the max_k rule runs over them only); omitted means the
        RuntimeModel's homogeneous/default speeds. Returns the new time.
        """
        fl = self.fl
        comp = self.rt.compute_time(fl.q * fl.tau, speeds)
        comm = self.rt.comm_time(fl.algorithm, fl.q, fl.pi, uplink_ratio)
        self.now += comp + comm
        return self.now

    def charge_program(self, program: "prg.RoundProgram",
                       speeds: Optional[Sequence[float]] = None,
                       mask: Optional[np.ndarray] = None,
                       uplink_ratio: float = 1.0) -> float:
        """Advance the clock by one round of ``program`` — the per-op
        cost hook: each op is priced individually, so non-canonical
        schedules (adaptive τ_k, time-varying π_t) are charged what
        they actually execute. ``speeds`` here is the FULL per-device
        FLOP/s vector (``mask`` selects the participants), unlike
        ``charge_round``'s participant subset."""
        self.now += (program_compute_time(self.rt, program, speeds, mask)
                     + program_comm_time(self.rt, self.fl.algorithm,
                                         program, uplink_ratio))
        return self.now

    def charge_program_async(self, program: "prg.RoundProgram",
                             speeds: Optional[Sequence[float]] = None,
                             mask: Optional[np.ndarray] = None,
                             uplink_ratio: float = 1.0, *,
                             staleness: int,
                             labels: Optional[np.ndarray] = None) -> float:
        """Advance the clock by one *async* round of ``program``: the
        per-cluster timeline (:func:`async_program_timeline`) is carried
        ACROSS rounds, so fast clusters flow through round boundaries
        and the clock reads the max cluster end time instead of summing
        max-over-participants barriers. At ``staleness == 0`` this
        delegates to :meth:`charge_program` — exactly equal, not merely
        close, the barrier-degeneracy anchor ``tests/test_clock.py``
        asserts."""
        if staleness == 0:
            self._async_carry = None
            return self.charge_program(program, speeds, mask,
                                       uplink_ratio)
        if self._async_carry is None:
            self._async_carry = {
                "T_end": np.full(self.fl.num_clusters, self.now),
                "cols": []}
        tl = async_program_timeline(self.rt, self.fl, program, speeds,
                                    mask, labels, staleness,
                                    uplink_ratio,
                                    carry=self._async_carry)
        self._async_carry = tl["carry_out"]
        self.now = float(tl["makespan"])
        return self.now


def run_wall_clock(sim, rt: RuntimeModel, rounds: int, *,
                   eval_every: int = 1, eval_batch: int = 512,
                   uplink_ratio: float = 1.0,
                   async_staleness: Optional[int] = None,
                   ckpt_dir: Optional[str] = None,
                   ckpt_every: int = 0,
                   resume: bool = False
                   ) -> Dict[str, List[float]]:
    """Drive ``sim`` (an FLSimulator) for ``rounds`` global rounds under
    the event clock, returning a history dict with ``round``,
    ``wall_time``, ``acc``, ``loss`` and ``participants`` columns.

    With a scenario attached to the simulator, each round's compute charge
    is paced by the slowest device in that round's realized cohort
    (``ScenarioEngine.speed_multipliers`` × the profile's device_flops,
    masked to the cohort by ``charge_program``); without one, by the
    RuntimeModel's own speeds.

    Besides the *simulated* wall clock, the history records the
    *simulator's own* per-eval-window host seconds, split into
    ``page_s`` (time the host spent paging the streamed client store —
    fetch/stage/drain/commit, read from the sim's cumulative
    ``_page_seconds`` counter; 0 for resident engines) and
    ``compute_s`` (the window's remaining wall seconds) — the
    perf-trajectory instrumentation the benchmarks read to verify that,
    e.g., the pipelined streamed driver really overlaps paging with
    compute (docs/PERFORMANCE.md "Paging pipeline").

    ``async_staleness`` switches the loop to bounded-staleness execution:
    rounds run through ``sim.step_round_async`` (per-cluster phase
    advance, staleness-masked boundaries) and are charged the overlapped
    timeline's makespan via :meth:`EventClock.charge_program_async`.
    ``async_staleness=0`` reproduces the barrier loop exactly.

    ``ckpt_dir`` + ``ckpt_every`` make the loop crash-consistent: every
    k-th round the FULL run state (engine buffers, RNG key, scenario
    cursor, async carries, clock, schedule state, this history) is
    written atomically by :class:`repro.checkpoint.runckpt.RunCheckpoint`;
    ``resume=True`` restores the latest checkpoint (if any) and
    continues from its round — bit-identically to the uninterrupted
    run, since every per-round draw is keyed (``tests/test_resume.py``).

    With a fault-injecting scenario attached, each round additionally
    charges the straggler-timeout retry ladder
    (:func:`fault_compute_penalty`); outage/link-loss degradation is
    already inside the plan's operators and cohort.
    """
    clock = EventClock(rt, sim.fl)
    hist: Dict[str, List[float]] = {
        "round": [], "wall_time": [], "acc": [], "loss": [],
        "participants": [], "page_s": [], "compute_s": []}
    rc = None
    start_round = 0
    if ckpt_dir is not None:
        from repro.checkpoint.runckpt import RunCheckpoint
        rc = RunCheckpoint(ckpt_dir)
        if resume and rc.exists():
            meta = rc.restore(sim, clock=clock, hist=hist,
                              staleness=async_staleness)
            start_round = int(meta["round"])
    window_t0 = time.perf_counter()
    page0 = float(getattr(sim, "_page_seconds", 0.0))
    for r in range(start_round, rounds):
        if async_staleness is None:
            plan = sim.step_round()
        else:
            plan = sim.step_round_async(async_staleness, rt,
                                        uplink_ratio=uplink_ratio)
        program = getattr(sim, "last_program", None)
        if plan is not None:
            mult = np.asarray(sim.engine.speed_multipliers, float)
            fleet = mult * rt.hw.device_flops
            participants = int(plan.mask.sum())
        else:
            fleet = None
            participants = sim.fl.n
        if program is not None:
            # per-op pricing: adaptive/non-canonical programs are
            # charged exactly the ops they executed
            if async_staleness is None:
                t = clock.charge_program(
                    program, fleet, None if plan is None else plan.mask,
                    uplink_ratio)
            else:
                t = clock.charge_program_async(
                    program, fleet, None if plan is None else plan.mask,
                    uplink_ratio, staleness=async_staleness,
                    labels=None if plan is None else plan.labels)
        else:
            speeds = (None if fleet is None
                      else fleet[np.asarray(plan.mask) > 0])
            t = clock.charge_round(speeds, uplink_ratio)
        # straggler faults: price the retry ladder of timed-out devices
        # on top of the cohort's compute charge
        # streamed rounds page client state through the edge — charge
        # the page-in/page-out rows as d2e traffic
        paging = getattr(sim, "last_paging", None)
        if paging is not None:
            clock.now += paging_comm_time(rt, paging["rows_in"],
                                          paging["rows_out"],
                                          paging["bits_per_row"])
            t = clock.now
        fault = getattr(plan, "fault", None)
        if program is not None and fault is not None:
            fc = sim.engine.sc.faults
            pen = fault_compute_penalty(rt, program, fc, fault,
                                        speeds=fleet, mask=plan.mask)
            if pen > 0.0:
                clock.now += pen
                t = clock.now
        # online-schedule feedback: report the realized per-device step
        # counts and compute seconds this round to the schedule's
        # estimator (the "adaptive_tau_online" loop)
        est = getattr(getattr(sim, "_schedule_fn", None), "estimator",
                      None)
        if est is not None and program is not None:
            fleet_v = (fleet if fleet is not None
                       else np.full(sim.fl.n, rt.hw.device_flops))
            steps = program_device_steps(program, sim.fl.n)
            times = steps * rt.wl.flops_per_step / fleet_v
            est.observe(steps, times,
                        None if plan is None else plan.mask)
        if (r + 1) % eval_every == 0:
            wall = time.perf_counter() - window_t0
            page1 = float(getattr(sim, "_page_seconds", 0.0))
            page_s = page1 - page0
            acc, loss = sim.evaluate(eval_batch)
            hist["round"].append(r + 1)
            hist["wall_time"].append(t)
            hist["acc"].append(acc)
            hist["loss"].append(loss)
            hist["participants"].append(participants)
            hist["page_s"].append(page_s)
            hist["compute_s"].append(max(wall - page_s, 0.0))
            window_t0 = time.perf_counter()
            page0 = float(getattr(sim, "_page_seconds", 0.0))
        if rc is not None and ckpt_every and (r + 1) % ckpt_every == 0:
            rc.save(sim, round_idx=r + 1, clock=clock, hist=hist,
                    staleness=async_staleness)
    return hist


def time_to_accuracy(hist: Dict[str, List[float]],
                     target: float) -> Optional[float]:
    """First wall-clock time at which the evaluated accuracy reached
    ``target``, or None if the curve never got there."""
    for t, a in zip(hist["wall_time"], hist["acc"]):
        if a >= target:
            return float(t)
    return None


def summarize(hist: Dict[str, List[float]], target: float) -> str:
    """One-line human summary of a wall-clock curve."""
    tta = time_to_accuracy(hist, target)
    final = hist["acc"][-1] if hist["acc"] else float("nan")
    total = hist["wall_time"][-1] if hist["wall_time"] else 0.0
    reach = "never" if tta is None else f"{tta:,.0f}s"
    return (f"final_acc={final:.3f} total={total:,.0f}s "
            f"time_to_{target:.0%}={reach}")
