"""Production CE-FedAvg trainer: stacked federated replicas on a TPU mesh.

Parameters/optimizer state carry a leading replica axis R sharded over the
mesh's replica axes (``pod`` × ``data``); the ``model`` axis is tensor
parallel *within* a replica. One ``global_round`` = q edge rounds of
(τ local SGD steps + intra-cluster averaging) followed by π gossip steps of
inter-cluster mixing — a literal, sharded implementation of eq. (10)/(11).

Three aggregation backends (see ``core.gossip`` for the sparse two):
- ``dense``      (paper-faithful baseline): the full W_t operators applied
  as a (R,R)·(R,…) contraction over the replica axis — XLA lowers this to
  all-gathers over the replica axes.
- ``sparse``     (beyond-paper optimized): shard_map with
  ``psum(axis_index_groups=clusters)`` for V and π gossip rounds of
  weighted neighbor ``ppermute`` matchings realizing H on ANY connected
  backhaul graph — O(π·deg·|θ|) neighbor traffic and O(|θ|) peak memory
  instead of O(R·|θ|).
- ``ringweight`` (beyond-paper optimized): the exact H^π in M−1 weighted
  cyclic rotations — (M−1)·|θ| neighbor traffic, any topology.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import sharding as sh
from repro.config import ExperimentConfig, FLConfig
from repro.core import collectives as col
from repro.core import gossip as gsp
from repro.core import program as prg
from repro.core.cefedavg import FLSimulator, make_w_schedule, mix
from repro.core.groups import get_registry
from repro.core.modelbank import ModelBank
from repro.models import model as mdl
from repro.optim import make_optimizer, make_lr_schedule
from repro.optim.optimizers import apply_updates


# ---------------------------------------------------------------------------
# replica geometry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ReplicaGeometry:
    num_replicas: int          # R
    num_clusters: int          # M (global)
    devices_per_cluster: int
    clusters_per_pod: int
    num_pods: int

    @staticmethod
    def build(fl: FLConfig, mesh: Mesh) -> "ReplicaGeometry":
        data = mesh.shape["data"]
        pods = mesh.shape.get("pod", 1)
        R = data * pods
        M = fl.num_clusters
        assert R % M == 0, f"{R} replicas not divisible into {M} clusters"
        dpc = R // M
        assert data % dpc == 0, "clusters must not span pods"
        return ReplicaGeometry(R, M, dpc, data // dpc, pods)

    def cluster_of(self, r: int) -> int:
        return r // self.devices_per_cluster


# ---------------------------------------------------------------------------
# abstract init + logical axes (no allocation — works for 123B params)
# ---------------------------------------------------------------------------

def abstract_model(model_cfg):
    """(param ShapeDtypeStructs, logical axes) without allocating."""
    box = []

    def f(k):
        p, logical = mdl.init_model(k, model_cfg)
        box.append(logical)
        return p
    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, box[0]


def stacked_abstract(model_cfg, R: int):
    shapes, logical = abstract_model(model_cfg)
    stacked = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((R,) + tuple(s.shape), s.dtype),
        shapes)
    logical = sh.prepend_axis(logical, "replica")
    return stacked, logical


# ---------------------------------------------------------------------------
# sparse aggregation backends — see core.gossip for the schedule machinery
# ---------------------------------------------------------------------------

def sparse_intra_mix(params, specs, mesh: Mesh, geo: ReplicaGeometry):
    """Intra-cluster averaging (V) via grouped psum on the replica axis."""
    return gsp.apply_cluster_mean(params, specs, mesh, geo.num_clusters,
                                  geo.devices_per_cluster)


# ---------------------------------------------------------------------------
# trainer
# ---------------------------------------------------------------------------

class ShardedCEFedAvg:
    """Builds jittable FL step functions + shardings for one experiment."""

    def __init__(self, exp: ExperimentConfig, mesh: Mesh,
                 loss_fn: Optional[Callable] = None):
        self.exp = exp
        self.mesh = mesh
        self.geo = ReplicaGeometry.build(exp.fl, mesh)
        self.fl = dataclasses.replace(
            exp.fl, devices_per_cluster=self.geo.devices_per_cluster)
        self.sched = make_w_schedule(self.fl)
        self.model_cfg = exp.model
        self.loss_fn = loss_fn or (
            lambda p, b: mdl.lm_loss(self.model_cfg, p, b,
                                     remat=exp.train.remat))
        self.opt_init, self.opt_update = make_optimizer(exp.train)
        self.lr_fn = make_lr_schedule(exp.train)
        impl = exp.fl.gossip_impl
        # communicator groups: built once per (fl, mesh) and queried for
        # every tiered collective (means, gossip schedules)
        self.registry = get_registry(self.fl, mesh)
        self.gossip_schedule: Optional[gsp.GossipSchedule] = None
        if impl in ("sparse", "ringweight") and \
                self.fl.algorithm in ("ce_fedavg", "dec_local_sgd"):
            self.gossip_schedule = self.registry.gossip_schedule(
                1, self.fl.pi,
                mode="exact" if impl == "ringweight" else "rounds")
        self._build_specs()

    # -- specs ---------------------------------------------------------------
    def _build_specs(self):
        R = self.geo.num_replicas
        self.param_shapes, self.param_logical = stacked_abstract(
            self.model_cfg, R)
        self.param_specs = sh.resolve_specs(
            self.param_shapes, self.param_logical, self.mesh)
        opt_shapes = jax.eval_shape(
            lambda p: jax.vmap(self.opt_init)(p), self.param_shapes)
        # opt leaves mirror params (plus scalar counters -> replicate)
        self.opt_shapes = opt_shapes
        self.opt_specs = self._opt_specs(opt_shapes)

    def _opt_specs(self, opt_shapes):
        pleaves = {tuple(s.shape): spec for s, spec in zip(
            jax.tree.leaves(self.param_shapes),
            jax.tree.leaves(self.param_specs,
                            is_leaf=lambda x: isinstance(x, P)))}

        def one(s):
            return pleaves.get(tuple(s.shape), P())
        return jax.tree.map(one, opt_shapes)

    # -- init ----------------------------------------------------------------
    def init_fn(self):
        R = self.geo.num_replicas

        def init(key):
            keys = jax.random.split(key, R)
            params = jax.vmap(
                lambda k: mdl.init_model(k, self.model_cfg)[0])(keys)
            opt = jax.vmap(self.opt_init)(params)
            return params, opt
        return init

    # -- mixing --------------------------------------------------------------
    def _intra(self, params):
        if self.fl.algorithm == "fedavg":
            return params  # cloud FedAvg: no intra-cluster boundary
        if self.exp.fl.gossip_impl in ("sparse", "ringweight"):
            return self.registry.mean(params, self.param_specs, 0)
        return mix(self.sched.W_intra, params)

    def _inter(self, params):
        if self.gossip_schedule is not None:
            params = self.registry.mean(params, self.param_specs, 0)
            impl = self.exp.fl.gossip_impl
            return self.registry.gossip(
                params, self.param_specs, 1, self.fl.pi,
                mode="exact" if impl == "ringweight" else "rounds")
        return mix(self.sched.W_inter, params)

    # -- the steps -----------------------------------------------------------
    def make_global_round(self):
        """fn(params, opt_state, batch, step) -> (params, opt, metrics, step)

        batch: dict of arrays with leading (q, tau, R, ...) dims.
        """
        fl = self.fl
        loss_fn = self.loss_fn

        def replica_loss(params, mb):
            losses = jax.vmap(loss_fn)(params, mb)
            return jnp.sum(losses), losses

        grad_fn = jax.value_and_grad(replica_loss, has_aux=True)

        def local_step(carry, mb):
            params, opt, step = carry
            (_, losses), grads = grad_fn(params, mb)
            lr = self.lr_fn(step)
            upd, opt = jax.vmap(
                self.opt_update, in_axes=(0, 0, 0, None)
            )(grads, opt, params, lr)
            params = apply_updates(params, upd)
            return (params, opt, step + 1), jnp.mean(losses)

        def edge_round(carry, ebatch):
            carry, losses = jax.lax.scan(local_step, carry, ebatch)
            params, opt, step = carry
            params = self._intra(params)
            return (params, opt, step), losses

        def global_round(params, opt, batch, step):
            (params, opt, step), losses = jax.lax.scan(
                edge_round, (params, opt, step), batch)
            params = self._inter(params)
            return params, opt, {"loss": jnp.mean(losses)}, step

        return global_round

    # -- component steps (analysis-mode lowering units) -----------------------
    def make_local_step(self):
        """One local SGD step on one microbatch (R,B,...); no mixing."""
        loss_fn = self.loss_fn

        def replica_loss(params, mb):
            losses = jax.vmap(loss_fn)(params, mb)
            return jnp.sum(losses), losses

        grad_fn = jax.value_and_grad(replica_loss, has_aux=True)

        def local_step(params, opt, mb, step):
            (_, losses), grads = grad_fn(params, mb)
            lr = self.lr_fn(step)
            upd, opt = jax.vmap(
                self.opt_update, in_axes=(0, 0, 0, None)
            )(grads, opt, params, lr)
            params = apply_updates(params, upd)
            return params, opt, jnp.mean(losses), step + 1
        return local_step

    def make_intra_fn(self):
        return lambda params: self._intra(params)

    def make_inter_fn(self):
        return lambda params: self._inter(params)

    def microbatch_specs(self, mb_shapes) -> Any:
        """Specs for (R, B, ...) microbatches."""
        raxes = sh.replica_axes(self.mesh)
        rspec = tuple(raxes) if len(raxes) > 1 else (raxes[0] if raxes
                                                     else None)

        def one(s):
            return P(rspec, *([None] * (len(s.shape) - 1)))
        return jax.tree.map(one, mb_shapes)

    # -- sharding helpers for jit --------------------------------------------
    def batch_specs(self, batch_shapes) -> Any:
        """Specs for (q, tau, R, B, ...) batches: R over replica axes."""
        raxes = sh.replica_axes(self.mesh)
        rspec = tuple(raxes) if len(raxes) > 1 else (raxes[0] if raxes
                                                     else None)

        def one(s):
            return P(None, None, rspec, *([None] * (len(s.shape) - 3)))
        return jax.tree.map(one, batch_shapes)

    def in_shardings(self, batch_shapes):
        ns = lambda t: jax.tree.map(  # noqa: E731
            lambda p: NamedSharding(self.mesh, p), t,
            is_leaf=lambda x: isinstance(x, P))
        return (ns(self.param_specs), ns(self.opt_specs),
                ns(self.batch_specs(batch_shapes)),
                NamedSharding(self.mesh, P()))

    def out_shardings(self):
        ns = lambda t: jax.tree.map(  # noqa: E731
            lambda p: NamedSharding(self.mesh, p), t,
            is_leaf=lambda x: isinstance(x, P))
        return (ns(self.param_specs), ns(self.opt_specs),
                NamedSharding(self.mesh, P()),
                NamedSharding(self.mesh, P()))


# ---------------------------------------------------------------------------
# sharded ModelBank engine: device-parallel flat-bank CE-FedAvg
# ---------------------------------------------------------------------------

class ShardedBankCEFedAvg(FLSimulator):
    """Device-parallel flat-bank CE-FedAvg: the :class:`FLSimulator`
    ModelBank engine with the ``(n, T)`` bank row-sharded over the mesh's
    replica axes (``pod`` × ``data``) — one bank row (one paper device
    model) per mesh device, for the whole run.

    Params, momentum and the EF-residual live as contiguous per-device
    ``(1, T)`` bank shards; the jitted global round is ONE ``shard_map``
    whose q·τ local SGD steps run on the local row (pytree views exist
    only inside the per-row ``apply_fn`` call) and whose mixing
    boundaries never materialize the bank on one device:

    - **static schedule** (no scenario, ``ce_fedavg``): intra-cluster
      averaging is a grouped ``psum`` over the cluster's rows
      (:func:`repro.core.gossip.cluster_mean_in_body`); the coincident
      τ/qτ boundary fuses that psum with π gossip rounds of
      :class:`repro.core.gossip.GossipSchedule`'s edge-colored
      ``ppermute`` matchings in the same pass
      (:func:`repro.core.gossip.gossip_in_body`) — O(π·deg·T) neighbor
      traffic, mirroring the fused single-pass
      ``gossip_mix_rows(W_inter @ W_intra, ·)`` boundary of the
      single-device bank.
    - **scenario rounds** (masked / mobility / non-gossip baselines): the
      exact per-round dense operators are row-applied by R−1 weighted
      cyclic rotations (:func:`repro.core.gossip.dense_mix_rows`), which
      handles arbitrary asymmetric row-stochastic W_t.

    The legacy per-leaf pytree trainer (:class:`ShardedCEFedAvg`, and
    ``FLSimulator(bank=False)`` on one device) stays as the parity
    oracle. Semantics — key schedule, batch draws, SGD+momentum updates,
    mixing algebra — match the single-device ModelBank engine row for
    row, so trajectories agree to float tolerance (asserted in
    ``tests/test_sharded_bank.py``).

    Tiered collectives come from the :class:`repro.core.groups.
    GroupRegistry` built once for ``(fl, mesh)``: any ``TierMix(ℓ)`` —
    ``IntraMix`` (tier 0), ``InterGossip`` (tier 1), or deeper tiers of
    an ``fl.hierarchy`` like (2, 2, 2) — lowers to that tier's grouped
    psum plus its cached block-diagonal gossip matchings, so a depth-3
    round still contains no all-gather.

    Constraints: ``fl.n`` must equal the replica-axis device count (one
    row per device), and any ``model`` mesh axis must have size 1 (bank
    rows are not tensor-parallel). The never-materialize guarantee
    covers init as well as the steady-state round: the bank is built
    per-shard via ``ModelBank.from_model_sharded``
    (``jax.make_array_from_callback``), each device filling only its own
    ``(1, T)`` rows — the multi-host-correct path. Checkpoint *restore*
    keeps the same guarantee: ``RunCheckpoint`` writes the buffers back
    through :meth:`ModelBank.load_rows`, which fills each device's row
    shard against the resident sharding. Fault injection
    (``ScenarioConfig.faults``) needs no sharded special-casing either:
    a scenario engine forces the dense-operator path (``structured``
    False below), so outage-gated / link-degraded mixing matrices flow
    through ``dense_mix_rows`` like any other row-stochastic operator.
    """

    def __init__(self, init_fn: Callable, apply_fn: Callable, fl, data,
                 mesh: Mesh, **kw):
        assert kw.pop("bank", True), \
            "ShardedBankCEFedAvg IS the bank engine; use FLSimulator or " \
            "ShardedCEFedAvg for the pytree engines"
        self.mesh = mesh
        raxes = col.replica_axis_names(mesh)
        assert raxes, f"mesh {mesh.axis_names} has no replica axes"
        R = col.flat_axis_size(mesh)
        assert fl.n == R, \
            f"need one bank row per replica device: n={fl.n}, devices={R}"
        if "model" in mesh.axis_names:
            assert mesh.shape["model"] == 1, \
                "bank rows are not tensor-parallel (model axis must be 1)"
        self._rspec = raxes if len(raxes) > 1 else raxes[0]
        self._row_sharding = NamedSharding(mesh, P(self._rspec, None))
        self.registry = get_registry(fl, mesh)
        placed = {}
        for key, v in data.items():
            spec = P(self._rspec) if key in ("xs", "ys") else P()
            placed[key] = jax.device_put(jnp.asarray(v),
                                         NamedSharding(mesh, spec))
        super().__init__(init_fn, apply_fn, fl, placed, bank=True, **kw)
        # rows are pinned to devices: no cohort compaction; scenario
        # rounds run mask-frozen on the full (sharded) bank instead
        self._compact_enabled = False

    def _make_bank(self, one, n: int, with_residual: bool) -> ModelBank:
        """Per-shard init: each device fills its own bank rows directly
        (``jax.make_array_from_callback``); the full (n, T) bank never
        exists on one device, init included."""
        return ModelBank.from_model_sharded(
            one, n, self._row_sharding, with_residual=with_residual)

    # -- the sharded round ---------------------------------------------------
    def _lower_compact(self, program):
        """Never dispatched: rows are pinned to devices, so compaction
        (a cross-device cohort gather) is disabled in ``__init__``."""
        raise AssertionError(
            "ShardedBankCEFedAvg disables cohort compaction")

    def _lower_flat(self, program, block_keyed: bool = False):
        """Compile a :class:`repro.core.program.RoundProgram` to ONE
        jitted ``shard_map`` global round over the bank shards — the
        sharded lowering of the IR, same operand schedule as the
        single-device flat lowering so ``step_round`` dispatches
        identically:

        - ``LocalSteps`` → q·τ local SGD steps on the local row (the
          single-device key/batch schedule, with per-device ``tau_dev``
          cutoffs for adaptive programs);
        - ``TierMix(ℓ, π)`` (``IntraMix`` = tier 0, ``InterGossip`` =
          tier 1) → the registry tier's grouped ``psum`` plus, for
          ℓ >= 1, π gossip rounds of that tier's edge-colored
          ``ppermute`` matchings (one cached ``GossipSchedule`` per
          distinct (ℓ, π) in the program), or dense masked operators
          via weighted rotations on the scenario/non-gossip path.
          Means dedupe through the ``usize`` uniformity tracker (a row
          already uniform over a tier-ℓ' ⊇ tier-ℓ group needs no new
          psum), which is exactly how the fused τ∘qτ boundary stays a
          single psum + gossip pass at any depth.

        Buffers are donated: peak per-device memory stays ~1× the
        (1, T) bank shard per resident buffer.

        ``block_keyed`` is the single-block async-event variant (see
        ``FLSimulator._lower_flat``): the passed key is consumed
        directly, and the dense-operator path is forced — staleness-
        masked operators are arbitrary row-stochastic matrices the
        structured collectives can't express."""
        fl = self.fl
        n = self.sched.n
        mesh = self.mesh
        comp, dp = self.compression, self.dp
        with_res = self.bank.residual is not None
        xs, ys = self.data["xs"], self.data["ys"]
        N = xs.shape[1]
        layout = self.bank.layout
        batch, momentum, lr0 = self.batch, self.momentum, self.lr
        segments = layout.segments
        plans = prg.lowering_plan(program, fuse=True)
        runs = prg.block_runs(plans)
        nblocks = len(plans)
        assert not block_keyed or nblocks == 1, \
            "block_keyed lowers single-block programs"
        adaptive = program.adaptive
        goffs, nmats = [], 0
        for bp, _cnt in runs:
            goffs.append(nmats)
            nmats += len(bp.groups)
        # static ce_fedavg schedule -> structured collectives (registry
        # tier psums + gossip matchings); anything time-varying or
        # non-gossip — including async staleness-masked operators —
        # -> exact dense operators via weighted rotations
        structured = (self.engine is None and fl.algorithm == "ce_fedavg"
                      and not block_keyed)
        registry = self.registry
        gsize = tuple(registry.tier(lvl).group_size
                      for lvl in range(registry.depth))
        gscheds = {}
        if structured:
            for bp in plans:
                for g in bp.groups:
                    for op in g.ops:
                        key_lp = (op.level, op.pi)
                        if (op.level >= 1 and key_lp not in gscheds
                                and registry.hier.num_siblings(
                                    op.level) > 1):
                            gscheds[key_lp] = registry.gossip_schedule(
                                op.level, op.pi)

        def loss_row(row, x, y):
            return self._loss(layout.unflatten_one(row), x, y)
        grad_row = jax.grad(loss_row)

        def body(*flat):
            Y, M = flat[0], flat[1]
            i = 2
            Rres = None
            if with_res:
                Rres, i = flat[2], 3
            key = flat[i]
            mats = flat[i + 1:i + 1 + nmats]
            i += 1 + nmats
            td = None
            if adaptive:
                td, i = flat[i], i + 1
            mask, xs_l, ys_l = flat[i], flat[i + 1], flat[i + 2]
            my = col.flat_axis_index(mesh)
            act = jax.lax.dynamic_slice_in_dim(
                (mask > 0.5)[:, None], my, 1, 0)          # (1, 1)
            td_my = (jax.lax.dynamic_slice_in_dim(td, my, 1, 0)
                     if adaptive else None)               # (1,)
            x0, y0 = xs_l[0], ys_l[0]

            def make_local_step(op):
                lr = lr0 * op.lr_scale

                def local_step(carry, xs_):
                    if op.adaptive:
                        k, s = xs_
                        a = act & (s < td_my[:, None])
                    else:
                        k, a = xs_, act
                    Y, M = carry
                    idx = jax.random.randint(k, (n, batch), 0, N)
                    ib = jax.lax.dynamic_slice_in_dim(idx, my, 1, 0)[0]
                    G = grad_row(Y[0], x0[ib], y0[ib])[None]
                    M = jnp.where(a, momentum * M + G, M)
                    Y = jnp.where(a, Y - lr * M, Y)
                    return (Y, M), None
                return local_step

            def train_block(Y, M, k1, op):
                keys = jax.random.split(k1, op.tau)
                xs_ = (keys, jnp.arange(op.tau)) if op.adaptive else keys
                (Y, M), _ = jax.lax.scan(make_local_step(op), (Y, M), xs_)
                return Y, M

            def upload_row(d_row, r_row, key, bp):
                """Device-side upload transform of the LOCAL delta row —
                same per-row key schedule as the single-device engine
                (row i of split(key, n)), so uploads are bit-matched."""
                if bp.privatize and dp is not None and dp.enabled:
                    from repro.core.privacy import privatize_update_flat
                    keys = jax.random.split(key, n)
                    d_row = privatize_update_flat(d_row, dp, keys[my])
                if bp.compress and comp is not None and comp.kind != "none":
                    from repro.core.compress import compress_flat
                    keys = jax.random.split(jax.random.fold_in(key, 1), n)
                    d_row, r_row = compress_flat(comp, d_row, r_row,
                                                 keys[my], segments)
                return d_row, r_row

            def apply_group(Y, g, Wg, usize):
                """Lower one MixGroup. ``usize`` tracks the tier group
                size at which rows are already uniform (1 = not), so
                consecutive tier means dedupe into one psum (V
                idempotent, W_inter's leading B^T…B, and — contiguous
                nesting — any coarser tier implying the finer ones):
                the fused τ∘qτ boundary at any depth. Gossip at tier ℓ
                keeps rows node-uniform at ℓ but breaks coarser
                uniformity, so it resets ``usize`` to its tier's
                size."""
                if not structured:
                    return gsp.dense_mix_rows(Wg, Y, mesh), 1
                for op in g.ops:
                    s = gsize[op.level]
                    if usize < s:
                        Y = registry.mean_in_body(Y, op.level)
                        usize = s
                    if op.level >= 1:
                        gs = gscheds.get((op.level, op.pi))
                        if gs is not None:
                            Y = gsp.gossip_in_body(gs, mesh, Y)
                            usize = s
                return Y, usize

            def run_block(bp, goff, Y, M, Rres, k1):
                op = bp.local
                if not bp.upload:
                    Y, M = train_block(Y, M, k1, op)
                    usize = 1
                    for j, g in enumerate(bp.groups):
                        Y, usize = apply_group(Y, g, mats[goff + j],
                                               usize)
                    return Y, M, Rres
                Y0 = Y
                Y, M = train_block(Y, M, k1, op)
                d_row, r_row = upload_row(
                    (Y - Y0)[0], None if Rres is None else Rres[0],
                    jax.random.fold_in(k1, 7), bp)
                Rres = Rres if r_row is None else r_row[None]
                d, _ = apply_group(d_row[None], bp.groups[0], mats[goff],
                                   1)
                Y = Y0 + d
                usize = 1
                for j in range(1, len(bp.groups)):
                    Y, usize = apply_group(Y, bp.groups[j],
                                           mats[goff + j], usize)
                return Y, M, Rres

            keys = (key[None] if block_keyed
                    else jax.random.split(key, nblocks))
            ki = 0
            for (bp, count), goff in zip(runs, goffs):
                bkeys = keys[ki:ki + count]
                ki += count
                if count > 1:
                    def qbody(carry, k1, bp=bp, goff=goff):
                        Y, M, Rr = carry
                        Y, M, Rr = run_block(bp, goff, Y, M, Rr, k1)
                        return (Y, M, Rr), None
                    (Y, M, Rres), _ = jax.lax.scan(qbody, (Y, M, Rres),
                                                   bkeys)
                else:
                    Y, M, Rres = run_block(bp, goff, Y, M, Rres, bkeys[0])
            return (Y, M, Rres) if with_res else (Y, M)

        row = P(self._rspec, None)
        rep = P()
        nbank = 3 if with_res else 2
        nextra = 1 + nmats + (1 if adaptive else 0) + 1  # key+mats+td+mask
        in_specs = (row,) * nbank + (rep,) * nextra + (P(self._rspec),) * 2
        out_specs = (row,) * nbank
        mapped = col.shard_map(body, mesh, in_specs, out_specs)

        @functools.partial(jax.jit,
                           donate_argnums=(0, 1, 2) if with_res else (0, 1))
        def global_round(Y, M, R, key, args, mask):
            extras = tuple(args.mats)
            if adaptive:
                extras = extras + (args.tau_dev,)
            if with_res:
                return mapped(Y, M, R, key, *extras, mask, xs, ys)
            Y, M = mapped(Y, M, key, *extras, mask, xs, ys)
            return Y, M, R

        return global_round


# ---------------------------------------------------------------------------
# sharded streamed engine: row-sharded hot slab over a virtual population
# ---------------------------------------------------------------------------

class ShardedStreamedBank(FLSimulator):
    """Streamed client-store engine (ISSUE 9) with the per-round hot
    slab row-sharded over the mesh's replica axes.

    Where :class:`ShardedBankCEFedAvg` pins one *enumerated* device row
    per mesh device for the whole run, this engine scales past
    enumeration: the population lives in per-shard cold stores
    (``client_id % R`` routing, one :class:`~repro.core.clientstore.
    ClientStore` shard per bank shard) and only each round's working
    set — cohort + one representative lane per cluster — exists on the
    accelerators, as an ``(S, T)`` slab placed per-shard via
    ``ModelBank.from_rows(..., sharding=...)`` so no single device ever
    holds the whole working set. ``min_bucket = R`` keeps every slab
    bucket divisible by the shard count (even row shards).

    The slab round itself is the ordinary ``_lower_streamed`` lowering:
    mixing is a cohort-sized ``(S, S)·(S, T)`` contraction that GSPMD
    partitions over the row shards — at streamed scale the slab, not
    the population, bounds the communication, so no structured
    collective path is needed. Requires a scenario carrying a
    ``PopulationConfig`` (virtual clients are what make per-shard cold
    stores meaningful).

    ``pipeline=True`` (forwarded to :class:`FLSimulator`) composes with
    the sharding: the pipelined driver stages encoded rows with
    ``jax.device_put(..., slab_sharding)``, so prefetched cohorts land
    row-sharded and the on-device codec kernels run per shard."""

    def __init__(self, init_fn: Callable, apply_fn: Callable, fl, data,
                 mesh: Mesh, **kw):
        assert kw.pop("bank", True), \
            "ShardedStreamedBank IS a bank engine"
        scenario = kw.get("scenario")
        assert scenario is not None and scenario.population is not None, \
            "ShardedStreamedBank streams a virtual population " \
            "(ScenarioConfig.population)"
        self.mesh = mesh
        raxes = col.replica_axis_names(mesh)
        assert raxes, f"mesh {mesh.axis_names} has no replica axes"
        R = col.flat_axis_size(mesh)
        if "model" in mesh.axis_names:
            assert mesh.shape["model"] == 1, \
                "slab rows are not tensor-parallel (model axis must be 1)"
        self._rspec = raxes if len(raxes) > 1 else raxes[0]
        super().__init__(
            init_fn, apply_fn, fl, data, bank=True,
            slab_sharding=NamedSharding(mesh, P(self._rspec, None)),
            store_shards=R, min_bucket=R, **kw)
        assert self._streamed
        self._compact_enabled = False


# ---------------------------------------------------------------------------
# serving (non-FL: global/edge model)
# ---------------------------------------------------------------------------

def make_prefill_fn(model_cfg):
    def prefill(params, batch):
        logits, _ = mdl.forward(model_cfg, params, batch)
        return logits
    return prefill


def make_decode_fn(model_cfg):
    def decode(params, cache, tokens, pos):
        return mdl.decode_step(model_cfg, params, cache, tokens, pos)
    return decode


def serve_specs(model_cfg, mesh: Mesh, batch: int, seq: int):
    """(param specs, cache specs) for single-model serving."""
    shapes, logical = abstract_model(model_cfg)
    pspecs = sh.resolve_specs(shapes, logical, mesh)
    cache_shapes = jax.eval_shape(
        lambda: mdl.init_decode_cache(model_cfg, batch, seq)[0])
    _, cache_logical = mdl.init_decode_cache(model_cfg, 1, 1)
    # decode cache sharding: batch over data when divisible, else kv_seq
    rules = dict(sh.DEFAULT_RULES)
    if batch % mesh.shape["data"] != 0:
        rules["batch"] = None
        rules["kv_seq"] = "data"
    cspecs = sh.resolve_specs(cache_shapes, cache_logical, mesh, rules)
    return shapes, pspecs, cache_shapes, cspecs
