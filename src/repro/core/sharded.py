"""Production CE-FedAvg trainer: stacked federated replicas on a TPU mesh.

Parameters/optimizer state carry a leading replica axis R sharded over the
mesh's replica axes (``pod`` × ``data``); the ``model`` axis is tensor
parallel *within* a replica. One ``global_round`` = q edge rounds of
(τ local SGD steps + intra-cluster averaging) followed by π gossip steps of
inter-cluster mixing — a literal, sharded implementation of eq. (10)/(11).

Three aggregation backends (see ``core.gossip`` for the sparse two):
- ``dense``      (paper-faithful baseline): the full W_t operators applied
  as a (R,R)·(R,…) contraction over the replica axis — XLA lowers this to
  all-gathers over the replica axes.
- ``sparse``     (beyond-paper optimized): shard_map with
  ``psum(axis_index_groups=clusters)`` for V and π gossip rounds of
  weighted neighbor ``ppermute`` matchings realizing H on ANY connected
  backhaul graph — O(π·deg·|θ|) neighbor traffic and O(|θ|) peak memory
  instead of O(R·|θ|).
- ``ringweight`` (beyond-paper optimized): the exact H^π in M−1 weighted
  cyclic rotations — (M−1)·|θ| neighbor traffic, any topology.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

from repro import sharding as sh
from repro.config import ExperimentConfig, FLConfig
from repro.core import gossip as gsp
from repro.core.cefedavg import make_w_schedule, mix
from repro.models import model as mdl
from repro.optim import make_optimizer, make_lr_schedule
from repro.optim.optimizers import apply_updates


# ---------------------------------------------------------------------------
# replica geometry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ReplicaGeometry:
    num_replicas: int          # R
    num_clusters: int          # M (global)
    devices_per_cluster: int
    clusters_per_pod: int
    num_pods: int

    @staticmethod
    def build(fl: FLConfig, mesh: Mesh) -> "ReplicaGeometry":
        data = mesh.shape["data"]
        pods = mesh.shape.get("pod", 1)
        R = data * pods
        M = fl.num_clusters
        assert R % M == 0, f"{R} replicas not divisible into {M} clusters"
        dpc = R // M
        assert data % dpc == 0, "clusters must not span pods"
        return ReplicaGeometry(R, M, dpc, data // dpc, pods)

    def cluster_of(self, r: int) -> int:
        return r // self.devices_per_cluster


# ---------------------------------------------------------------------------
# abstract init + logical axes (no allocation — works for 123B params)
# ---------------------------------------------------------------------------

def abstract_model(model_cfg):
    """(param ShapeDtypeStructs, logical axes) without allocating."""
    box = []

    def f(k):
        p, logical = mdl.init_model(k, model_cfg)
        box.append(logical)
        return p
    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, box[0]


def stacked_abstract(model_cfg, R: int):
    shapes, logical = abstract_model(model_cfg)
    stacked = jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((R,) + tuple(s.shape), s.dtype),
        shapes)
    logical = sh.prepend_axis(logical, "replica")
    return stacked, logical


# ---------------------------------------------------------------------------
# sparse aggregation backends — see core.gossip for the schedule machinery
# ---------------------------------------------------------------------------

def sparse_intra_mix(params, specs, mesh: Mesh, geo: ReplicaGeometry):
    """Intra-cluster averaging (V) via grouped psum on the replica axis."""
    return gsp.apply_cluster_mean(params, specs, mesh, geo.num_clusters,
                                  geo.devices_per_cluster)


# ---------------------------------------------------------------------------
# trainer
# ---------------------------------------------------------------------------

class ShardedCEFedAvg:
    """Builds jittable FL step functions + shardings for one experiment."""

    def __init__(self, exp: ExperimentConfig, mesh: Mesh,
                 loss_fn: Optional[Callable] = None):
        self.exp = exp
        self.mesh = mesh
        self.geo = ReplicaGeometry.build(exp.fl, mesh)
        self.fl = dataclasses.replace(
            exp.fl, devices_per_cluster=self.geo.devices_per_cluster)
        self.sched = make_w_schedule(self.fl)
        self.model_cfg = exp.model
        self.loss_fn = loss_fn or (
            lambda p, b: mdl.lm_loss(self.model_cfg, p, b,
                                     remat=exp.train.remat))
        self.opt_init, self.opt_update = make_optimizer(exp.train)
        self.lr_fn = make_lr_schedule(exp.train)
        impl = exp.fl.gossip_impl
        self.gossip_schedule: Optional[gsp.GossipSchedule] = None
        if impl in ("sparse", "ringweight") and \
                self.fl.algorithm in ("ce_fedavg", "dec_local_sgd"):
            self.gossip_schedule = gsp.GossipSchedule.build(
                self.sched.H, self.fl.pi, self.geo.devices_per_cluster,
                mode="exact" if impl == "ringweight" else "rounds")
        self._build_specs()

    # -- specs ---------------------------------------------------------------
    def _build_specs(self):
        R = self.geo.num_replicas
        self.param_shapes, self.param_logical = stacked_abstract(
            self.model_cfg, R)
        self.param_specs = sh.resolve_specs(
            self.param_shapes, self.param_logical, self.mesh)
        opt_shapes = jax.eval_shape(
            lambda p: jax.vmap(self.opt_init)(p), self.param_shapes)
        # opt leaves mirror params (plus scalar counters -> replicate)
        self.opt_shapes = opt_shapes
        self.opt_specs = self._opt_specs(opt_shapes)

    def _opt_specs(self, opt_shapes):
        pleaves = {tuple(s.shape): spec for s, spec in zip(
            jax.tree.leaves(self.param_shapes),
            jax.tree.leaves(self.param_specs,
                            is_leaf=lambda x: isinstance(x, P)))}

        def one(s):
            return pleaves.get(tuple(s.shape), P())
        return jax.tree.map(one, opt_shapes)

    # -- init ----------------------------------------------------------------
    def init_fn(self):
        R = self.geo.num_replicas

        def init(key):
            keys = jax.random.split(key, R)
            params = jax.vmap(
                lambda k: mdl.init_model(k, self.model_cfg)[0])(keys)
            opt = jax.vmap(self.opt_init)(params)
            return params, opt
        return init

    # -- mixing --------------------------------------------------------------
    def _intra(self, params):
        if self.fl.algorithm == "fedavg":
            return params  # cloud FedAvg: no intra-cluster boundary
        if self.exp.fl.gossip_impl in ("sparse", "ringweight"):
            return sparse_intra_mix(params, self.param_specs, self.mesh,
                                    self.geo)
        return mix(self.sched.W_intra, params)

    def _inter(self, params):
        if self.gossip_schedule is not None:
            params = sparse_intra_mix(params, self.param_specs, self.mesh,
                                      self.geo)
            return gsp.apply_gossip(self.gossip_schedule, params,
                                    self.param_specs, self.mesh)
        return mix(self.sched.W_inter, params)

    # -- the steps -----------------------------------------------------------
    def make_global_round(self):
        """fn(params, opt_state, batch, step) -> (params, opt, metrics, step)

        batch: dict of arrays with leading (q, tau, R, ...) dims.
        """
        fl = self.fl
        loss_fn = self.loss_fn

        def replica_loss(params, mb):
            losses = jax.vmap(loss_fn)(params, mb)
            return jnp.sum(losses), losses

        grad_fn = jax.value_and_grad(replica_loss, has_aux=True)

        def local_step(carry, mb):
            params, opt, step = carry
            (_, losses), grads = grad_fn(params, mb)
            lr = self.lr_fn(step)
            upd, opt = jax.vmap(
                self.opt_update, in_axes=(0, 0, 0, None)
            )(grads, opt, params, lr)
            params = apply_updates(params, upd)
            return (params, opt, step + 1), jnp.mean(losses)

        def edge_round(carry, ebatch):
            carry, losses = jax.lax.scan(local_step, carry, ebatch)
            params, opt, step = carry
            params = self._intra(params)
            return (params, opt, step), losses

        def global_round(params, opt, batch, step):
            (params, opt, step), losses = jax.lax.scan(
                edge_round, (params, opt, step), batch)
            params = self._inter(params)
            return params, opt, {"loss": jnp.mean(losses)}, step

        return global_round

    # -- component steps (analysis-mode lowering units) -----------------------
    def make_local_step(self):
        """One local SGD step on one microbatch (R,B,...); no mixing."""
        loss_fn = self.loss_fn

        def replica_loss(params, mb):
            losses = jax.vmap(loss_fn)(params, mb)
            return jnp.sum(losses), losses

        grad_fn = jax.value_and_grad(replica_loss, has_aux=True)

        def local_step(params, opt, mb, step):
            (_, losses), grads = grad_fn(params, mb)
            lr = self.lr_fn(step)
            upd, opt = jax.vmap(
                self.opt_update, in_axes=(0, 0, 0, None)
            )(grads, opt, params, lr)
            params = apply_updates(params, upd)
            return params, opt, jnp.mean(losses), step + 1
        return local_step

    def make_intra_fn(self):
        return lambda params: self._intra(params)

    def make_inter_fn(self):
        return lambda params: self._inter(params)

    def microbatch_specs(self, mb_shapes) -> Any:
        """Specs for (R, B, ...) microbatches."""
        raxes = sh.replica_axes(self.mesh)
        rspec = tuple(raxes) if len(raxes) > 1 else (raxes[0] if raxes
                                                     else None)

        def one(s):
            return P(rspec, *([None] * (len(s.shape) - 1)))
        return jax.tree.map(one, mb_shapes)

    # -- sharding helpers for jit --------------------------------------------
    def batch_specs(self, batch_shapes) -> Any:
        """Specs for (q, tau, R, B, ...) batches: R over replica axes."""
        raxes = sh.replica_axes(self.mesh)
        rspec = tuple(raxes) if len(raxes) > 1 else (raxes[0] if raxes
                                                     else None)

        def one(s):
            return P(None, None, rspec, *([None] * (len(s.shape) - 3)))
        return jax.tree.map(one, batch_shapes)

    def in_shardings(self, batch_shapes):
        ns = lambda t: jax.tree.map(  # noqa: E731
            lambda p: NamedSharding(self.mesh, p), t,
            is_leaf=lambda x: isinstance(x, P))
        return (ns(self.param_specs), ns(self.opt_specs),
                ns(self.batch_specs(batch_shapes)),
                NamedSharding(self.mesh, P()))

    def out_shardings(self):
        ns = lambda t: jax.tree.map(  # noqa: E731
            lambda p: NamedSharding(self.mesh, p), t,
            is_leaf=lambda x: isinstance(x, P))
        return (ns(self.param_specs), ns(self.opt_specs),
                NamedSharding(self.mesh, P()),
                NamedSharding(self.mesh, P()))


# ---------------------------------------------------------------------------
# serving (non-FL: global/edge model)
# ---------------------------------------------------------------------------

def make_prefill_fn(model_cfg):
    def prefill(params, batch):
        logits, _ = mdl.forward(model_cfg, params, batch)
        return logits
    return prefill


def make_decode_fn(model_cfg):
    def decode(params, cache, tokens, pos):
        return mdl.decode_step(model_cfg, params, cache, tokens, pos)
    return decode


def serve_specs(model_cfg, mesh: Mesh, batch: int, seq: int):
    """(param specs, cache specs) for single-model serving."""
    shapes, logical = abstract_model(model_cfg)
    pspecs = sh.resolve_specs(shapes, logical, mesh)
    cache_shapes = jax.eval_shape(
        lambda: mdl.init_decode_cache(model_cfg, batch, seq)[0])
    _, cache_logical = mdl.init_decode_cache(model_cfg, 1, 1)
    # decode cache sharding: batch over data when divisible, else kv_seq
    rules = dict(sh.DEFAULT_RULES)
    if batch % mesh.shape["data"] != 0:
        rules["batch"] = None
        rules["kv_seq"] = "data"
    cspecs = sh.resolve_specs(cache_shapes, cache_logical, mesh, rules)
    return shapes, pspecs, cache_shapes, cspecs
