"""RoundProgram IR — one declarative round schedule, three engine lowerings.

CE-FedAvg's accuracy–latency tradeoff is governed entirely by the round's
*boundary schedule*: τ local steps, intra-cluster aggregation every τ,
π-round inter-server gossip every qτ (eq. 10/11). The paper fixes
τ/q/π statically; related work (Ganguly et al., optimized floating
aggregation; Wang et al., cooperative hetero edge/fog) argues the
aggregation structure itself should adapt to device and network state.

This module makes the schedule a first-class value. A
:class:`RoundProgram` is a validated sequence of ops —

=================  =========================================================
op                 meaning
=================  =========================================================
``LocalSteps``     τ SGD+momentum steps on every participating device
                   (optionally per-device step cutoffs ≤ τ, and an
                   lr multiplier for this op)
``Privatize``      device-side DP transform of the delta accumulated since
                   the previous mixing boundary (before upload)
``Compress``       device-side compression (+ error feedback) of that delta
``IntraMix``       apply the intra-cluster operator V (eq. 11 τ-boundary)
``InterGossip``    apply the inter-cluster operator B^T diag(c) H^π B with
                   this op's own π (eq. 11 qτ-boundary)
``MaskRenorm``     plan-level directive: renormalize this round's mixing
                   operators over the participation mask (the
                   ``topology.masked_*`` / ``renormalize_rows`` forms);
                   without it a partial cohort still freezes its local
                   steps but mixes with the *unmasked* operators
=================  =========================================================

— plus a :data:`ScheduleFn` hook ``(round_idx, RoundPlan) -> RoundProgram``
so the schedule can react to realized device/network state between global
rounds. :func:`canonical_program` compiles an :class:`repro.config.FLConfig`'s
current τ/q/π knobs into the canonical program, so existing configs are
untouched; each engine (legacy pytree, flat ModelBank, compacted cohort,
sharded bank) is a *lowering* from the program to its jitted round —
see ``FLSimulator._lower_*`` and ``ShardedBankCEFedAvg._lower_flat``.

Lowerings consume the program through :func:`lowering_plan` (blocks of
local work + mixing groups, with engine-dependent fusion of adjacent
mixes) and :func:`block_runs` (maximal runs of identical blocks, which
compile to one ``lax.scan`` instead of an unrolled trace). The runtime
matrices for one concrete round come from :func:`resolve_matrices`, in
exactly the order the lowered round consumes them — the single source of
truth that keeps compiler and caller in lockstep.
"""
from __future__ import annotations

import dataclasses
from typing import (Callable, Dict, List, NamedTuple, Optional, Sequence,
                    Tuple, Union)

import numpy as np

from repro.config import FLConfig

# ---------------------------------------------------------------------------
# ops
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LocalSteps:
    """``tau`` local SGD+momentum steps; ``lr_scale`` multiplies the
    engine's learning rate for this op only. ``adaptive=True`` makes the
    op read a per-device step cutoff (``RoundProgram.tau_dev``, values in
    [1, tau]) at run time: device k applies only its first ``tau_dev[k]``
    steps and is frozen for the rest — the trip count (and therefore the
    compiled trace) stays ``tau``, so a schedule can re-draw the cutoffs
    every round without recompiling."""
    tau: int
    lr_scale: float = 1.0
    adaptive: bool = False


@dataclasses.dataclass(frozen=True, eq=False)
class TierMix:
    """Apply hierarchy tier ``level``'s mixing operator: average each
    tier group, then (for ``level >= 1``) run ``pi`` gossip steps of
    that tier's block-diagonal backhaul mixing among sibling groups
    (``topology.Hierarchy``). ``TierMix(0)`` is the intra-cluster V and
    ``TierMix(1, π)`` the paper's B^T diag(c) H^π B — :class:`IntraMix`
    and :class:`InterGossip` are sugar for exactly those two, and
    compare/hash equal to them, so depth-2 programs are unchanged.
    Levels >= 2 (region, ...) need an ``FLConfig.hierarchy`` of matching
    depth; the engines validate that at resolve time."""
    level: int
    pi: int = 1

    def __eq__(self, other):
        return (isinstance(other, TierMix)
                and (self.level, self.pi) == (other.level, other.pi))

    def __hash__(self):
        return hash(("TierMix", self.level, self.pi))


class IntraMix(TierMix):
    """Apply the intra-cluster averaging operator V (eq. 11) — sugar
    for ``TierMix(0)``."""

    def __init__(self):
        super().__init__(0, 1)

    def __repr__(self):
        return "IntraMix()"


class InterGossip(TierMix):
    """Apply the inter-cluster operator built with THIS op's ``pi``
    gossip steps (eq. 11's B^T diag(c) H^π B) — sugar for
    ``TierMix(1, pi)``."""

    def __init__(self, pi: int):
        super().__init__(1, pi)

    def __repr__(self):
        return f"InterGossip(pi={self.pi})"


@dataclasses.dataclass(frozen=True)
class Compress:
    """Compress (+ error-feedback) the device delta before upload."""


@dataclasses.dataclass(frozen=True)
class Privatize:
    """DP-transform (clip + noise) the device delta before upload."""


@dataclasses.dataclass(frozen=True)
class MaskRenorm:
    """Plan-level directive: build this round's operators renormalized
    over the participation mask (``scenario.make_masked_w``)."""


@dataclasses.dataclass(frozen=True)
class FaultGate:
    """Plan-level directive: gate this round's operators for the plan's
    realized faults (``gossip.fault_gate``) — dark clusters' device
    rows become the identity and their columns' mass folds onto each
    surviving row's diagonal, so every resolved operator stays
    row-stochastic under edge-server outages. Applied per *op* operator
    before any fusion, so fused and unfused lowerings stay in bitwise
    parity. A no-op on fault-free rounds (and in engines without a
    fault model)."""


MixOp = TierMix
Op = Union[LocalSteps, TierMix, Compress, Privatize, MaskRenorm, FaultGate]


# ---------------------------------------------------------------------------
# blocks — the normal form every lowering consumes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Block:
    """One unit of local work plus the mixing boundary that closes it."""
    local: LocalSteps
    privatize: bool
    compress: bool
    mixes: Tuple[MixOp, ...]

    @property
    def upload(self) -> bool:
        """True when the block takes the delta/upload path (the mixing
        operator applies to the transformed delta, not the params)."""
        return self.privatize or self.compress


@dataclasses.dataclass(frozen=True)
class RoundProgram:
    """A validated sequence of round ops (the IR).

    ``ops`` is the structural identity: it is what lowerings compile and
    what the per-engine jit caches key on (``signature``). ``tau_dev`` is
    a *runtime binding* — the per-device step cutoffs an ``adaptive``
    ``LocalSteps`` op reads — deliberately excluded from equality/hash so
    re-drawing it each round never recompiles."""
    ops: Tuple[Op, ...]
    tau_dev: Optional[np.ndarray] = dataclasses.field(
        default=None, compare=False)

    def __post_init__(self):
        object.__setattr__(self, "ops", tuple(self.ops))
        self.validate()

    # -- structure -----------------------------------------------------------
    @property
    def signature(self) -> Tuple[Op, ...]:
        """Hashable structural identity (compile-cache key)."""
        return self.ops

    @property
    def mask_renorm(self) -> bool:
        return any(isinstance(o, MaskRenorm) for o in self.ops)

    @property
    def fault_gate(self) -> bool:
        """True when the program asks for per-round fault gating of its
        operators (see :class:`FaultGate`)."""
        return any(isinstance(o, FaultGate) for o in self.ops)

    @property
    def has_upload(self) -> bool:
        return any(isinstance(o, (Compress, Privatize)) for o in self.ops)

    @property
    def adaptive(self) -> bool:
        return any(isinstance(o, LocalSteps) and o.adaptive
                   for o in self.ops)

    @property
    def num_blocks(self) -> int:
        return len(self.blocks())

    def blocks(self) -> Tuple[Block, ...]:
        """Parse ``ops`` into the block normal form (cached)."""
        cached = getattr(self, "_blocks", None)
        if cached is None:
            cached = _parse_blocks(self.ops)
            object.__setattr__(self, "_blocks", cached)
        return cached

    def validate(self) -> None:
        """Raise ValueError unless the op sequence parses into blocks."""
        blocks = self.blocks()
        if not blocks:
            raise ValueError("a RoundProgram needs at least one "
                             "LocalSteps block")
        for b in blocks:
            if b.local.tau < 1:
                raise ValueError(f"LocalSteps.tau must be >= 1: {b.local}")
            if b.local.lr_scale <= 0.0:
                raise ValueError(f"lr_scale must be > 0: {b.local}")
            for m in b.mixes:
                if m.level < 0:
                    raise ValueError(f"TierMix.level must be >= 0: {m}")
                if m.level >= 1 and m.pi < 1:
                    raise ValueError(
                        f"gossip tiers' pi must be >= 1: {m}")
        if self.tau_dev is not None:
            td = np.asarray(self.tau_dev)
            if td.ndim != 1 or not np.issubdtype(td.dtype, np.integer):
                raise ValueError("tau_dev must be a 1-D integer array")
            taus = [b.local.tau for b in blocks if b.local.adaptive]
            if taus and (td.min() < 1 or td.max() > max(taus)):
                raise ValueError(
                    f"tau_dev values must lie in [1, {max(taus)}], got "
                    f"[{td.min()}, {td.max()}]")
        if self.adaptive and self.tau_dev is None:
            raise ValueError("adaptive LocalSteps need a tau_dev binding "
                             "(RoundProgram(..., tau_dev=...))")

    def bind(self, tau_dev: Optional[np.ndarray]) -> "RoundProgram":
        """Same structure, new per-device cutoffs (no recompile)."""
        return dataclasses.replace(self, tau_dev=tau_dev)


def block_programs(program: RoundProgram) -> Tuple[RoundProgram, ...]:
    """Split a program into one single-block program per block, in block
    order — the unit of work an async bounded-staleness round executes
    per cluster event (``FLSimulator.step_round_async``).

    Each piece keeps the parent's ``MaskRenorm`` directive and, for
    adaptive blocks, a ``tau_dev`` binding clipped to that block's τ (the
    per-block effective cutoff, so validation and execution match the
    parent program's semantics block for block). Identical blocks share
    a signature, so lowering the pieces reuses one compiled round per
    distinct block."""
    prefix: Tuple[Op, ...] = ((MaskRenorm(),) if program.mask_renorm
                              else ())
    if program.fault_gate:
        prefix = prefix + (FaultGate(),)
    out: List[RoundProgram] = []
    for b in program.blocks():
        ops: List[Op] = [b.local]
        if b.privatize:
            ops.append(Privatize())
        if b.compress:
            ops.append(Compress())
        ops.extend(b.mixes)
        td = None
        if b.local.adaptive and program.tau_dev is not None:
            td = np.minimum(np.asarray(program.tau_dev),
                            b.local.tau).astype(np.int32)
        out.append(RoundProgram(prefix + tuple(ops), tau_dev=td))
    return tuple(out)


def _parse_blocks(ops: Sequence[Op]) -> Tuple[Block, ...]:
    blocks: List[Block] = []
    i, N = 0, len(ops)
    while i < N:
        op = ops[i]
        if isinstance(op, (MaskRenorm, FaultGate)):
            i += 1
            continue
        if not isinstance(op, LocalSteps):
            raise ValueError(
                f"op {i} ({op}) must start a block with LocalSteps")
        local = op
        i += 1
        privatize = compress = False
        if i < N and isinstance(ops[i], Privatize):
            privatize, i = True, i + 1
        if i < N and isinstance(ops[i], Compress):
            compress, i = True, i + 1
        if i < N and isinstance(ops[i], Privatize):
            raise ValueError("Privatize must precede Compress (the upload "
                             "applies DP before compression)")
        mixes: List[MixOp] = []
        while i < N and isinstance(ops[i], TierMix):
            mixes.append(ops[i])
            i += 1
        if not mixes:
            raise ValueError(
                f"LocalSteps at op {i - 1} has no closing mixing boundary "
                f"(IntraMix/InterGossip/TierMix)")
        blocks.append(Block(local, privatize, compress, tuple(mixes)))
    return tuple(blocks)


# ---------------------------------------------------------------------------
# canonical program — FLConfig's τ/q/π knobs, compiled
# ---------------------------------------------------------------------------

def canonical_program(fl: FLConfig, *, privatize: bool = False,
                      compress: bool = False,
                      faults: bool = False) -> RoundProgram:
    """The static schedule of Algorithm 1 as a program: q blocks of
    (τ local steps → [Privatize → Compress →] IntraMix), the last block
    also closed by ``InterGossip(fl.pi)`` — exactly the boundary
    placement of eq. 11, so lowering this program reproduces the
    pre-IR engines' trajectories. A depth-L ``fl.hierarchy`` appends one
    ``TierMix(ℓ, fl.pi)`` per deeper tier to the final boundary
    (:func:`hierarchical_program` with default repeats). ``faults``
    prepends a :class:`FaultGate` directive (fault-injecting
    scenarios)."""
    return hierarchical_program(fl, privatize=privatize, compress=compress,
                                faults=faults)


def hierarchical_program(fl: FLConfig, qs=None, pis=None, *,
                         privatize: bool = False,
                         compress: bool = False,
                         faults: bool = False) -> RoundProgram:
    """The canonical schedule generalized to a depth-L hierarchy.

    The tier-ℓ superblock is ``qs[ℓ-1]`` repetitions of the tier-(ℓ-1)
    superblock closed by ``TierMix(ℓ, pis[ℓ-1])``; tier 0's unit is the
    usual (τ local steps → [upload →] IntraMix) block. Defaults:
    ``qs = (fl.q, 1, 1, ...)`` and ``pis = (fl.pi,) * (L-1)``, so depth
    2 reduces exactly to the pre-hierarchy canonical program."""
    L = fl.depth
    qs = ((fl.q,) + (1,) * (L - 2)) if qs is None else tuple(qs)
    pis = ((fl.pi,) * (L - 1)) if pis is None else tuple(pis)
    assert len(qs) == L - 1 and len(pis) == L - 1, (qs, pis, L)
    block: List[Op] = [LocalSteps(fl.tau)]
    if privatize:
        block.append(Privatize())
    if compress:
        block.append(Compress())
    block.append(IntraMix())
    unit: List[Op] = []
    for _ in range(qs[0]):
        unit.extend(block)
    unit.append(InterGossip(pis[0]))
    for lvl in range(2, L):
        rep: List[Op] = []
        for _ in range(qs[lvl - 1]):
            rep.extend(unit)
        rep.append(TierMix(lvl, pis[lvl - 1]))
        unit = rep
    prefix: List[Op] = [MaskRenorm()]
    if faults:
        prefix.append(FaultGate())
    return RoundProgram(tuple(prefix + unit))


# ---------------------------------------------------------------------------
# lowering plan: mixing groups (+ engine fusion policy) and scan runs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MixGroup:
    """Mix ops an engine applies as ONE pass: a fused group's matrices
    multiply into a single operator at resolve time (the ModelBank
    engines' single-pass ``W_inter @ W_intra`` boundary); an unfused
    group holds exactly one op (the legacy engine's sequential form)."""
    ops: Tuple[MixOp, ...]


@dataclasses.dataclass(frozen=True)
class BlockPlan:
    """A block with its mixes grouped under an engine's fusion policy.
    On the upload path the first mix stays its own group — it applies to
    the transformed *delta*, which cannot fold into the later mixes."""
    local: LocalSteps
    privatize: bool
    compress: bool
    upload: bool
    groups: Tuple[MixGroup, ...]


def lowering_plan(program: RoundProgram, *,
                  fuse: bool) -> Tuple[BlockPlan, ...]:
    """Group each block's mixes for an engine: ``fuse=True`` folds
    adjacent plain mixes into one streaming pass (flat/compact/sharded
    banks); ``fuse=False`` keeps one group per op (legacy pytree)."""
    plans: List[BlockPlan] = []
    for b in program.blocks():
        if b.upload:
            head = [MixGroup((b.mixes[0],))]
            rest = b.mixes[1:]
            if rest:
                if fuse:
                    head.append(MixGroup(tuple(rest)))
                else:
                    head.extend(MixGroup((m,)) for m in rest)
            groups = tuple(head)
        elif fuse:
            groups = (MixGroup(tuple(b.mixes)),)
        else:
            groups = tuple(MixGroup((m,)) for m in b.mixes)
        plans.append(BlockPlan(b.local, b.privatize, b.compress, b.upload,
                               groups))
    return tuple(plans)


def block_runs(plans: Sequence[BlockPlan]
               ) -> Tuple[Tuple[BlockPlan, int], ...]:
    """Maximal runs of identical consecutive block plans. A run of
    length L lowers to ONE ``lax.scan`` over its L block keys (the
    canonical program's q-1 identical edge rounds), so arbitrary
    programs stay cheap to compile."""
    runs: List[List] = []
    for bp in plans:
        if runs and runs[-1][0] == bp:
            runs[-1][1] += 1
        else:
            runs.append([bp, 1])
    return tuple((bp, c) for bp, c in runs)


def resolve_matrices(plans: Sequence[BlockPlan], W_intra: np.ndarray,
                     inter_of_pi: Callable[[int], np.ndarray],
                     tier_of: Optional[Callable[[TierMix], np.ndarray]] = None
                     ) -> Tuple[np.ndarray, ...]:
    """The concrete mixing matrices one round's lowered function
    consumes, in consumption order: one matrix per MixGroup per *run*
    (identical consecutive blocks share their groups' matrices). A fused
    group's ops compose right-to-left — ops applied o1 then o2 become
    the single operator M2 @ M1. ``tier_of`` resolves mixes above the
    backhaul (``TierMix(level >= 2)``); the base tiers keep their
    dedicated resolvers so depth-2 callers need not pass it."""
    mats: List[np.ndarray] = []
    for bp, _count in block_runs(plans):
        for g in bp.groups:
            M = None
            for op in g.ops:
                if op.level == 0:
                    Mi = W_intra
                elif op.level == 1:
                    Mi = inter_of_pi(op.pi)
                elif tier_of is None:
                    raise ValueError(
                        f"TierMix(level={op.level}) needs a tier_of resolver")
                else:
                    Mi = tier_of(op)
                M = Mi if M is None else Mi @ M
            mats.append(np.asarray(M, np.float32))
    return tuple(mats)


class RoundArgs(NamedTuple):
    """Runtime operands of a lowered round: the resolved mixing matrices
    (``resolve_matrices`` order) and, for adaptive programs, the (n,)
    int32 per-device step cutoffs. A pytree, so it jits transparently;
    ``tau_dev=None`` is structural (no dummy operand for non-adaptive
    programs)."""
    mats: Tuple
    tau_dev: Optional[object] = None


# ---------------------------------------------------------------------------
# schedules — ScheduleFn hook + the named non-canonical schedules
# ---------------------------------------------------------------------------

#: ``(round_idx, RoundPlan | None) -> RoundProgram`` — called once per
#: global round, BEFORE the round runs, with the realized scenario plan
#: (mobility/sampling) for that round; returns the program to execute.
ScheduleFn = Callable[[int, Optional[object]], RoundProgram]

SCHEDULES = ("static", "adaptive_tau", "pi_decay", "adaptive_tau_online",
             "pi_feedback")


def edge_disagreement(sim) -> float:
    """Mean pairwise L2 distance between the current edge (cluster)
    models of a simulator — the observable the ``pi_feedback`` schedule
    adapts gossip depth from. 0.0 when fewer than two clusters."""
    import jax
    em = sim.edge_models()
    leaves = jax.tree.leaves(em)
    X = np.concatenate(
        [np.asarray(jax.device_get(l)).reshape(l.shape[0], -1)
         for l in leaves], axis=1)
    m = X.shape[0]
    if m < 2:
        return 0.0
    diffs = X[:, None, :] - X[None, :, :]
    d = np.sqrt((diffs * diffs).sum(-1))
    iu = np.triu_indices(m, 1)
    return float(d[iu].mean())


class OnlineSpeedEstimator:
    """EMA of realized per-device compute rates, fed by the EventClock.

    ``observe`` takes the step counts and wall-clock compute times a
    round actually charged and folds rate = steps/time into a per-device
    EMA; devices outside the cohort keep their last estimate. The EMA is
    kept in *raw* rate units (not per-round normalized) so observations
    of different partial cohorts across rounds stay comparable —
    :func:`adaptive_tau_map` only consumes the ratios exposed by
    ``multipliers``."""

    def __init__(self, n: int, beta: float = 0.5):
        self.n = int(n)
        self.beta = float(beta)
        self._rate = np.full(self.n, np.nan)

    def observe(self, steps: np.ndarray, times: np.ndarray,
                mask: Optional[np.ndarray] = None) -> None:
        steps = np.asarray(steps, float)
        times = np.asarray(times, float)
        sel = (steps > 0) & (times > 0)
        if mask is not None:
            sel &= np.asarray(mask) > 0
        if not sel.any():
            return
        rate = steps[sel] / times[sel]
        prev = self._rate[sel]
        self._rate[sel] = np.where(
            np.isnan(prev), rate, (1.0 - self.beta) * prev + self.beta * rate)

    @property
    def ready(self) -> bool:
        return bool(np.isfinite(self._rate).any())

    @property
    def multipliers(self) -> np.ndarray:
        r = self._rate
        if not np.isfinite(r).any():
            return np.ones(self.n)
        return np.where(np.isfinite(r), r / np.nanmean(r), 1.0)


def adaptive_tau_map(tau: int, labels: np.ndarray, mask: np.ndarray,
                     multipliers: np.ndarray, num_clusters: int,
                     tau_floor: int = 1) -> np.ndarray:
    """Per-device step cutoffs for the adaptive-τ_k schedule.

    Cluster k's cutoff scales the base τ by the speed of its slowest
    *participating* device relative to the fastest cluster's slowest
    device: τ_k = clip(round(τ · c_k / max_j c_j), tau_floor, τ). The
    round's compute time — the EventClock's max-over-participants
    τ_k·C/c_d rule — then collapses from τ/min_d c_d to ≈ τ/max_k c_k:
    a slow cluster no longer paces everyone, it just trains less.
    """
    mult = np.asarray(multipliers, float)
    c = np.full(num_clusters, np.nan)
    for k in range(num_clusters):
        sel = (labels == k) & (mask > 0)
        if sel.any():
            c[k] = mult[sel].min()
    ref = np.nanmax(c) if np.isfinite(c).any() else 1.0
    tau_k = np.where(np.isfinite(c),
                     np.clip(np.round(tau * c / ref), tau_floor, tau),
                     tau)
    return tau_k[labels].astype(np.int32)


def make_schedule(name: str, fl: FLConfig, *, engine=None,
                  speeds: Optional[np.ndarray] = None,
                  privatize: bool = False, compress: bool = False,
                  faults: bool = False, sim=None,
                  tau_floor: int = 1, decay_round: int = 5,
                  pi_late: Optional[int] = None,
                  pi_floor: int = 1,
                  ema_beta: float = 0.5) -> ScheduleFn:
    """Build a named :data:`ScheduleFn`.

    - ``static``: the canonical program every round (the paper).
    - ``adaptive_tau``: per-cluster τ_k cutoffs from device speeds
      (``speeds`` multipliers, or ``engine.speed_multipliers`` of an
      attached :class:`repro.core.scenario.ScenarioEngine`); re-drawn
      every round from that round's realized cohort and assignment, so
      it tracks mobility. Homogeneous speeds reduce to static.
    - ``pi_decay``: time-varying π_t — the full ``fl.pi`` gossip depth
      while ``round_idx < decay_round`` (consensus matters early), then
      ``pi_late`` (default max(1, fl.pi // 5)) to shed backhaul time
      once the edge models agree.
    - ``adaptive_tau_online``: adaptive τ_k, but driven by *online*
      per-device rate estimates (an :class:`OnlineSpeedEstimator` EMA
      fed by the EventClock's realized compute times) instead of oracle
      scenario speeds. Round 0 runs the full τ; once observations
      arrive the cutoffs converge to the oracle schedule's. The
      estimator is exposed as ``schedule_fn.estimator`` so the wall
      clock driver can feed it.
    - ``pi_feedback``: time-varying π_t driven by *observed* edge-model
      disagreement (:func:`edge_disagreement` of the attached ``sim``,
      EMA-smoothed): π_t = clip(ceil(π · D_t/D_1), pi_floor, π), so
      gossip depth decays exactly as fast as the edge models actually
      agree — the closed-loop counterpart of ``pi_decay``'s open-loop
      round threshold. Round 0 (no observation yet) runs the full π;
      ``schedule_fn.state`` holds the EMA/reference (checkpointed by
      ``RunCheckpoint``), ``schedule_fn.pi_trace`` the realized depths.

    ``faults=True`` compiles every produced program with the
    :class:`FaultGate` plan-level directive (fault-injecting
    scenarios).
    """
    if name not in SCHEDULES:
        raise ValueError(
            f"unknown schedule {name!r}; choose from {SCHEDULES}")
    canonical = canonical_program(fl, privatize=privatize,
                                  compress=compress, faults=faults)
    if name == "static":
        return lambda r, plan: canonical

    if name in ("adaptive_tau", "adaptive_tau_online"):
        template = RoundProgram(
            tuple(dataclasses.replace(o, adaptive=True)
                  if isinstance(o, LocalSteps) else o
                  for o in canonical.ops),
            tau_dev=np.full(fl.n, fl.tau, np.int32))
        base_labels = np.repeat(np.arange(fl.num_clusters),
                                fl.devices_per_cluster)
        full_tau = np.full(fl.n, fl.tau, np.int32)

        if name == "adaptive_tau":
            mult = None
            if speeds is not None:
                mult = np.asarray(speeds, float)
            elif engine is not None:
                mult = np.asarray(engine.speed_multipliers, float)
            if mult is None:
                mult = np.ones(fl.n)

            def adaptive(r, plan):
                labels = plan.labels if plan is not None else base_labels
                mask = plan.mask if plan is not None else np.ones(fl.n)
                return template.bind(adaptive_tau_map(
                    fl.tau, labels, mask, mult, fl.num_clusters, tau_floor))
            return adaptive

        est = OnlineSpeedEstimator(fl.n, ema_beta)

        def online(r, plan):
            if not est.ready:
                return template.bind(full_tau)
            labels = plan.labels if plan is not None else base_labels
            mask = plan.mask if plan is not None else np.ones(fl.n)
            return template.bind(adaptive_tau_map(
                fl.tau, labels, mask, est.multipliers, fl.num_clusters,
                tau_floor))
        online.estimator = est
        return online

    if name == "pi_feedback":
        at_pi: Dict[int, RoundProgram] = {fl.pi: canonical}

        def _program_at(pi: int) -> RoundProgram:
            if pi not in at_pi:
                at_pi[pi] = RoundProgram(tuple(
                    InterGossip(pi) if isinstance(o, InterGossip) else o
                    for o in canonical.ops))
            return at_pi[pi]

        state = {"ref": np.nan, "ema": np.nan}

        def feedback(r, plan):
            if sim is None or r == 0:
                return canonical
            d = edge_disagreement(sim)
            if not np.isfinite(state["ema"]):
                state["ema"] = d
            else:
                state["ema"] = ((1.0 - ema_beta) * state["ema"]
                                + ema_beta * d)
            if not np.isfinite(state["ref"]) or state["ref"] <= 0.0:
                # first observation anchors the reference disagreement
                state["ref"] = state["ema"]
                feedback.pi_trace.append(fl.pi)
                return canonical
            frac = min(1.0, state["ema"] / state["ref"])
            pi_r = int(np.clip(int(np.ceil(fl.pi * frac)),
                               pi_floor, fl.pi))
            feedback.pi_trace.append(pi_r)
            return _program_at(pi_r)
        feedback.state = state
        feedback.pi_trace = []
        return feedback

    lo_pi = max(1, fl.pi // 5) if pi_late is None else pi_late
    late = RoundProgram(tuple(
        InterGossip(lo_pi) if isinstance(o, InterGossip) else o
        for o in canonical.ops))

    def decay(r, plan):
        return canonical if r < decay_round else late
    return decay
