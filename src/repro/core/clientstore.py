"""Streaming client-state store: O(cohort) resident memory at n≈10⁵.

The flat ModelBank (``core/modelbank.py``) materializes every client as
a hot ``(n, T)`` row, so memory and init cost grow with the population
even though cohort compaction already made per-round *compute*
O(cohort). The :class:`ClientStore` breaks that last O(n) dependence:
per round only the sampled cohort's rows are materialized as the hot
``(k_pad, T)`` slab (``ModelBank.from_rows``), while cold state lives
here — host-side, compressed under a ``core/compress.py`` cold codec —
and is paged in/out at round boundaries.

Why the cold store is small — what per-client state actually exists
-------------------------------------------------------------------

Every supported round program ends in a cluster-level mixing boundary
(the qτ-boundary of eq. 11, or its Hier-FAvg/FedAvg/Local-Edge
reductions), and every masked operator row is a function of the row's
cluster label only. So at the end of a round, **every member of a
cluster holds the identical synced value** — per-client params would be
n duplicates of an (m, T) table. The store therefore keeps:

- ``cluster_params`` — the (m, T) per-cluster reference models (what a
  cold client's row *is*);
- encoded **momentum** rows of ever-sampled clients only, lazily: a
  never-sampled client's momentum is exactly zero (momentum is never
  mixed, and ``where``-frozen while a client sits out), so it needs no
  bytes at all.

Page-in builds each working-set lane from ``cluster_params[label]``
plus its decoded momentum (zeros on first touch); page-out reads each
cluster's synced row back into ``cluster_params`` and re-encodes the
cohort's momentum. With the default lossless ``f32`` codec the
page-out/page-in round trip is bit-exact, which is what makes
killed-and-resumed streamed runs bit-identical (``RunCheckpoint``
snapshots :meth:`ClientStore.snapshot` under fixed keys).

Sharding: the store partitions client rows ``client_id % num_shards``
into independent per-shard maps, so the sharded engine
(``core/sharded.py``) keeps one cold shard per bank shard and no single
host map ever holds the whole population's rows.

Resident-memory formula (doctested in docs/PERFORMANCE.md):

>>> resident_slab_nbytes(16, 1000)   # 16-lane slab, T=1000 params
128000
>>> cold_row_nbytes(1000, "int8", 4)  # 4-segment layout: q + scales
1016
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.compress import (COLD_CODECS, cold_bits_per_param,
                                 cold_dtype, decode_cold_rows,
                                 encode_cold_rows)


def resident_slab_nbytes(k_pad: int, total: int) -> int:
    """Peak resident hot-slab bytes of one streamed round: params +
    momentum at ``(k_pad, T)`` float32 — a function of the *cohort
    bucket*, never of the population size.

    >>> resident_slab_nbytes(8, 100)
    6400
    """
    return 2 * 4 * int(k_pad) * int(total)


def cold_row_nbytes(total: int, codec: str, num_segments: int) -> int:
    """Host cold-store bytes of one stored client row: ``T`` params at
    the codec's width, plus one float32 affine scale per FlatLayout
    segment for ``int8``.

    >>> cold_row_nbytes(100, "f32", 4)
    400
    >>> cold_row_nbytes(100, "f16", 4)
    200
    >>> cold_row_nbytes(100, "int8", 4)
    116
    """
    per = cold_bits_per_param(codec) // 8
    scales = 4 * num_segments if codec == "int8" else 0
    return per * int(total) + scales


class ClientStore:
    """Compressed host store of cold client state behind the hot slab.

    ``layout`` is the model's FlatLayout; ``init_row`` the shared-init
    flat row (Algorithm 1's common y_{0,0}); ``codec`` one of
    ``compress.COLD_CODECS``. Rows are partitioned
    ``client_id % num_shards`` so a sharded engine keeps per-shard cold
    stores (``num_shards=1`` for the single-process engine)."""

    def __init__(self, layout, num_clusters: int, init_row: np.ndarray,
                 *, codec: str = "f32", num_shards: int = 1):
        assert codec in COLD_CODECS, codec
        assert num_shards >= 1
        self.layout = layout
        self.m = int(num_clusters)
        self.codec = codec
        self.num_shards = int(num_shards)
        row = np.asarray(init_row, np.float32).reshape(-1)
        assert row.shape[0] == layout.total, (row.shape, layout.total)
        #: (m, T) per-cluster reference params — a cold client's row IS
        #: its cluster's reference (see module docstring)
        self.cluster_params = np.tile(row[None, :], (self.m, 1))
        # per-shard maps: client_id -> (encoded q row, scale row)
        self._shards: List[Dict[int, tuple]] = [
            dict() for _ in range(self.num_shards)]

    # -- bookkeeping ---------------------------------------------------------
    @property
    def num_stored(self) -> int:
        """Clients with a materialized (ever-sampled) momentum row."""
        return sum(len(s) for s in self._shards)

    @property
    def bits_per_row(self) -> int:
        """Paged bits per client row — what ``clock.paging_comm_time``
        charges each page-in/page-out row of device↔edge traffic."""
        return 8 * cold_row_nbytes(self.layout.total, self.codec,
                                   len(self.layout.segments))

    def shard_nbytes(self) -> List[int]:
        """Cold bytes held per shard (stored rows only)."""
        per = cold_row_nbytes(self.layout.total, self.codec,
                              len(self.layout.segments))
        return [per * len(s) for s in self._shards]

    @property
    def nbytes(self) -> int:
        """Total host bytes: cluster references + stored cold rows."""
        return int(self.cluster_params.nbytes) + sum(self.shard_nbytes())

    # -- paging --------------------------------------------------------------
    def fetch(self, clients: np.ndarray) -> np.ndarray:
        """Decode the momentum rows of ``clients`` as (k, T) float32.
        Never-stored clients decode to zeros (their exact momentum)."""
        ids = np.asarray(clients, np.int64).reshape(-1)
        out = np.zeros((ids.shape[0], self.layout.total), np.float32)
        hit, qs, scales = [], [], []
        for j, i in enumerate(ids):
            row = self._shards[int(i) % self.num_shards].get(int(i))
            if row is not None:
                hit.append(j)
                qs.append(row[0])
                scales.append(row[1])
        if hit:
            enc = {"q": np.stack(qs), "scale": np.stack(scales)}
            out[hit] = decode_cold_rows(enc, self.codec,
                                        self.layout.segments)
        return out

    def commit(self, clients: np.ndarray, rows: np.ndarray) -> None:
        """Encode and store the momentum rows of ``clients`` (page-out).
        Re-committing a client overwrites its previous row."""
        ids = np.asarray(clients, np.int64).reshape(-1)
        rows = np.asarray(rows, np.float32)
        assert rows.shape == (ids.shape[0], self.layout.total)
        enc = encode_cold_rows(rows, self.codec, self.layout.segments)
        for j, i in enumerate(ids):
            self._shards[int(i) % self.num_shards][int(i)] = (
                enc["q"][j], enc["scale"][j])

    def update_clusters(self, refs: np.ndarray) -> None:
        """Replace the per-cluster reference params (page-out)."""
        refs = np.asarray(refs, np.float32)
        assert refs.shape == self.cluster_params.shape
        self.cluster_params = refs.copy()

    # -- checkpoint edge -----------------------------------------------------
    def snapshot(self) -> Dict[str, np.ndarray]:
        """Fixed-key host snapshot for ``RunCheckpoint``: stored rows
        stay *encoded*, so a save/restore round trip reproduces the
        identical cold bytes under every codec (no re-quantization)."""
        ids = sorted(i for s in self._shards for i in s)
        T, nseg = self.layout.total, len(self.layout.segments)
        dt = cold_dtype(self.codec)
        if ids:
            rows = [self._shards[i % self.num_shards][i] for i in ids]
            q = np.stack([r[0] for r in rows]).astype(dt)
            scale = np.stack([r[1] for r in rows]).astype(np.float32)
        else:
            q = np.zeros((0, T), dt)
            scale = np.zeros((0, nseg if self.codec == "int8" else 0),
                             np.float32)
        return {"cluster": self.cluster_params.copy(),
                "ids": np.asarray(ids, np.int64),
                "mom_q": q, "mom_scale": scale}

    def load(self, state: Dict[str, np.ndarray]) -> None:
        """Restore :meth:`snapshot` output (mirror of ``_assign``)."""
        cluster = np.asarray(state["cluster"], np.float32)
        assert cluster.shape == self.cluster_params.shape, \
            (cluster.shape, self.cluster_params.shape)
        self.cluster_params = cluster.copy()
        self._shards = [dict() for _ in range(self.num_shards)]
        ids = np.asarray(state["ids"], np.int64)
        q = np.asarray(state["mom_q"])
        scale = np.asarray(state["mom_scale"], np.float32)
        for j, i in enumerate(ids):
            self._shards[int(i) % self.num_shards][int(i)] = (
                q[j], scale[j])
