"""Streaming client-state store: O(cohort) resident memory at n≈10⁵.

The flat ModelBank (``core/modelbank.py``) materializes every client as
a hot ``(n, T)`` row, so memory and init cost grow with the population
even though cohort compaction already made per-round *compute*
O(cohort). The :class:`ClientStore` breaks that last O(n) dependence:
per round only the sampled cohort's rows are materialized as the hot
``(k_pad, T)`` slab (``ModelBank.from_rows``), while cold state lives
here — host-side, compressed under a ``core/compress.py`` cold codec —
and is paged in/out at round boundaries.

Why the cold store is small — what per-client state actually exists
-------------------------------------------------------------------

Every supported round program ends in a cluster-level mixing boundary
(the qτ-boundary of eq. 11, or its Hier-FAvg/FedAvg/Local-Edge
reductions), and every masked operator row is a function of the row's
cluster label only. So at the end of a round, **every member of a
cluster holds the identical synced value** — per-client params would be
n duplicates of an (m, T) table. The store therefore keeps:

- ``cluster_params`` — the (m, T) per-cluster reference models (what a
  cold client's row *is*);
- encoded **momentum** rows of ever-sampled clients only, lazily: a
  never-sampled client's momentum is exactly zero (momentum is never
  mixed, and ``where``-frozen while a client sits out), so it needs no
  bytes at all.

Page-in builds each working-set lane from ``cluster_params[label]``
plus its decoded momentum (zeros on first touch); page-out reads each
cluster's synced row back into ``cluster_params`` and re-encodes the
cohort's momentum. With the default lossless ``f32`` codec the
page-out/page-in round trip is bit-exact, which is what makes
killed-and-resumed streamed runs bit-identical (``RunCheckpoint``
snapshots :meth:`ClientStore.snapshot` under fixed keys).

Storage layout (PR 10): each shard is a growable contiguous *arena* —
``(capacity, T)`` encoded rows + ``(capacity, nseg)`` scales + a dense
``local_id -> slot`` map — so :meth:`fetch`/:meth:`commit` are single
numpy gather/scatters instead of O(k) Python dict walks, and the
pipelined driver's :meth:`fetch_encoded`/:meth:`commit_encoded` move
codec-width bytes without a host decode/encode in the loop. Per-slot
dirty bits make :meth:`snapshot` incremental: only rows committed since
the last snapshot are re-gathered (bit-identical to a full rebuild).

Sharding: the store partitions client rows ``client_id % num_shards``
into independent per-shard arenas, so the sharded engine
(``core/sharded.py``) keeps one cold shard per bank shard and no single
host map ever holds the whole population's rows.

Resident-memory formula (doctested in docs/PERFORMANCE.md):

>>> resident_slab_nbytes(16, 1000)   # 16-lane slab, T=1000 params
128000
>>> cold_row_nbytes(1000, "int8", 4)  # 4-segment layout: q + scales
1016
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.core.compress import (COLD_CODECS, cold_bits_per_param,
                                 cold_dtype, decode_cold_rows,
                                 encode_cold_rows)


def resident_slab_nbytes(k_pad: int, total: int) -> int:
    """Peak resident hot-slab bytes of one streamed round: params +
    momentum at ``(k_pad, T)`` float32 — a function of the *cohort
    bucket*, never of the population size.

    >>> resident_slab_nbytes(8, 100)
    6400
    """
    return 2 * 4 * int(k_pad) * int(total)


def cold_row_nbytes(total: int, codec: str, num_segments: int) -> int:
    """Host cold-store bytes of one stored client row: ``T`` params at
    the codec's width, plus one float32 affine scale per FlatLayout
    segment for ``int8``.

    >>> cold_row_nbytes(100, "f32", 4)
    400
    >>> cold_row_nbytes(100, "f16", 4)
    200
    >>> cold_row_nbytes(100, "int8", 4)
    116
    """
    per = cold_bits_per_param(codec) // 8
    scales = 4 * num_segments if codec == "int8" else 0
    return per * int(total) + scales


class ClientStore:
    """Compressed host store of cold client state behind the hot slab.

    ``layout`` is the model's FlatLayout; ``init_row`` the shared-init
    flat row (Algorithm 1's common y_{0,0}); ``codec`` one of
    ``compress.COLD_CODECS``. Rows are partitioned
    ``client_id % num_shards`` so a sharded engine keeps per-shard cold
    stores (``num_shards=1`` for the single-process engine)."""

    _GROW = 64  # minimum arena/slot-map growth quantum

    def __init__(self, layout, num_clusters: int, init_row: np.ndarray,
                 *, codec: str = "f32", num_shards: int = 1):
        assert codec in COLD_CODECS, codec
        assert num_shards >= 1
        self.layout = layout
        self.m = int(num_clusters)
        self.codec = codec
        self.num_shards = int(num_shards)
        row = np.asarray(init_row, np.float32).reshape(-1)
        assert row.shape[0] == layout.total, (row.shape, layout.total)
        #: (m, T) per-cluster reference params — a cold client's row IS
        #: its cluster's reference (see module docstring)
        self.cluster_params = np.tile(row[None, :], (self.m, 1))
        self._dt = cold_dtype(codec)
        self._sw = len(layout.segments) if codec == "int8" else 0
        self._reset_arenas()

    def _reset_arenas(self) -> None:
        ns, T = self.num_shards, self.layout.total
        # per-shard contiguous arenas over slots [0, _size): encoded q
        # rows, f32 scales, slot->id, per-slot dirty-since-snapshot bit
        self._q: List[np.ndarray] = [
            np.empty((0, T), self._dt) for _ in range(ns)]
        self._scale: List[np.ndarray] = [
            np.empty((0, self._sw), np.float32) for _ in range(ns)]
        self._ids: List[np.ndarray] = [
            np.empty((0,), np.int64) for _ in range(ns)]
        self._dirty: List[np.ndarray] = [
            np.empty((0,), bool) for _ in range(ns)]
        self._size: List[int] = [0] * ns
        # dense local-id (= client_id // num_shards) -> slot, -1 absent
        self._slot: List[np.ndarray] = [
            np.empty((0,), np.int64) for _ in range(ns)]
        # cached (ids, q, scale) of the last snapshot; stale once an
        # id is stored that the cache has never seen
        self._snap: Tuple[np.ndarray, np.ndarray, np.ndarray] | None = None
        self._snap_stale = True

    # -- bookkeeping ---------------------------------------------------------
    @property
    def num_stored(self) -> int:
        """Clients with a materialized (ever-sampled) momentum row."""
        return sum(self._size)

    @property
    def bits_per_row(self) -> int:
        """Paged bits per client row — what ``clock.paging_comm_time``
        charges each page-in/page-out row of device↔edge traffic."""
        return 8 * cold_row_nbytes(self.layout.total, self.codec,
                                   len(self.layout.segments))

    def shard_nbytes(self) -> List[int]:
        """Cold bytes held per shard (stored rows only)."""
        per = cold_row_nbytes(self.layout.total, self.codec,
                              len(self.layout.segments))
        return [per * sz for sz in self._size]

    @property
    def nbytes(self) -> int:
        """Total host bytes: cluster references + stored cold rows."""
        return int(self.cluster_params.nbytes) + sum(self.shard_nbytes())

    # -- arena plumbing ------------------------------------------------------
    def _lookup(self, sh: int, local: np.ndarray) -> np.ndarray:
        """Slots of local ids in shard ``sh`` (-1 where never stored)."""
        m = self._slot[sh]
        out = np.full(local.shape, -1, np.int64)
        ok = local < m.shape[0]
        out[ok] = m[local[ok]]
        return out

    def _ensure_slots(self, sh: int, ids: np.ndarray) -> np.ndarray:
        """Slots for ``ids`` (unique, this shard), appending fresh
        arena slots — and growing the arena — for unseen ids."""
        local = ids // self.num_shards
        m = self._slot[sh]
        need = int(local.max()) + 1 if local.size else 0
        if need > m.shape[0]:
            nm = np.full(max(need, 2 * m.shape[0], self._GROW), -1,
                         np.int64)
            nm[:m.shape[0]] = m
            self._slot[sh] = m = nm
        slots = m[local]
        fresh = slots < 0
        n_new = int(fresh.sum())
        if n_new:
            start = self._size[sh]
            end = start + n_new
            if end > self._q[sh].shape[0]:
                cap = max(end, 2 * self._q[sh].shape[0], self._GROW)
                for arrs, shape in ((self._q, (cap, self.layout.total)),
                                    (self._scale, (cap, self._sw))):
                    grown = np.empty(shape, arrs[sh].dtype)
                    grown[:start] = arrs[sh][:start]
                    arrs[sh] = grown
                gid = np.empty((cap,), np.int64)
                gid[:start] = self._ids[sh][:start]
                self._ids[sh] = gid
                gd = np.zeros((cap,), bool)
                gd[:start] = self._dirty[sh][:start]
                self._dirty[sh] = gd
            new_slots = np.arange(start, end, dtype=np.int64)
            m[local[fresh]] = new_slots
            self._ids[sh][new_slots] = ids[fresh]
            self._size[sh] = end
            self._snap_stale = True
            slots = m[local]
        return slots

    def _by_shard(self, ids: np.ndarray):
        """Yield ``(shard, positions)`` covering ``ids``."""
        if self.num_shards == 1:
            yield 0, slice(None)
            return
        sh = ids % self.num_shards
        for s in range(self.num_shards):
            pos = np.nonzero(sh == s)[0]
            if pos.size:
                yield s, pos

    # -- paging --------------------------------------------------------------
    def fetch(self, clients: np.ndarray) -> np.ndarray:
        """Decode the momentum rows of ``clients`` as (k, T) float32.
        Never-stored clients decode to zeros (their exact momentum).

        Warm-cohort fast path: when every requested row is stored, the
        gathered rows decode straight into the output — no (k, T)
        zero-fill memset on the all-hit path."""
        ids = np.asarray(clients, np.int64).reshape(-1)
        k, T = ids.shape[0], self.layout.total
        if k == 0:
            return np.zeros((0, T), np.float32)
        if self.num_shards == 1:
            slots = self._lookup(0, ids)
            if (slots >= 0).all():
                enc = {"q": self._q[0][slots],
                       "scale": self._scale[0][slots]}
                return decode_cold_rows(enc, self.codec,
                                        self.layout.segments)
        parts = []
        for s, pos in self._by_shard(ids):
            slots = self._lookup(s, ids[pos] // self.num_shards)
            parts.append((s, pos, slots))
        all_hit = all((slots >= 0).all() for _, _, slots in parts)
        out = (np.empty if all_hit else np.zeros)((k, T), np.float32)
        for s, pos, slots in parts:
            hit = slots >= 0
            if not hit.any():
                continue
            enc = {"q": self._q[s][slots[hit]],
                   "scale": self._scale[s][slots[hit]]}
            dec = decode_cold_rows(enc, self.codec, self.layout.segments)
            idx = np.arange(k)[pos][hit] if isinstance(pos, slice) \
                else pos[hit]
            out[idx] = dec
        return out

    def fetch_encoded(self, clients: np.ndarray) \
            -> Tuple[np.ndarray, np.ndarray]:
        """Gather the *encoded* momentum rows of ``clients`` as
        ``(q (k, T) codec-dtype, scale (k, nseg) f32)`` — the pipelined
        driver's page-in payload (decoded on device by
        ``kernels.cold_codec.decode_rows``). Never-stored clients get
        zero q and zero scales, which decode to exact zeros."""
        ids = np.asarray(clients, np.int64).reshape(-1)
        k, T = ids.shape[0], self.layout.total
        if self.num_shards == 1 and k:
            slots = self._lookup(0, ids)
            if (slots >= 0).all():
                return self._q[0][slots], self._scale[0][slots]
        q = np.zeros((k, T), self._dt)
        scale = np.zeros((k, self._sw), np.float32)
        for s, pos in self._by_shard(ids):
            slots = self._lookup(s, ids[pos] // self.num_shards)
            hit = slots >= 0
            if not hit.any():
                continue
            idx = np.arange(k)[pos][hit] if isinstance(pos, slice) \
                else pos[hit]
            q[idx] = self._q[s][slots[hit]]
            scale[idx] = self._scale[s][slots[hit]]
        return q, scale

    def commit(self, clients: np.ndarray, rows: np.ndarray) -> None:
        """Encode and store the momentum rows of ``clients`` (page-out).
        Re-committing a client overwrites its previous row."""
        ids = np.asarray(clients, np.int64).reshape(-1)
        rows = np.asarray(rows, np.float32)
        assert rows.shape == (ids.shape[0], self.layout.total)
        enc = encode_cold_rows(rows, self.codec, self.layout.segments)
        self.commit_encoded(ids, enc["q"], enc["scale"])

    def commit_encoded(self, clients: np.ndarray, q: np.ndarray,
                       scale: np.ndarray) -> None:
        """Store already-encoded rows verbatim (page-out of the
        pipelined driver, whose encode ran on device). Single scatter
        per shard; committed slots are marked dirty for the
        incremental :meth:`snapshot`."""
        ids = np.asarray(clients, np.int64).reshape(-1)
        q = np.asarray(q)
        scale = np.asarray(scale, np.float32)
        assert q.shape == (ids.shape[0], self.layout.total), q.shape
        assert q.dtype == self._dt, (q.dtype, self._dt)
        assert scale.shape == (ids.shape[0], self._sw), scale.shape
        for s, pos in self._by_shard(ids):
            slots = self._ensure_slots(s, ids[pos])
            self._q[s][slots] = q[pos]
            self._scale[s][slots] = scale[pos]
            self._dirty[s][slots] = True

    def update_clusters(self, refs: np.ndarray) -> None:
        """Replace the per-cluster reference params (page-out)."""
        refs = np.asarray(refs, np.float32)
        assert refs.shape == self.cluster_params.shape
        self.cluster_params = refs.copy()

    # -- checkpoint edge -----------------------------------------------------
    def snapshot(self) -> Dict[str, np.ndarray]:
        """Fixed-key host snapshot for ``RunCheckpoint``: stored rows
        stay *encoded*, so a save/restore round trip reproduces the
        identical cold bytes under every codec (no re-quantization).

        Incremental: the cached (ids, q, scale) arrays are patched in
        place for slots dirtied since the last snapshot; a full
        re-gather happens only when ids unseen by the cache appeared.
        Either path yields bit-identical output (asserted in tests)."""
        if self._snap is None or self._snap_stale:
            sizes = self._size
            all_ids = np.concatenate(
                [self._ids[s][:sizes[s]] for s in range(self.num_shards)])
            order = np.argsort(all_ids)
            ids = all_ids[order]
            q = np.concatenate(
                [self._q[s][:sizes[s]] for s in range(self.num_shards)]
            )[order]
            scale = np.concatenate(
                [self._scale[s][:sizes[s]]
                 for s in range(self.num_shards)])[order]
            self._snap = (ids, q, scale)
        else:
            ids, q, scale = self._snap
            for s in range(self.num_shards):
                d = self._dirty[s][:self._size[s]]
                if not d.any():
                    continue
                slots = np.nonzero(d)[0]
                pos = np.searchsorted(ids, self._ids[s][slots])
                q[pos] = self._q[s][slots]
                scale[pos] = self._scale[s][slots]
        for s in range(self.num_shards):
            self._dirty[s][:self._size[s]] = False
        self._snap_stale = False
        ids, q, scale = self._snap
        return {"cluster": self.cluster_params.copy(),
                "ids": ids.copy(), "mom_q": q.copy(),
                "mom_scale": scale.copy()}

    def load(self, state: Dict[str, np.ndarray]) -> None:
        """Restore :meth:`snapshot` output (mirror of ``_assign``)."""
        cluster = np.asarray(state["cluster"], np.float32)
        assert cluster.shape == self.cluster_params.shape, \
            (cluster.shape, self.cluster_params.shape)
        self.cluster_params = cluster.copy()
        self._reset_arenas()
        ids = np.asarray(state["ids"], np.int64)
        q = np.asarray(state["mom_q"]).astype(self._dt)
        scale = np.asarray(state["mom_scale"],
                           np.float32).reshape(ids.shape[0], self._sw)
        if ids.size:
            self.commit_encoded(ids, q, scale)
        # the loaded state IS the current snapshot — seed the cache
        order = np.argsort(ids)
        self._snap = (ids[order].copy(), q[order].copy(),
                      scale[order].copy())
        self._snap_stale = False
        for s in range(self.num_shards):
            self._dirty[s][:self._size[s]] = False
