"""Communicator-group registry: named per-tier collective groups.

The two-tier device→edge→backhaul layout used to be hard-coded into the
collectives layer — replica-axis names, flat-index math, and
``axis_index_groups`` lists recomputed ad hoc wherever a mean or a gossip
round was needed. The :class:`GroupRegistry` builds that state ONCE per
``(FLConfig, Mesh)`` and exposes it by tier name (vLLM's
``parallel_state`` pattern): ``device`` (intra-cluster), ``edge``
(backhaul gossip), and arbitrary deeper tiers (``region``, ``tier3``,
…), each a :class:`TierGroups` with member lists, mean/gossip wrappers
over the flat replica axis, and a cached per-tier
:class:`~repro.core.gossip.GossipSchedule`. Engines query the registry
instead of recomputing group math inline, which is what makes depth>2
``TierMix`` lowerings and multi-host meshes possible without touching
the callers again.

Tier semantics (see :class:`repro.core.topology.Hierarchy`): a
``TierMix(level)`` averages each tier-``level`` device group, then (for
``level >= 1``) gossips among the ``num_siblings`` aggregation nodes
under each common parent — a block-diagonal mixing matrix
``kron(I, H_block)``, which the existing edge-colored
:class:`~repro.core.gossip.GossipSchedule` machinery lowers unchanged
because the groups are contiguous in the flat replica numbering.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh

from repro.config import FLConfig
from repro.core import collectives as col
from repro.core import gossip as gsp
from repro.core import topology as topo


@dataclasses.dataclass(frozen=True)
class TierGroups:
    """One tier's communicator groups: ``members[g]`` lists the flat
    replica ids averaged together by ``TierMix(level)`` (contiguous under
    the static assignment), so ``len(members)`` groups of
    ``group_size`` replicas partition the mesh's flat replica axis."""
    name: str
    level: int
    num_groups: int
    group_size: int
    members: Tuple[Tuple[int, ...], ...]


class GroupRegistry:
    """Per-(FLConfig, Mesh) registry of tiered communicator groups.

    Built once (use :func:`get_registry` for the cached instance) and
    queried everywhere: ``tier(level_or_name)`` returns the
    :class:`TierGroups`, ``mean_in_body``/``gossip_in_body`` apply the
    tier's collective to a local shard inside an existing ``shard_map``
    body, ``mixing``/``operator`` expose the dense H_ℓ / (n, n) forms the
    dense engines and the clock consume, and ``gossip_schedule`` caches
    the edge-colored ppermute plan per ``(level, pi, mode)``.
    """

    def __init__(self, fl: FLConfig, mesh: Mesh):
        fl.validate()
        self.fl = fl
        self.mesh = mesh
        self.hier = topo.Hierarchy.from_config(fl)
        R = col.flat_axis_size(mesh)
        assert self.hier.n == R, (
            f"hierarchy has {self.hier.n} leaf devices but the mesh's "
            f"flat replica axis has {R}")
        tiers = []
        for lvl in range(self.hier.depth):
            ng = self.hier.num_groups(lvl)
            gs = self.hier.group_size(lvl)
            members = tuple(tuple(range(g * gs, (g + 1) * gs))
                            for g in range(ng))
            tiers.append(TierGroups(
                name=self.hier.tier_name(lvl), level=lvl,
                num_groups=ng, group_size=gs, members=members))
        self._tiers: Tuple[TierGroups, ...] = tuple(tiers)
        self._by_name: Dict[str, TierGroups] = {t.name: t for t in tiers}
        self._mixing: Dict[int, object] = {}
        self._scheds: Dict[Tuple[int, int, str], gsp.GossipSchedule] = {}

    # -- lookup -------------------------------------------------------------
    @property
    def depth(self) -> int:
        return self.hier.depth

    def tier(self, key: Union[int, str]) -> TierGroups:
        """The tier's groups, by level (int) or name ('device', 'edge',
        'region', 'tier<ℓ>')."""
        if isinstance(key, str):
            return self._by_name[key]
        return self._tiers[key]

    # -- dense forms (host-side numpy) --------------------------------------
    def mixing(self, level: int):
        """H_ℓ: the (num_nodes, num_nodes) block-diagonal Metropolis
        mixing matrix of tier ``level`` >= 1, cached."""
        if level not in self._mixing:
            self._mixing[level] = self.hier.mixing(
                level, self.fl.topology, self.fl.mixing, self.fl)
        return self._mixing[level]

    def operator(self, level: int, pi: int = 1):
        """Dense (n, n) ``TierMix(level, pi)`` operator under the static
        contiguous assignment (the legacy/flat engines' form)."""
        return self.hier.tier_operator(
            level, pi, self.fl.topology, self.fl.mixing, self.fl)

    def stale_operator(self, level: int, pi: int, phases, staleness: int,
                       advancing):
        """Dense ``TierMix(level, pi)`` operator gated for one async
        event: clusters in ``advancing`` apply the boundary reading only
        neighbors whose phase is within ``staleness`` of theirs; all
        other device rows are identity (see
        :func:`repro.core.gossip.staleness_mask`). Degenerates to
        :meth:`operator` when every cluster advances at one phase."""
        labels = np.repeat(np.arange(self.fl.num_clusters),
                           self.fl.devices_per_cluster)
        return gsp.staleness_mask(self.operator(level, pi), labels,
                                  phases, staleness, advancing)

    def faulted_operator(self, level: int, pi: int, cluster_down):
        """Dense ``TierMix(level, pi)`` operator degraded for an
        edge-outage round: dark clusters become identity rows and are
        dropped from surviving rows' reads, the deficit folded onto the
        diagonal (see :func:`repro.core.gossip.fault_gate`) — the tiered
        form of the per-op gating the plan-level ``FaultGate`` applies.
        Bitwise equal to :meth:`operator` when nothing is down."""
        labels = np.repeat(np.arange(self.fl.num_clusters),
                           self.fl.devices_per_cluster)
        return gsp.fault_gate(self.operator(level, pi), labels,
                              cluster_down)

    def gossip_schedule(self, level: int, pi: int,
                        mode: str = "rounds") -> gsp.GossipSchedule:
        """The tier's sparse ppermute plan: H_ℓ edge-colored into
        matchings over ``node_size(level)``-wide nodes; cached per
        ``(level, pi, mode)``. Block-diagonal H_ℓ colors into per-parent
        matchings that never cross parents."""
        key = (level, pi, mode)
        if key not in self._scheds:
            self._scheds[key] = gsp.GossipSchedule.build(
                self.mixing(level), pi, self.hier.node_size(level),
                mode=mode)
        return self._scheds[key]

    # -- collectives (inside an existing shard_map body) --------------------
    def mean_in_body(self, p, level: int):
        """Average the local f32 shard over this tier's groups (one
        grouped psum per leaf)."""
        t = self.tier(level)
        if t.group_size == 1:
            return p
        return gsp.group_mean_in_body(self.mesh, p, t.members)

    def gossip_in_body(self, p, level: int, pi: int,
                       mode: str = "rounds"):
        """π gossip rounds among tier-``level`` sibling nodes, applied to
        the local f32 shard via the tier's cached schedule."""
        return gsp.gossip_in_body(
            self.gossip_schedule(level, pi, mode), self.mesh, p)

    # -- collectives (standalone, on replica-stacked pytrees) ----------------
    def mean(self, params, specs, level: int):
        """Tier mean on replica-stacked params (leading axis R): wraps
        :meth:`mean_in_body` in its own ``shard_map``."""
        if self.tier(level).group_size == 1:
            return params

        def body(p):
            q = self.mean_in_body(
                jax.tree.map(lambda x: x.astype(jnp.float32), p), level)
            return jax.tree.map(lambda x, o: o.astype(x.dtype), p, q)
        return col.shard_map(body, self.mesh, (specs,), specs)(params)

    def gossip(self, params, specs, level: int, pi: int,
               mode: str = "rounds"):
        """Tier gossip on replica-stacked params via the tier's cached
        schedule (see :func:`repro.core.gossip.apply_gossip`)."""
        return gsp.apply_gossip(
            self.gossip_schedule(level, pi, mode), params, specs,
            self.mesh)

    # -- introspection -------------------------------------------------------
    def describe(self) -> str:
        """Human-readable tier → group table (one line per tier)."""
        lines = []
        for t in self._tiers:
            lines.append(
                f"level {t.level} ({t.name}): {t.num_groups} groups × "
                f"{t.group_size} replicas")
        return "\n".join(lines)


_REGISTRY_CACHE: Dict[Tuple[FLConfig, Mesh], GroupRegistry] = {}


def get_registry(fl: FLConfig, mesh: Mesh) -> GroupRegistry:
    """The process-wide cached registry for ``(fl, mesh)`` — built once,
    shared by every engine touching the same config and mesh."""
    key = (fl, mesh)
    if key not in _REGISTRY_CACHE:
        _REGISTRY_CACHE[key] = GroupRegistry(fl, mesh)
    return _REGISTRY_CACHE[key]
