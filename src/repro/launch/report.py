"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from the JSON
records written by repro.launch.dryrun.

  PYTHONPATH=src python -m repro.launch.report [--dir experiments/dryrun]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict, List

ARCH_ORDER = ["whisper-medium", "zamba2-2.7b", "qwen2.5-14b", "mamba2-2.7b",
              "pixtral-12b", "qwen2-0.5b", "minitron-8b", "mixtral-8x7b",
              "mistral-large-123b", "llama4-maverick-400b-a17b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.1f}µs"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def _fmt_b(x: float) -> str:
    for unit in ("B", "KB", "MB", "GB", "TB"):
        if abs(x) < 1024:
            return f"{x:.1f}{unit}"
        x /= 1024
    return f"{x:.1f}PB"


def load(dirname: str, mesh: str, suffix: str = "") -> List[Dict]:
    recs = []
    for p in sorted(glob.glob(os.path.join(dirname, f"*_{mesh}{suffix}.json"))):
        base = os.path.basename(p)[:-5]
        tag = base.split(f"_{mesh}")[1]
        if tag != suffix:
            continue
        recs.append(json.load(open(p)))
    recs.sort(key=lambda r: (ARCH_ORDER.index(r["arch"]),
                             SHAPE_ORDER.index(r["shape"])))
    return recs


def roofline_table(recs: List[Dict]) -> str:
    out = ["| arch | shape | compute | memory | collective | bottleneck | "
           "useful (6N·D/HLO) | what moves the dominant term |",
           "|---|---|---|---|---|---|---|---|"]
    for r in recs:
        if "terms" not in r:
            continue
        t = r["terms"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(t['compute_s'])} | "
            f"{_fmt_s(t['memory_s'])} | {_fmt_s(t['collective_s'])} | "
            f"**{t['bottleneck']}** | {r['useful_ratio']:.3f} | "
            f"{advice(r)} |")
    return "\n".join(out)


def advice(r: Dict) -> str:
    t = r["terms"]
    arch = r["arch"]
    heads_bad = arch in ("qwen2.5-14b", "qwen2-0.5b",
                         "llama4-maverick-400b-a17b")
    if t["bottleneck"] == "memory":
        if r["kind"] == "train":
            return ("flash-tile residency + remat keeps activations in "
                    "VMEM; CPU-HLO fusion pessimism inflates this term")
        return "KV-cache layout: shard kv_seq, fuse logits gather"
    if t["bottleneck"] == "collective":
        if r["kind"] == "train":
            return "sparse ppermute gossip instead of dense W_t all-gather"
        return "reduce TP all-reduces: fuse qkv/out projections"
    if heads_bad and r["kind"] != "decode":
        return "14/40 heads not divisible by 16: pad heads or context-par."
    return "MXU-align tiles; overlap collectives with compute"


def dryrun_table(recs: List[Dict]) -> str:
    out = ["| arch | shape | mesh | per-dev peak mem | HLO flops/dev | "
           "coll bytes/dev | compile |",
           "|---|---|---|---|---|---|---|"]
    for r in recs:
        mem = r.get("memory", {}).get("peak_bytes_per_device")
        prod = r.get("production", {})
        flops = r.get("flops_per_device") or prod.get("flops", 0)
        coll = (r.get("collective_bytes_per_device")
                if "collective_bytes_per_device" in r
                else prod.get("coll_bytes", 0))
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
            f"{_fmt_b(mem) if mem else 'n/a'} | "
            f"{flops:.3g} | "
            f"{_fmt_b(coll)} | "
            f"{prod.get('compile_s', '?')}s |")
    return "\n".join(out)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--mesh", default="16x16")
    ap.add_argument("--suffix", default="")
    ap.add_argument("--table", choices=("roofline", "dryrun"),
                    default="roofline")
    args = ap.parse_args()
    recs = load(args.dir, args.mesh, args.suffix)
    if args.table == "roofline":
        print(roofline_table(recs))
    else:
        print(dryrun_table(recs))


if __name__ == "__main__":
    main()
