"""End-to-end CE-FedAvg training driver (real execution, any device count).

Runs the sharded trainer on whatever devices exist (1 CPU device locally,
a real mesh on TPU), streaming synthetic federated token data, logging loss
per global round and checkpointing the gossip-averaged global model.

  PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --reduced \
      --rounds 5 --data-parallel 4 --model-parallel 1

``--engine bank`` instead runs the device-parallel flat-bank engine
(``core.sharded.ShardedBankCEFedAvg``): one (1, T) bank-row shard per
device on synthetic federated classification data — the same fused
single-pass mixing hot path the simulator benchmarks, on a real mesh:

  XLA_FLAGS=--xla_force_host_platform_device_count=8 PYTHONPATH=src \
      python -m repro.launch.train --engine bank --data-parallel 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.config import ExperimentConfig, FLConfig, TrainConfig
from repro.configs import ARCHS, get_model_config
from repro.core.sharded import ShardedCEFedAvg
from repro.data.lm import TokenStream
from repro.launch.mesh import make_mesh


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true",
                    help="smoke-scale model (CPU-friendly)")
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--data-parallel", type=int, default=1)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--clusters", type=int, default=0)
    ap.add_argument("--tau", type=int, default=2)
    ap.add_argument("--q", type=int, default=2)
    ap.add_argument("--pi", type=int, default=4)
    from repro.core.topology import TOPOLOGIES
    ap.add_argument("--gossip", choices=FLConfig.GOSSIP_IMPLS,
                    default="dense")
    ap.add_argument("--topology", default="ring",
                    choices=sorted(TOPOLOGIES))
    ap.add_argument("--er-prob", type=float, default=0.4)
    ap.add_argument("--algorithm", default="ce_fedavg")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=0.05)
    ap.add_argument("--ckpt", default="")
    ap.add_argument("--engine", choices=("pytree", "bank"),
                    default="pytree",
                    help="pytree: LM trainer with stacked replica pytrees; "
                         "bank: device-parallel flat (n, T) ModelBank "
                         "shards (classification workload)")
    from repro.core.program import SCHEDULES
    ap.add_argument("--schedule", choices=SCHEDULES, default="static",
                    help="round schedule (RoundProgram IR, bank engine): "
                         "static reproduces the paper's fixed tau/q/pi; "
                         "adaptive_tau gives slow clusters fewer local "
                         "steps; pi_decay runs deep gossip early, sparse "
                         "late")
    from repro.core.scenario import FAULTS, SCENARIOS
    ap.add_argument("--scenario", choices=sorted(SCENARIOS), default="",
                    help="named wall-clock scenario (bank engine): device "
                         "heterogeneity / client sampling / mobility — "
                         "adaptive_tau needs a heterogeneous one to bite")
    ap.add_argument("--faults", choices=sorted(FAULTS), default="",
                    help="named fault preset (bank engine, "
                         "docs/FAULT_MODEL.md): edge outages / backhaul "
                         "link loss / straggler timeouts injected into "
                         "the scenario; engines degrade gracefully "
                         "instead of crashing")
    ap.add_argument("--ckpt-dir", default="",
                    help="crash-consistent run checkpoint directory "
                         "(bank engine): full run state written "
                         "atomically every --ckpt-every rounds")
    ap.add_argument("--ckpt-every", type=int, default=1,
                    help="rounds between run checkpoints (with "
                         "--ckpt-dir)")
    ap.add_argument("--resume", action="store_true",
                    help="resume from the latest checkpoint in "
                         "--ckpt-dir (bit-identical to the "
                         "uninterrupted run)")
    ap.add_argument("--async-staleness", type=int, default=-1,
                    metavar="S",
                    help="bounded-staleness async rounds (bank engine): "
                         "each cluster advances to its next block as "
                         "soon as its own boundary clears, gossiping "
                         "only with neighbors within S blocks; 0 is the "
                         "global barrier (identical trajectory), -1 "
                         "(default) disables async execution")
    ap.add_argument("--hierarchy", default="",
                    help="depth>2 tier preset (bank engine): comma-"
                         "separated branching factors root->leaf, e.g. "
                         "'2,2,2' = 2 regions x 2 edges x 2 devices; "
                         "overrides --clusters/--data-parallel geometry")
    ap.add_argument("--population", type=int, default=0, metavar="N",
                    help="stream a virtual population of N clients "
                         "through the cold client store "
                         "(core/clientstore.py) instead of enumerating "
                         "devices: per-round resident memory is bounded "
                         "by the cohort, not N — n~1e5 runs on a laptop "
                         "(docs/PERFORMANCE.md, population scaling)")
    ap.add_argument("--cohort", type=int, default=8, metavar="K",
                    help="sampled clients per cluster per round with "
                         "--population (before sample_fraction/dropout)")
    ap.add_argument("--codec", choices=("f32", "f16", "int8"),
                    default="f32",
                    help="cold-row codec of the streamed client store "
                         "(--population): f32 lossless, f16/int8 trade "
                         "round-trip error for 2x/4x smaller cold rows")
    ap.add_argument("--pipeline", action="store_true",
                    help="overlap paging with compute (--population): "
                         "round t's page-out drains and round t+1's "
                         "cohort prefetches while round t runs; encoded "
                         "rows cross the host-device link and the cold "
                         "codec runs on device (kernels/cold_codec.py). "
                         "Bit-identical to the serial driver at f32 "
                         "(docs/PERFORMANCE.md, paging pipeline)")
    ap.add_argument("--multihost", action="store_true",
                    help="call jax.distributed.initialize before any "
                         "device use (real-cluster entry point; "
                         "auto-detects on Cloud TPU, or pass the "
                         "--coordinator/--num-processes/--process-id "
                         "trio / JAX_* env vars)")
    ap.add_argument("--coordinator", default="",
                    help="coordinator address host:port for --multihost")
    ap.add_argument("--num-processes", type=int, default=0)
    ap.add_argument("--process-id", type=int, default=-1)
    args = ap.parse_args(argv)
    if args.population:
        if (args.schedule != "static" or args.hierarchy or args.faults
                or args.async_staleness >= 0):
            ap.error("--population supports --scenario/--ckpt-dir/"
                     "--resume/--pipeline only (no schedules, "
                     "hierarchies, faults or async rounds over a "
                     "virtual population)")
    elif args.pipeline:
        ap.error("--pipeline overlaps the streamed engine's paging; "
                 "it requires --population")
    elif args.engine != "bank" and (args.schedule != "static"
                                    or args.scenario or args.hierarchy
                                    or args.async_staleness >= 0
                                    or args.faults or args.ckpt_dir
                                    or args.resume):
        ap.error("--schedule/--scenario/--hierarchy/--async-staleness/"
                 "--faults/--ckpt-dir/--resume require --engine bank")
    if args.resume and not args.ckpt_dir:
        ap.error("--resume needs --ckpt-dir")

    if args.multihost:
        from repro.launch.mesh import initialize_multihost
        initialize_multihost(
            coordinator_address=args.coordinator or None,
            num_processes=args.num_processes or None,
            process_id=args.process_id if args.process_id >= 0 else None)

    if args.population:
        return run_population_engine(args)
    if args.engine == "bank":
        return run_bank_engine(args)

    ndev = len(jax.devices())
    dp, mp = args.data_parallel, args.model_parallel
    assert dp * mp <= ndev, f"need {dp*mp} devices, have {ndev}"
    mesh = make_mesh((dp, mp), ("data", "model"))

    cfg = get_model_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    m = args.clusters or max(1, dp // 2)
    exp = ExperimentConfig(
        model=cfg,
        fl=FLConfig(algorithm=args.algorithm, num_clusters=m,
                    devices_per_cluster=max(dp // m, 1), tau=args.tau,
                    q=args.q, pi=args.pi, topology=args.topology,
                    er_prob=args.er_prob, gossip_impl=args.gossip),
        train=TrainConfig(optimizer="sgd", learning_rate=args.lr,
                          momentum=0.9),
    )
    tr = ShardedCEFedAvg(exp, mesh)
    R = tr.geo.num_replicas
    stream = TokenStream(cfg.vocab_size, R, tr.geo.cluster_of)

    with mesh:
        params, opt = jax.jit(tr.init_fn())(jax.random.PRNGKey(0))
        round_fn = jax.jit(tr.make_global_round(), donate_argnums=(0, 1))
        step = jnp.zeros((), jnp.int32)
        for r in range(args.rounds):
            t0 = time.time()
            # draw q·tau genuinely distinct microbatches from the stream:
            # (R, q*tau, B, S) -> (q, tau, R, B, S), one per local step
            qt = exp.fl.q * exp.fl.tau
            nb = stream.next_batch((qt, args.batch, args.seq))
            batch = {k: jnp.asarray(np.moveaxis(v, 0, 1).reshape(
                exp.fl.q, exp.fl.tau, R, args.batch, args.seq))
                for k, v in nb.items()}
            if cfg.family == "encdec":
                batch["frames"] = jnp.zeros(
                    (exp.fl.q, exp.fl.tau, R, args.batch, cfg.encoder_seq,
                     cfg.d_model), jnp.dtype(cfg.dtype))
            if cfg.family == "vlm":
                batch["patch_embeds"] = jnp.zeros(
                    (exp.fl.q, exp.fl.tau, R, args.batch, cfg.num_patches,
                     cfg.d_model), jnp.dtype(cfg.dtype))
            params, opt, metrics, step = round_fn(params, opt, batch, step)
            print(f"round {r}: loss={float(metrics['loss']):.4f} "
                  f"({time.time()-t0:.1f}s)", flush=True)

        if args.ckpt:
            # checkpoint the gossip-consensus global model (replica average)
            gl = jax.tree.map(lambda l: jnp.mean(l.astype(jnp.float32), 0),
                              params)
            save_checkpoint(args.ckpt, jax.device_get(gl),
                            {"arch": args.arch, "rounds": args.rounds})
            print(f"saved global model to {args.ckpt}")


def run_population_engine(args):
    """Drive the streamed client-store engine over a virtual population
    of ``--population`` clients (ISSUE 9): only each round's cohort (+
    one representative lane per cluster) is resident; cold state pages
    through the compressed host store. With ``--data-parallel R > 1``
    the hot slab is row-sharded over a replica mesh
    (``core.sharded.ShardedStreamedBank``)."""
    import dataclasses

    from repro.checkpoint import RunCheckpoint
    from repro.config import PopulationConfig
    from repro.core.cefedavg import FLSimulator
    from repro.core.clientstore import resident_slab_nbytes
    from repro.core.scenario import get_scenario
    from repro.data.federated import (build_fl_data, dirichlet_partition,
                                      make_synthetic_classification)
    from repro.models.cnn import apply_mlp_classifier, init_mlp_classifier

    m = args.clusters or 4
    # enumerated *data shards* (client_id mod n picks one) — a small
    # constant; the population itself is never enumerated
    n = m * 4
    fl = FLConfig(algorithm=args.algorithm, num_clusters=m,
                  devices_per_cluster=n // m, tau=args.tau, q=args.q,
                  pi=args.pi, topology=args.topology,
                  er_prob=args.er_prob)
    x, y = make_synthetic_classification(1600, 16, 8, seed=0, noise=2.5)
    tx, ty = make_synthetic_classification(400, 16, 8, seed=1, noise=2.5)
    parts = dirichlet_partition(y, n, alpha=0.3, seed=0)
    data = build_fl_data(x, y, parts, tx, ty, samples_per_device=64)
    base = get_scenario(args.scenario) if args.scenario else \
        get_scenario("sampled")
    scenario = dataclasses.replace(
        base, population=PopulationConfig(
            clients_per_cluster=max(1, -(-args.population // m)),
            cohort_per_cluster=args.cohort, codec=args.codec))
    init = lambda k: init_mlp_classifier(k, 16, 32, 8)   # noqa: E731
    if args.data_parallel > 1:
        from repro.core.sharded import ShardedStreamedBank
        from repro.launch.mesh import make_replica_mesh
        assert args.model_parallel == 1, \
            "slab rows are not tensor-parallel; use --model-parallel 1"
        mesh = make_replica_mesh(args.data_parallel)
        sim = ShardedStreamedBank(
            init, apply_mlp_classifier, fl, data, mesh, lr=args.lr,
            batch_size=args.batch, seed=0, scenario=scenario,
            pipeline=args.pipeline)
    else:
        sim = FLSimulator(
            init, apply_mlp_classifier, fl, data, lr=args.lr,
            batch_size=args.batch, seed=0, scenario=scenario,
            pipeline=args.pipeline)
    eng = sim.engine
    print(f"population engine: N={eng.population} virtual clients over "
          f"m={m} clusters (codec={args.codec}), slab cap "
          f"{max(sim._buckets)} rows x T={sim._layout.total} = "
          f"{resident_slab_nbytes(max(sim._buckets), sim._layout.total)}"
          f" B resident", flush=True)
    rc = RunCheckpoint(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if args.resume and rc is not None and rc.exists():
        meta = rc.restore(sim)
        start = meta["round"]
        print(f"resumed from {rc.path} at round {start}")
    for r in range(start, args.rounds):
        t0 = time.time()
        plan = sim.step_round()
        acc, loss = sim.evaluate(256)
        print(f"round {r}: acc={acc:.3f} loss={loss:.4f} "
              f"cohort={plan.clients.shape[0]} "
              f"slab={sim.last_bucket} rows "
              f"store={sim.store.nbytes / 1e6:.2f}MB "
              f"({time.time()-t0:.1f}s)", flush=True)
        if rc is not None and (r + 1) % max(args.ckpt_every, 1) == 0:
            rc.save(sim, round_idx=r + 1)
    print(f"peak resident slab: {sim.peak_slab_bytes} B "
          f"(population {eng.population}, cold store "
          f"{sim.store.nbytes / 1e6:.2f}MB host)")
    if args.ckpt:
        save_checkpoint(args.ckpt, jax.device_get(sim.global_model()),
                        {"engine": "streamed", "rounds": args.rounds})
        print(f"saved global model to {args.ckpt}")


def run_bank_engine(args):
    """Drive ``ShardedBankCEFedAvg`` — one bank row per device — on
    synthetic federated classification data, logging loss/accuracy of the
    edge models per global round (the paper's evaluation protocol)."""
    import dataclasses

    from repro.checkpoint import RunCheckpoint
    from repro.core.runtime import compute_bound_runtime_model
    from repro.core.scenario import get_faults, get_scenario
    from repro.core.sharded import ShardedBankCEFedAvg
    from repro.data.federated import (build_fl_data, dirichlet_partition,
                                      make_synthetic_classification)
    from repro.launch.mesh import make_replica_mesh
    from repro.models.cnn import apply_mlp_classifier, init_mlp_classifier

    n = args.data_parallel
    assert args.model_parallel == 1, \
        "bank rows are not tensor-parallel; use --model-parallel 1"
    if args.gossip != "dense":
        print(f"note: --gossip {args.gossip} only selects a backend for "
              "the pytree engine; the bank engine always lowers its "
              "boundaries to psum + ppermute matchings (static schedule) "
              "or weighted rotations (scenario rounds)")
    if args.hierarchy:
        # depth>2 preset: geometry comes from the branching factors
        tiers = tuple(int(s) for s in args.hierarchy.split(","))
        n = int(np.prod(tiers))
        m = int(np.prod(tiers[:-1]))
        fl = FLConfig(algorithm=args.algorithm, num_clusters=m,
                      devices_per_cluster=tiers[-1], tau=args.tau,
                      q=args.q, pi=args.pi, topology=args.topology,
                      er_prob=args.er_prob, hierarchy=tiers)
    else:
        m = args.clusters or max(1, n // 2)
        assert n % m == 0, f"{n} devices not divisible into {m} clusters"
        fl = FLConfig(algorithm=args.algorithm, num_clusters=m,
                      devices_per_cluster=n // m, tau=args.tau, q=args.q,
                      pi=args.pi, topology=args.topology,
                      er_prob=args.er_prob)
    mesh = make_replica_mesh(n)
    x, y = make_synthetic_classification(1600, 16, 8, seed=0, noise=2.5)
    tx, ty = make_synthetic_classification(400, 16, 8, seed=1, noise=2.5)
    parts = dirichlet_partition(y, n, alpha=0.3, seed=0)
    data = build_fl_data(x, y, parts, tx, ty, samples_per_device=64)
    scenario = get_scenario(args.scenario) if args.scenario else None
    if args.faults:
        # fault injection rides on the scenario engine; without a named
        # scenario, attach the faults to the homogeneous baseline
        scenario = dataclasses.replace(
            scenario or get_scenario("homogeneous"),
            faults=get_faults(args.faults))
    schedule = None if args.schedule == "static" else args.schedule
    sim = ShardedBankCEFedAvg(
        lambda k: init_mlp_classifier(k, 16, 32, 8), apply_mlp_classifier,
        fl, data, mesh, lr=args.lr, batch_size=args.batch, seed=0,
        scenario=scenario, schedule=schedule)
    use_async = args.async_staleness >= 0
    print(f"bank engine: n={n} rows x T={sim.bank.layout.total} "
          f"({sim.bank.layout.row_nbytes} B/row), m={m} clusters, "
          f"mesh={dict(mesh.shape)}, schedule={args.schedule}"
          + (f", scenario={args.scenario}" if args.scenario else "")
          + (f", faults={args.faults}" if args.faults else "")
          + (f", async_staleness={args.async_staleness}" if use_async
             else ""))
    rt = compute_bound_runtime_model() if use_async else None
    rc = RunCheckpoint(args.ckpt_dir) if args.ckpt_dir else None
    start = 0
    if args.resume and rc is not None and rc.exists():
        meta = rc.restore(
            sim, staleness=args.async_staleness if use_async else None)
        start = meta["round"]
        print(f"resumed from {rc.path} at round {start}")
    for r in range(start, args.rounds):
        t0 = time.time()
        if use_async:
            sim.step_round_async(args.async_staleness, rt)
            nev = len(sim.last_async["timeline"]["events"])
            extra = (f" events={nev} "
                     f"makespan={sim.last_async['timeline']['makespan']:.1f}s")
        else:
            sim.step_round()
            extra = ""
        acc, loss = sim.evaluate(256)
        print(f"round {r}: acc={acc:.3f} loss={loss:.4f} "
              f"({time.time()-t0:.1f}s){extra}", flush=True)
        if rc is not None and (r + 1) % max(args.ckpt_every, 1) == 0:
            rc.save(sim, round_idx=r + 1,
                    staleness=args.async_staleness if use_async else None)
    if args.ckpt:
        save_checkpoint(args.ckpt, jax.device_get(sim.global_model()),
                        {"engine": "bank", "rounds": args.rounds})
        print(f"saved global model to {args.ckpt}")


if __name__ == "__main__":
    main()
