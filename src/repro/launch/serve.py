"""Batched serving driver: prefill a batch of prompts, then decode tokens
with the per-family KV/SSM cache. Runs on real devices (CPU locally).

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-2.7b --reduced \
      --batch 4 --prompt-len 64 --decode-tokens 16
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_model_config
from repro.models import model as mdl


def prefill_into_cache(cfg, params, tokens, cache):
    """Sequential prefill via decode steps (cache-filling reference path)."""
    B, S = tokens.shape

    def body(carry, i):
        cache, last = carry
        logits, cache = mdl.decode_step(cfg, params, cache, tokens[:, i:i+1],
                                        i)
        return (cache, logits), None
    # simple python loop: prompt lengths are small in the demo driver
    logits = None
    for i in range(S):
        logits, cache = mdl.decode_step(
            cfg, params, cache, tokens[:, i:i + 1], jnp.asarray(i, jnp.int32))
    return logits, cache


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default="qwen2-0.5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--decode-tokens", type=int, default=16)
    ap.add_argument("--max-seq", type=int, default=256)
    args = ap.parse_args(argv)

    cfg = get_model_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    params, _ = mdl.init_model(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    cache, _ = mdl.init_decode_cache(cfg, args.batch, args.max_seq)
    step_fn = jax.jit(
        lambda p, c, t, q: mdl.decode_step(cfg, p, c, t, q))

    t0 = time.time()
    logits = None
    for i in range(args.prompt_len):
        logits, cache = step_fn(params, cache, prompts[:, i:i + 1],
                                jnp.asarray(i, jnp.int32))
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0

    toks = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    out = [toks]
    t0 = time.time()
    for i in range(args.decode_tokens):
        logits, cache = step_fn(params, cache, toks,
                                jnp.asarray(args.prompt_len + i, jnp.int32))
        toks = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
        out.append(toks)
    jax.block_until_ready(toks)
    t_decode = time.time() - t0

    gen = jnp.concatenate(out, axis=1)
    print(f"arch={cfg.name} batch={args.batch}")
    print(f"prefill: {args.prompt_len} steps in {t_prefill:.2f}s")
    print(f"decode:  {args.decode_tokens} tokens in {t_decode:.2f}s "
          f"({args.decode_tokens*args.batch/max(t_decode,1e-9):.1f} tok/s)")
    print("sample token ids:", np.asarray(gen[0])[:16].tolist())


if __name__ == "__main__":
    main()
