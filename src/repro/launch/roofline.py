"""Roofline-term extraction from compiled dry-run artifacts.

TPU v5e constants (per assignment): 197 TFLOP/s bf16 per chip, 819 GB/s
HBM, ~50 GB/s/link ICI. ``compiled.cost_analysis()`` on an SPMD-partitioned
module reports PER-DEVICE flops/bytes (verified empirically: a (1024x1024)
matmul on 8 devices reports 1/8 of the full FLOPs), so the three terms are

    compute    = flops_per_device / PEAK_FLOPS
    memory     = bytes_per_device / HBM_BW
    collective = collective_bytes_per_device / ICI_BW

which is algebraically identical to the assignment's
``HLO_total / (chips × peak)`` form. Collective bytes are not in
cost_analysis: we parse the post-SPMD HLO text and sum the shapes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute.
"""
from __future__ import annotations

import re
from typing import Any, Dict

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s
ICI_BW = 50e9             # bytes/s/link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLL_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*?)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute"
    r"|all-gather-start|all-reduce-start|collective-permute-start)\(",
    re.M)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    """Sum output bytes of every collective op in a (per-device) HLO module.

    'done' halves of async pairs are skipped (the 'start' carries the shape).
    """
    per_kind: Dict[str, int] = {}
    counts: Dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        out_type, kind = m.group(1), m.group(2)
        kind = kind.replace("-start", "")
        b = _shape_bytes(out_type)
        per_kind[kind] = per_kind.get(kind, 0) + b
        counts[kind] = counts.get(kind, 0) + 1
    return {"bytes_by_kind": per_kind, "counts": counts,
            "total_bytes": sum(per_kind.values())}


def cost_dict(compiled) -> Dict[str, float]:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return dict(ca)


def roofline_terms(flops_dev: float, bytes_dev: float,
                   coll_bytes_dev: float) -> Dict[str, float]:
    compute = flops_dev / PEAK_FLOPS
    memory = bytes_dev / HBM_BW
    coll = coll_bytes_dev / ICI_BW
    terms = {"compute_s": compute, "memory_s": memory, "collective_s": coll}
    dom = max(terms, key=terms.get)
    terms["bottleneck"] = dom.replace("_s", "")
    total = max(compute, memory, coll)
    terms["roofline_bound_s"] = total
    return terms


def model_flops(cfg, params, kind: str, tokens: int) -> float:
    """MODEL_FLOPS = 6·N·D (train) or 2·N·D (inference), with N = active
    params for MoE (experts scaled by k/E, shared expert kept whole)."""
    import jax
    expert_n = 0
    total_n = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        keys = [getattr(p, "key", getattr(p, "name", "")) for p in path]
        n = 1
        for s in leaf.shape:
            n *= s
        total_n += n
        if "moe" in keys and "shared" not in keys and any(
                k in ("w_gate", "w_up", "w_out") for k in keys):
            expert_n += n
    if cfg.num_experts:
        frac = cfg.experts_per_token / cfg.num_experts
        active = total_n - expert_n + expert_n * frac
    else:
        active = total_n
    mult = 6.0 if kind == "train" else 2.0
    return mult * active * tokens, total_n, active


def summarize(record: Dict[str, Any]) -> str:
    t = record["terms"]
    return (f"{record['arch']:26s} {record['shape']:12s} "
            f"{record['mesh']:9s} comp={t['compute_s']:9.4f}s "
            f"mem={t['memory_s']:9.4f}s coll={t['collective_s']:9.4f}s "
            f"-> {t['bottleneck']:10s} useful={record['useful_ratio']:.3f}")
