"""ShapeDtypeStruct input stand-ins for every (arch × input shape) combo.

``input_specs`` returns abstract inputs only — no device allocation — which
is what the multi-pod dry-run lowers against. Modality frontends are stubs
per the assignment carve-out: audio provides frame embeddings, VLM provides
patch embeddings, both already at d_model width.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from repro.config import ExperimentConfig, ModelConfig, ShapeConfig
from repro.models import model as mdl


def train_batch_shapes(exp: ExperimentConfig, shape: ShapeConfig,
                       R: int) -> Dict[str, jax.ShapeDtypeStruct]:
    """(q, tau, R, B_local, ...) abstract batch for one global round."""
    cfg = exp.model
    q, tau = exp.fl.q, exp.fl.tau
    assert shape.global_batch % R == 0, (shape.global_batch, R)
    B = shape.global_batch // R
    S = shape.seq_len
    lead = (q, tau, R, B)
    act = jnp.dtype(cfg.dtype)

    def tok(s):
        return jax.ShapeDtypeStruct(lead + s, jnp.int32)
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.family == "vlm":
        s_text = S - cfg.num_patches
        out["tokens"] = tok((s_text,))
        out["labels"] = tok((s_text,))
        out["patch_embeds"] = jax.ShapeDtypeStruct(
            lead + (cfg.num_patches, cfg.d_model), act)
    elif cfg.family == "encdec":
        out["tokens"] = tok((S,))
        out["labels"] = tok((S,))
        out["frames"] = jax.ShapeDtypeStruct(
            lead + (cfg.encoder_seq, cfg.d_model), act)
    else:
        out["tokens"] = tok((S,))
        out["labels"] = tok((S,))
    return out


def prefill_batch_shapes(cfg: ModelConfig, shape: ShapeConfig
                         ) -> Dict[str, jax.ShapeDtypeStruct]:
    B, S = shape.global_batch, shape.seq_len
    act = jnp.dtype(cfg.dtype)
    out: Dict[str, jax.ShapeDtypeStruct] = {}
    if cfg.family == "vlm":
        out["tokens"] = jax.ShapeDtypeStruct((B, S - cfg.num_patches),
                                             jnp.int32)
        out["patch_embeds"] = jax.ShapeDtypeStruct(
            (B, cfg.num_patches, cfg.d_model), act)
    elif cfg.family == "encdec":
        out["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        out["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq, cfg.d_model), act)
    else:
        out["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    return out


def decode_input_shapes(cfg: ModelConfig, shape: ShapeConfig):
    """(cache_shapes, tokens, pos) abstract inputs for serve_step."""
    B, S = shape.global_batch, shape.seq_len
    cache_shapes = jax.eval_shape(
        lambda: mdl.init_decode_cache(cfg, B, S)[0])
    tokens = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    return cache_shapes, tokens, pos
