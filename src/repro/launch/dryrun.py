import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input shape × mesh)
combination against ShapeDtypeStruct inputs (no allocation) and extract the
roofline terms from the compiled artifacts.

Methodology (documented in EXPERIMENTS.md §Dry-run):
- The PRODUCTION artifact keeps ``lax.scan`` over layers/steps — it is the
  lowering/compile proof and the source of ``memory_analysis()``.
- XLA's ``cost_analysis()`` counts a ``while`` body once (verified), so the
  roofline FLOPs/bytes/collective-bytes come from ANALYSIS artifacts with
  loops unrolled. Model depth is handled with an exact 2-point linear fit:
  lower at L=1 and L=2 layer-units, extrapolate cost(L) — exact because
  layers are homogeneous. Mixing operators (intra/inter) have no loops and
  are lowered at full parameter shapes.
- One train global round = qτ·local_step + q·intra_mix + inter_mix.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--gossip sparse]
"""
import argparse
import dataclasses
import json
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro import flags
from repro import sharding as sh
from repro.config import INPUT_SHAPES, ModelConfig
from repro.configs import applicable_shapes, ARCHS, get_experiment
from repro.core.sharded import (ShardedCEFedAvg, abstract_model,
                                make_decode_fn, make_prefill_fn, serve_specs)
from repro.launch import roofline as rf
from repro.launch import specs as sp
from repro.launch.mesh import make_production_mesh


def _ns(mesh, tree):
    return jax.tree.map(lambda p: NamedSharding(mesh, p), tree,
                        is_leaf=lambda x: isinstance(x, P))


def _stats(compiled) -> Dict[str, float]:
    ca = rf.cost_dict(compiled)
    coll = rf.collective_bytes(compiled.as_text())
    return {
        "flops": float(ca.get("flops", 0.0)),
        "bytes": float(ca.get("bytes accessed", 0.0)),
        "coll_bytes": float(coll["total_bytes"]),
        "coll": coll,
    }


import contextlib

_ACTIVE_MESH = None


def _compile(fn, args, in_shardings, out_shardings=None, donate=()):
    kw = {"in_shardings": in_shardings}
    if out_shardings is not None:
        kw["out_shardings"] = out_shardings
    if donate:
        kw["donate_argnums"] = donate
    ctx = _ACTIVE_MESH if _ACTIVE_MESH is not None else \
        contextlib.nullcontext()
    t0 = time.time()
    with ctx:
        lowered = jax.jit(fn, **kw).lower(*args)
    t1 = time.time()
    compiled = lowered.compile()
    t2 = time.time()
    return compiled, round(t1 - t0, 2), round(t2 - t1, 2)


def _memory(compiled) -> Dict[str, Any]:
    try:
        ma = compiled.memory_analysis()
        mem = {
            "argument_bytes": int(ma.argument_size_in_bytes),
            "output_bytes": int(ma.output_size_in_bytes),
            "temp_bytes": int(ma.temp_size_in_bytes),
            "alias_bytes": int(ma.alias_size_in_bytes),
        }
        mem["peak_bytes_per_device"] = (
            mem["argument_bytes"] + mem["output_bytes"] + mem["temp_bytes"]
            - mem["alias_bytes"])
        return mem
    except Exception as e:  # pragma: no cover
        return {"error": str(e)}


# --- layer-unit scaling (exact: homogeneous stacks) -------------------------

def layer_units(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return cfg.num_layers // cfg.attn_every
    if cfg.family == "moe" and cfg.moe_shared_expert:
        return cfg.num_layers // 2
    return cfg.num_layers


def with_units(cfg: ModelConfig, u: int) -> ModelConfig:
    if cfg.family == "hybrid":
        return dataclasses.replace(cfg, num_layers=u * cfg.attn_every)
    if cfg.family == "moe" and cfg.moe_shared_expert:
        return dataclasses.replace(cfg, num_layers=2 * u)
    if cfg.family == "encdec":
        return dataclasses.replace(cfg, num_layers=u, encoder_layers=u)
    return dataclasses.replace(cfg, num_layers=u)


def _fit(costs: Dict[int, Dict[str, float]], L: int) -> Dict[str, float]:
    (u1, c1), (u2, c2) = sorted(costs.items())
    out = {}
    for k in ("flops", "bytes", "coll_bytes"):
        slope = (c2[k] - c1[k]) / (u2 - u1)
        out[k] = max(c2[k] + slope * (L - u2), 0.0)
    return out




def _finish_skipped(record, cfg, shape, mesh):
    pshapes_count, _ = abstract_model(cfg)
    mf, total_n, active_n = rf.model_flops(
        cfg, pshapes_count, "train" if shape.kind == "train" else "infer",
        record["tokens_per_call"])
    record.update({"model_flops": mf, "params_total": int(total_n),
                   "params_active": int(active_n)})
    return record

# ---------------------------------------------------------------------------
# per-combination lowering
# ---------------------------------------------------------------------------

def lower_combo(arch: str, shape_name: str, *, multi_pod: bool = False,
                gossip: str = "dense", algorithm: str = "ce_fedavg",
                remat: bool = False, fl_overrides: Dict[str, Any] = None,
                skip_production: bool = False,
                skip_analysis: bool = False,
                model_overrides: Dict[str, Any] = None) -> Dict[str, Any]:
    global _ACTIVE_MESH
    mesh = make_production_mesh(multi_pod=multi_pod)
    _ACTIVE_MESH = mesh
    exp = get_experiment(arch, multi_pod=multi_pod)
    exp = exp.replace(fl=dataclasses.replace(
        exp.fl, gossip_impl=gossip, algorithm=algorithm,
        **(fl_overrides or {})))
    if remat:
        exp = exp.replace(train=dataclasses.replace(exp.train, remat=True))
    if model_overrides:
        exp = exp.replace(model=dataclasses.replace(exp.model,
                                                    **model_overrides))
    shape = INPUT_SHAPES[shape_name]
    cfg = exp.model
    record: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind, "algorithm": algorithm, "gossip": gossip,
        "remat": remat, "num_devices": mesh.size,
    }

    if shape.kind == "train":
        tr = ShardedCEFedAvg(exp, mesh)
        R = tr.geo.num_replicas
        batch_shapes = sp.train_batch_shapes(exp, shape, R)
        # ---- production artifact (scan form): proof + memory ----
        if not skip_production:
            compiled, tl, tc = _compile(
                tr.make_global_round(),
                (tr.param_shapes, tr.opt_shapes, batch_shapes,
                 jax.ShapeDtypeStruct((), jnp.int32)),
                tr.in_shardings(batch_shapes), tr.out_shardings(),
                donate=(0, 1))
            record["memory"] = _memory(compiled)
            record["production"] = {"lower_s": tl, "compile_s": tc,
                                    **{k: v for k, v in _stats(compiled).items()
                                       if k != "coll"}}
        # ---- analysis artifacts ----
        if skip_analysis:
            record["analysis"] = "skipped"
            record["tokens_per_call"] = (exp.fl.q * exp.fl.tau
                                         * shape.global_batch * shape.seq_len)
            return _finish_skipped(record, cfg, shape, mesh)
        with flags.analysis():
            costs = {}
            for u in (1, 2):
                e_u = exp.replace(model=with_units(cfg, u))
                tr_u = ShardedCEFedAvg(e_u, mesh)
                mb = {k: jax.ShapeDtypeStruct(v.shape[2:], v.dtype)
                      for k, v in batch_shapes.items()}
                c_u, _, _ = _compile(
                    tr_u.make_local_step(),
                    (tr_u.param_shapes, tr_u.opt_shapes, mb,
                     jax.ShapeDtypeStruct((), jnp.int32)),
                    (tr_u.in_shardings(mb)[0], tr_u.in_shardings(mb)[1],
                     _ns(mesh, tr_u.microbatch_specs(mb)),
                     NamedSharding(mesh, P())))
                costs[u] = _stats(c_u)
            step_cost = _fit(costs, layer_units(cfg))
            # mixing at full parameter shapes (loop-free under analysis)
            c_intra, _, _ = _compile(
                tr.make_intra_fn(), (tr.param_shapes,),
                (tr.in_shardings(batch_shapes)[0],))
            c_inter, _, _ = _compile(
                tr.make_inter_fn(), (tr.param_shapes,),
                (tr.in_shardings(batch_shapes)[0],))
            intra_cost, inter_cost = _stats(c_intra), _stats(c_inter)
        q, tau = exp.fl.q, exp.fl.tau
        flops = q * tau * step_cost["flops"] + q * intra_cost["flops"] \
            + inter_cost["flops"]
        bytes_ = q * tau * step_cost["bytes"] + q * intra_cost["bytes"] \
            + inter_cost["bytes"]
        coll = q * tau * step_cost["coll_bytes"] \
            + q * intra_cost["coll_bytes"] + inter_cost["coll_bytes"]
        record["components"] = {
            "local_step": step_cost,
            "intra_mix": {k: intra_cost[k] for k in
                          ("flops", "bytes", "coll_bytes")},
            "inter_mix": {k: inter_cost[k] for k in
                          ("flops", "bytes", "coll_bytes")},
            "inter_coll_by_kind": inter_cost["coll"]["bytes_by_kind"],
            "step_coll_by_kind": costs[2]["coll"]["bytes_by_kind"],
        }
        tokens = q * tau * shape.global_batch * shape.seq_len
        pshapes_count, _ = abstract_model(cfg)
    else:
        if shape.kind == "prefill":
            shapes, logical = abstract_model(cfg)
            pspecs = sh.resolve_specs(shapes, logical, mesh)
            batch_shapes = sp.prefill_batch_shapes(cfg, shape)
            bspecs = jax.tree.map(
                lambda s: P("data", *([None] * (len(s.shape) - 1))),
                batch_shapes)
            args = (shapes, batch_shapes)
            inshard = (_ns(mesh, pspecs), _ns(mesh, bspecs))
            fn_of = lambda c: make_prefill_fn(c)  # noqa: E731
            donate = ()
            outshard = None
            tokens = shape.global_batch * shape.seq_len
        else:
            pshapes, pspecs, cache_shapes, cspecs = serve_specs(
                cfg, mesh, shape.global_batch, shape.seq_len)
            _, tok_s, pos_s = sp.decode_input_shapes(cfg, shape)
            args = (pshapes, cache_shapes, tok_s, pos_s)
            inshard = (_ns(mesh, pspecs), _ns(mesh, cspecs),
                       NamedSharding(mesh, P()), NamedSharding(mesh, P()))
            outshard = (NamedSharding(mesh, P(None, None, "model")),
                        _ns(mesh, cspecs))
            fn_of = lambda c: make_decode_fn(c)  # noqa: E731
            donate = (1,)
            tokens = shape.global_batch
        # ---- production ----
        if not skip_production:
            compiled, tl, tc = _compile(fn_of(cfg), args, inshard, outshard,
                                        donate)
            record["memory"] = _memory(compiled)
            record["production"] = {"lower_s": tl, "compile_s": tc,
                                    **{k: v for k, v in _stats(compiled).items()
                                       if k != "coll"}}
        # ---- analysis (2-point layer fit, unrolled) ----
        if skip_analysis:
            record["analysis"] = "skipped"
            record["tokens_per_call"] = tokens
            return _finish_skipped(record, cfg, shape, mesh)
        with flags.analysis():
            costs = {}
            for u in (1, 2):
                cfg_u = with_units(cfg, u)
                if shape.kind == "prefill":
                    shapes_u, logical_u = abstract_model(cfg_u)
                    pspecs_u = sh.resolve_specs(shapes_u, logical_u, mesh)
                    args_u = (shapes_u, batch_shapes)
                    inshard_u = (_ns(mesh, pspecs_u), inshard[1])
                    out_u = None
                else:
                    ps_u, pp_u, cs_u, cp_u = serve_specs(
                        cfg_u, mesh, shape.global_batch, shape.seq_len)
                    args_u = (ps_u, cs_u, args[2], args[3])
                    inshard_u = (_ns(mesh, pp_u), _ns(mesh, cp_u),
                                 inshard[2], inshard[3])
                    out_u = (NamedSharding(mesh, P(None, None, "model")),
                             _ns(mesh, cp_u))
                c_u, _, _ = _compile(fn_of(cfg_u), args_u, inshard_u, out_u)
                costs[u] = _stats(c_u)
        fit = _fit(costs, layer_units(cfg))
        flops, bytes_, coll = fit["flops"], fit["bytes"], fit["coll_bytes"]
        record["components"] = {
            "per_unit_fit": fit,
            "coll_by_kind_u2": costs[2]["coll"]["bytes_by_kind"],
        }
        pshapes_count, _ = abstract_model(cfg)

    terms = rf.roofline_terms(flops, bytes_, coll)
    mf, total_n, active_n = rf.model_flops(
        cfg, pshapes_count, "train" if shape.kind == "train" else "infer",
        tokens)
    record.update({
        "tokens_per_call": tokens,
        "flops_per_device": flops,
        "bytes_per_device": bytes_,
        "collective_bytes_per_device": coll,
        "terms": terms,
        "model_flops": mf,
        "params_total": int(total_n),
        "params_active": int(active_n),
        "useful_ratio": mf / max(flops * mesh.size, 1.0),
    })
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), default=None)
    ap.add_argument("--shape", choices=sorted(INPUT_SHAPES), default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--gossip", choices=("dense", "sparse", "ringweight"), default="dense")
    ap.add_argument("--algorithm", default="ce_fedavg")
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--skip-production", action="store_true")
    ap.add_argument("--skip-analysis", action="store_true")
    ap.add_argument("--attn-seq-shard", action="store_true")
    ap.add_argument("--head-pad", type=int, default=0)
    ap.add_argument("--moe-local", action="store_true")
    ap.add_argument("--swa", type=int, default=0,
                    help="serve with a sliding window (dense-arch long-"
                         "context variant)")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--tag", default="")
    args = ap.parse_args()

    combos = []
    if args.all:
        for arch in ARCHS:
            for shape in applicable_shapes(arch):
                combos.append((arch, shape))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        combos.append((args.arch, args.shape))

    os.makedirs(args.out, exist_ok=True)
    failures = []
    for arch, shape in combos:
        name = f"{arch}_{shape}_{'2x16x16' if args.multi_pod else '16x16'}"
        if args.gossip != "dense":
            name += f"_{args.gossip}"
        if args.algorithm != "ce_fedavg":
            name += f"_{args.algorithm}"
        if args.remat:
            name += "_remat"
        if args.tag:
            name += f"_{args.tag}"
        t0 = time.time()
        try:
            rec = lower_combo(arch, shape, multi_pod=args.multi_pod,
                              gossip=args.gossip, algorithm=args.algorithm,
                              remat=args.remat,
                              skip_production=args.skip_production,
                              skip_analysis=args.skip_analysis,
                              model_overrides=(
                                  ({"attn_seq_shard": True}
                                   if args.attn_seq_shard else {}) |
                                  ({"head_pad_to": args.head_pad}
                                   if args.head_pad else {}) |
                                  ({"moe_local_dispatch": True}
                                   if args.moe_local else {}) |
                                  ({"sliding_window": args.swa}
                                   if args.swa else {}) or None))
            rec["wall_s"] = round(time.time() - t0, 1)
            with open(os.path.join(args.out, name + ".json"), "w") as f:
                json.dump(rec, f, indent=1)
            if "terms" in rec:
                print(rf.summarize(rec), f"[{rec['wall_s']}s]", flush=True)
            else:
                print(f"{name} compiled OK (analysis skipped) "
                      f"mem={rec.get('memory',{}).get('peak_bytes_per_device','?')} "
                      f"[{rec['wall_s']}s]", flush=True)
        except Exception as e:
            failures.append((name, repr(e)))
            print(f"{name} FAILED: {e}", flush=True)
            traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for n, e in failures:
            print(" ", n, e)
        raise SystemExit(1)
    print(f"\nall {len(combos)} combinations lowered + compiled OK")


if __name__ == "__main__":
    main()
