"""Wall-clock time-to-accuracy CLI: algorithms × scenarios (paper §6).

Couples the paper-faithful ``FLSimulator`` to the event clock
(``core/clock.py``) under named heterogeneity/mobility/sampling scenarios
(``core/scenario.py``), reporting for every (scenario, algorithm) pair the
simulated seconds to a target accuracy under the paper's §6.1 hardware
profile.

  PYTHONPATH=src python -m repro.launch.time_to_accuracy \\
      --scenarios homogeneous lognormal mobility \\
      --algorithms ce_fedavg hier_favg fedavg --target 0.75 --rounds 20
"""
from __future__ import annotations

import argparse
import dataclasses

import jax.numpy as jnp

from repro.config import FLConfig
from repro.core.cefedavg import FLSimulator
from repro.core.clock import run_wall_clock, time_to_accuracy
from repro.core.runtime import paper_runtime_model
from repro.core.scenario import SCENARIOS, get_scenario
from repro.data.federated import (build_fl_data, dirichlet_partition,
                                  make_synthetic_classification)
from repro.models.cnn import apply_mlp_classifier, init_mlp_classifier

MLP_DIM, MLP_CLASSES = 16, 8


def build_sim(fl: FLConfig, scenario, *, noise: float, alpha: float,
              lr: float, seed: int) -> FLSimulator:
    """MLP-surrogate federated task (same partitioners/orderings as the
    paper's image runs — see benchmarks/common.py for the rationale)."""
    x, y = make_synthetic_classification(1600, MLP_DIM, MLP_CLASSES,
                                         seed=seed, noise=noise)
    tx, ty = make_synthetic_classification(400, MLP_DIM, MLP_CLASSES,
                                           seed=seed + 1, noise=noise)
    parts = dirichlet_partition(y, fl.n, alpha, seed)
    data = {k: jnp.asarray(v) for k, v in
            build_fl_data(x, y, parts, tx, ty, 64).items()}
    return FLSimulator(
        lambda k: init_mlp_classifier(k, MLP_DIM, 32, MLP_CLASSES),
        apply_mlp_classifier, fl, data, lr=lr, batch_size=16, seed=seed,
        scenario=scenario)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--algorithms", nargs="+",
                    default=["ce_fedavg", "hier_favg", "fedavg"])
    ap.add_argument("--scenarios", nargs="+", choices=sorted(SCENARIOS),
                    default=["homogeneous", "lognormal", "mobility"])
    ap.add_argument("--target", type=float, default=0.75)
    ap.add_argument("--rounds", type=int, default=20)
    ap.add_argument("--clusters", type=int, default=4)
    ap.add_argument("--dpc", type=int, default=4)
    ap.add_argument("--tau", type=int, default=2)
    ap.add_argument("--q", type=int, default=4)
    ap.add_argument("--pi", type=int, default=10)
    ap.add_argument("--topology", default="ring")
    ap.add_argument("--lr", type=float, default=0.02)
    ap.add_argument("--noise", type=float, default=3.0)
    ap.add_argument("--alpha", type=float, default=0.1)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    rt = paper_runtime_model()                  # paper §6.1 constants
    print(f"{'scenario':14s} {'algorithm':13s} {'final_acc':>9s} "
          f"{'rounds@T':>8s} {'wall@T':>12s}")
    results = {}
    for sname in args.scenarios:
        sc = dataclasses.replace(get_scenario(sname), seed=args.seed)
        for algo in args.algorithms:
            fl = FLConfig(algorithm=algo, num_clusters=args.clusters,
                          devices_per_cluster=args.dpc, tau=args.tau,
                          q=args.q, pi=args.pi, topology=args.topology)
            sim = build_sim(fl, sc, noise=args.noise, alpha=args.alpha,
                            lr=args.lr, seed=args.seed)
            hist = run_wall_clock(sim, rt, args.rounds)
            tta = time_to_accuracy(hist, args.target)
            rounds_at = next((r for r, a in zip(hist["round"], hist["acc"])
                              if a >= args.target), None)
            results[(sname, algo)] = tta
            print(f"{sname:14s} {algo:13s} {hist['acc'][-1]:9.3f} "
                  f"{'-' if rounds_at is None else rounds_at:>8} "
                  f"{'never' if tta is None else f'{tta:,.0f}s':>12}")
    for sname in args.scenarios:
        ce = results.get((sname, "ce_fedavg"))
        others = {a: results.get((sname, a)) for a in args.algorithms
                  if a != "ce_fedavg"}
        if ce is not None and all(v is not None for v in others.values()):
            beat = ", ".join(f"{(1 - ce / v) * 100:.0f}% vs {a}"
                             for a, v in others.items())
            print(f"[{sname}] CE-FedAvg reaches {args.target:.0%} faster: "
                  f"{beat}")


if __name__ == "__main__":
    main()
