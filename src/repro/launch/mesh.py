"""Production mesh construction.

All functions (never module-level constants) so importing this module never
touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any import.
"""
from __future__ import annotations

import numpy as np

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    ndev = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < ndev:
        raise RuntimeError(
            f"need {ndev} devices, have {len(devices)}; run under "
            "XLA_FLAGS=--xla_force_host_platform_device_count=512")
    dev = np.asarray(devices[:ndev]).reshape(shape)
    return Mesh(dev, axes)


def make_mesh(shape, axes) -> Mesh:
    """Arbitrary (test-sized) mesh over the first prod(shape) devices."""
    ndev = int(np.prod(shape))
    dev = np.asarray(jax.devices()[:ndev]).reshape(shape)
    return Mesh(dev, axes)


def make_replica_mesh(num_replicas: int, *, pods: int = 1) -> Mesh:
    """Mesh for the sharded ModelBank engine: one bank row per device on
    the replica axes, model axis fixed at 1 (bank rows are not
    tensor-parallel). ``pods > 1`` adds a leading ``pod`` axis so
    multi-pod edge crossings are exercised (replica id =
    ``pod_idx * data_size + data_idx``)."""
    devices = jax.devices()
    if len(devices) < num_replicas:
        raise RuntimeError(
            f"need {num_replicas} devices for {num_replicas} bank rows, "
            f"have {len(devices)}; run under XLA_FLAGS="
            f"--xla_force_host_platform_device_count={num_replicas}")
    assert num_replicas % pods == 0, (num_replicas, pods)
    if pods > 1:
        shape: tuple = (pods, num_replicas // pods, 1)
        axes: tuple = ("pod", "data", "model")
    else:
        shape, axes = (num_replicas, 1), ("data", "model")
    dev = np.asarray(devices[:num_replicas]).reshape(shape)
    return Mesh(dev, axes)


def make_tier_mesh(hierarchy, *, pods: int = 1) -> Mesh:
    """Mesh for a depth-L hierarchy preset (branching factors root→leaf,
    e.g. ``(2, 2, 2)`` = 2 regions × 2 edges × 2 devices): one bank row
    per leaf device. The tier structure lives in ``FLConfig.hierarchy``
    / the GroupRegistry, not in extra mesh axes — the flat replica
    numbering is what the contiguous tier groups index."""
    n = int(np.prod(tuple(hierarchy)))
    return make_replica_mesh(n, pods=pods)


def initialize_multihost(coordinator_address: str | None = None,
                         num_processes: int | None = None,
                         process_id: int | None = None) -> None:
    """Real-cluster entry point: call before any other jax use on each host
    of a pod slice. On Cloud TPU all arguments are auto-detected from the
    environment; on other clusters pass them explicitly (or set
    JAX_COORDINATOR_ADDRESS / JAX_NUM_PROCESSES / JAX_PROCESS_ID)."""
    import os as _os
    kw = {}
    if coordinator_address or _os.environ.get("JAX_COORDINATOR_ADDRESS"):
        kw["coordinator_address"] = (
            coordinator_address or _os.environ["JAX_COORDINATOR_ADDRESS"])
        kw["num_processes"] = num_processes or int(
            _os.environ["JAX_NUM_PROCESSES"])
        kw["process_id"] = process_id or int(_os.environ["JAX_PROCESS_ID"])
    jax.distributed.initialize(**kw)
