"""Configuration system for the CFEL/CE-FedAvg framework.

Plain dataclasses (no external deps). Every assigned architecture provides a
``ModelConfig`` in ``repro.configs.<id>``; the FL layer, launcher and dry-run
consume ``ExperimentConfig`` which composes model + FL + mesh + train/serve.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Tuple

# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

FAMILIES = ("dense", "moe", "ssm", "hybrid", "encdec", "vlm", "cnn")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # one of FAMILIES
    num_layers: int
    d_model: int
    num_heads: int = 0            # 0 for attention-free archs
    num_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0             # 0 -> d_model // num_heads
    qkv_bias: bool = False
    mlp_act: str = "silu"         # silu | gelu | relu2 (nemotron squared relu)
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    rope_theta: float = 10000.0
    use_rope: bool = True         # whisper uses learned positions instead
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    capacity_factor: float = 1.25
    moe_shared_expert: bool = False   # llama4 has a shared expert
    # --- SSM (Mamba-2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv_width: int = 4
    # --- hybrid (Zamba2-style): one *shared* attention block every k SSM blocks
    attn_every: int = 0
    # --- attention locality ---
    sliding_window: int = 0       # 0 = full attention
    # --- encoder/decoder (Whisper) ---
    encoder_layers: int = 0
    encoder_seq: int = 1500       # stub audio frontend: #frames after conv
    # --- VLM (Pixtral): stub vision frontend
    num_patches: int = 0          # patch embeddings prepended to text
    # --- beyond-paper performance knobs ---
    attn_seq_shard: bool = False   # context-parallel attention core: shard
    #   the query sequence over the model axis (exact; rescues archs whose
    #   head count is not divisible by the model-parallel degree)
    moe_local_dispatch: bool = False  # dispatch MoE tokens within each
    #   batch row (per-device capacity) instead of globally: keeps the
    #   capacity buffer sharded with the batch — removes the full-buffer
    #   cross-shard all-reduce the global scatter otherwise lowers to
    head_pad_to: int = 0           # pad query heads to this count with
    #   zero-masked (permanently inert) heads so they shard evenly over the
    #   model axis; mathematically identical outputs, ~heads_pad/heads extra
    #   attention FLOPs, standard TP collectives
    # --- numerics ---
    dtype: str = "bfloat16"
    param_dtype: str = "bfloat16"
    # --- citation (model card / arXiv that fixes the shape) ---
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic serve path exists (SSM state or sliding window)."""
        return self.family in ("ssm", "hybrid") or self.sliding_window > 0

    def reduced(self, **overrides) -> "ModelConfig":
        """A smoke-test-sized variant of the same family (<=2 layers etc.)."""
        small = dict(
            num_layers=2,
            d_model=min(self.d_model, 256),
            num_heads=min(self.num_heads, 4) if self.num_heads else 0,
            num_kv_heads=min(self.num_kv_heads, 2) if self.num_kv_heads else 0,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            head_dim=64 if self.num_heads else 0,
            num_experts=min(self.num_experts, 4) if self.num_experts else 0,
            experts_per_token=min(self.experts_per_token, 2)
            if self.experts_per_token else 0,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_chunk=64 if self.ssm_state else 256,
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_seq=32 if self.encoder_layers else 1500,
            num_patches=8 if self.num_patches else 0,
            attn_every=2 if self.attn_every else 0,
            sliding_window=min(self.sliding_window, 64)
            if self.sliding_window else 0,
            dtype="float32",
            param_dtype="float32",
        )
        small.update(overrides)
        return dataclasses.replace(self, **small)


# ---------------------------------------------------------------------------
# Federated learning (the paper's knobs)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FLConfig:
    algorithm: str = "ce_fedavg"   # ce_fedavg | fedavg | hier_favg | local_edge | dec_local_sgd
    num_clusters: int = 4          # m
    devices_per_cluster: int = 4   # n_i (equal clusters by default)
    tau: int = 2                   # intra-cluster aggregation period
    q: int = 8                     # edge rounds per global round
    pi: int = 10                   # gossip steps per inter-cluster aggregation
    topology: str = "ring"         # ring | complete | star | torus | erdos_renyi
    er_prob: float = 0.4           # for erdos_renyi
    topology_seed: int = 0
    mixing: str = "metropolis"     # metropolis | uniform_neighbor
    # sharded-trainer mapping; all three backends support every topology:
    #   dense      paper-faithful (R,R)·(R,…) contraction (all-gather)
    #   sparse     π gossip rounds of weighted neighbor ppermute matchings
    #   ringweight exact H^π in M−1 weighted cyclic rotations
    gossip_impl: str = "dense"
    cluster_axis: str = "data"     # mesh axis along which replicas/clusters live
    # depth>2 hierarchies: branching factors root→leaf, e.g. (2, 2, 2) =
    # 2 regions × 2 edges × 2 devices. () keeps the paper's two tiers
    # (num_clusters, devices_per_cluster). When set, the last entry must
    # equal devices_per_cluster and the product of the rest num_clusters,
    # so the depth-2 projection of the hierarchy IS the existing config.
    hierarchy: Tuple[int, ...] = ()

    GOSSIP_IMPLS = ("dense", "sparse", "ringweight")

    @property
    def n(self) -> int:
        return self.num_clusters * self.devices_per_cluster

    @property
    def tiers(self) -> Tuple[int, ...]:
        """Resolved branching factors root→leaf: ``hierarchy`` when set,
        else the two-tier ``(num_clusters, devices_per_cluster)``."""
        return tuple(self.hierarchy) or (self.num_clusters,
                                         self.devices_per_cluster)

    @property
    def depth(self) -> int:
        """Number of hierarchy tiers (2 for the paper's device→edge)."""
        return len(self.tiers)

    def round_program(self, *, privatize: bool = False,
                      compress: bool = False):
        """Compile this config's τ/q/π knobs into the canonical
        :class:`repro.core.program.RoundProgram` — the declarative round
        schedule every engine lowers (see ``core/program.py``)."""
        from repro.core.program import canonical_program
        return canonical_program(self, privatize=privatize,
                                 compress=compress)

    def validate(self) -> None:
        assert self.algorithm in (
            "ce_fedavg", "fedavg", "hier_favg", "local_edge", "dec_local_sgd")
        assert self.tau >= 1 and self.q >= 1 and self.pi >= 1
        assert self.num_clusters >= 1 and self.devices_per_cluster >= 1
        from repro.core.topology import TOPOLOGIES  # single source of truth
        assert self.topology in TOPOLOGIES, \
            f"unknown topology {self.topology!r}"
        assert self.gossip_impl in self.GOSSIP_IMPLS, \
            f"unknown gossip_impl {self.gossip_impl!r}"
        if self.topology == "torus":
            side = int(round(self.num_clusters ** 0.5))
            assert side * side == self.num_clusters, \
                "torus backhaul needs a square number of clusters"
        if self.topology == "erdos_renyi":
            assert 0.0 < self.er_prob <= 1.0, \
                f"er_prob must be in (0, 1], got {self.er_prob}"
        if self.hierarchy:
            tiers = tuple(self.hierarchy)
            assert len(tiers) >= 2, \
                f"hierarchy needs >= 2 tiers, got {tiers}"
            assert all(t >= 1 for t in tiers), \
                f"hierarchy branching factors must be >= 1: {tiers}"
            prod = 1
            for t in tiers[:-1]:
                prod *= t
            assert prod == self.num_clusters, \
                f"prod(hierarchy[:-1])={prod} != num_clusters=" \
                f"{self.num_clusters}"
            assert tiers[-1] == self.devices_per_cluster, \
                f"hierarchy[-1]={tiers[-1]} != devices_per_cluster=" \
                f"{self.devices_per_cluster}"
            if len(tiers) > 2:
                assert self.algorithm == "ce_fedavg", \
                    "depth>2 hierarchies exist for ce_fedavg only " \
                    f"(got {self.algorithm!r})"
        if self.gossip_impl in ("sparse", "ringweight"):
            # the sparse backends lower the inter-cluster operator with
            # collectives; that path exists for the gossip algorithms only
            assert self.algorithm in ("ce_fedavg", "dec_local_sgd"), \
                f"{self.gossip_impl!r} backend requires a gossip algorithm" \
                f" (ce_fedavg/dec_local_sgd), not {self.algorithm!r}"


# ---------------------------------------------------------------------------
# Wall-clock scenarios (heterogeneity / sampling / mobility)
# ---------------------------------------------------------------------------

SPEED_DISTS = ("homogeneous", "uniform", "lognormal", "bimodal")


@dataclass(frozen=True)
class FaultConfig:
    """Edge/backhaul fault injection knobs (ISSUE 8).

    Realized per round by ``core.scenario.FaultModel`` with draws keyed
    by ``(seed, round, stream, entity)`` — the fault trace at round t is
    a pure function of (config, t), so a killed-and-resumed run replays
    the identical faults it would have seen uninterrupted.

    Three fault classes, mirroring what a mobile-edge deployment
    actually loses:

    - **Edge-server outages**: each round, each cluster independently
      starts an outage window with prob ``outage_prob``; the window
      lasts 1..``outage_len`` rounds (keyed draw at window start). A
      dark cluster trains nothing and its rows/columns are gated out of
      every mixing operator (identity rows, deficit folded onto the
      diagonal — see ``gossip.fault_gate``).
    - **Backhaul link loss**: each inter-edge backhaul link
      independently drops for the round with prob ``link_drop_prob``;
      the round's gossip runs on the surviving (possibly partitioned)
      graph, re-weighted per connected component.
    - **Straggler timeouts**: a participating device whose local-steps
      compute exceeds ``timeout_factor`` x the cohort-median compute is
      aborted and retried with an exponentially backed-off budget
      (``retry_backoff``); after ``max_retries`` failed retries it is
      dropped from the round's cohort. The aborted-attempt ladder is
      priced in ``EventClock`` (see ``clock.fault_compute_penalty``).
    """
    outage_prob: float = 0.0    # per-cluster per-round window-start prob
    outage_len: int = 1         # max outage window length (rounds)
    link_drop_prob: float = 0.0  # per-backhaul-link per-round drop prob
    timeout_factor: float = 0.0  # x median compute; 0 disables timeouts
    max_retries: int = 2        # retry attempts before dropping a device
    retry_backoff: float = 1.5  # budget multiplier per retry attempt
    seed: int = 0               # fault stream seed (independent of scenario)

    def validate(self) -> None:
        assert 0.0 <= self.outage_prob < 1.0
        assert self.outage_len >= 1
        assert 0.0 <= self.link_drop_prob < 1.0
        assert self.timeout_factor >= 0.0
        assert self.max_retries >= 0
        assert self.retry_backoff >= 1.0

    @property
    def trivial(self) -> bool:
        """True iff no fault can ever fire (the parity regime: a
        fault-gated run must match the ungated run bitwise)."""
        return (self.outage_prob == 0.0 and self.link_drop_prob == 0.0
                and self.timeout_factor == 0.0)


@dataclass(frozen=True)
class PopulationConfig:
    """Virtual-client population (ISSUE 9): per-cluster member-count
    *distributions* replace enumerated devices, so a cluster can claim
    10^4 members without 10^4 resident bank rows.

    Realized once (keyed by the scenario seed) by
    ``core.scenario.PopulationEngine``: each cluster draws its member
    count from ``size_dist`` around ``clients_per_cluster``, client ids
    are the implicit contiguous ranges under the cluster-size prefix
    sums, and every per-round draw (cohort sampling, visit mobility,
    per-client speeds) is keyed by ``SeedSequence`` — never stateful —
    so a resumed run replays the identical population trace. Client
    state lives in the streaming ``core.clientstore.ClientStore``:
    only each round's cohort is resident, cold rows are stored under
    ``codec``, and each cohort client trains on data shard
    ``client_id % n`` of the enumerated per-device data."""
    clients_per_cluster: int = 1000  # mean cluster size
    size_dist: str = "fixed"         # fixed | uniform | lognormal
    size_spread: float = 0.0         # uniform half-width / lognormal sigma
    cohort_per_cluster: int = 4      # sampled members per cluster per round
    codec: str = "f32"               # cold-row codec (compress.COLD_CODECS)

    SIZE_DISTS = ("fixed", "uniform", "lognormal")

    def validate(self) -> None:
        assert self.clients_per_cluster >= 1
        assert self.size_dist in self.SIZE_DISTS, \
            f"unknown size_dist {self.size_dist!r}"
        assert self.size_spread >= 0.0
        if self.size_dist == "uniform":
            assert self.size_spread < 1.0, \
                "uniform size spread must leave clusters nonempty"
        assert self.cohort_per_cluster >= 1
        from repro.core.compress import COLD_CODECS
        assert self.codec in COLD_CODECS, \
            f"unknown cold-row codec {self.codec!r}"


@dataclass(frozen=True)
class ScenarioConfig:
    """A wall-clock scenario: who trains each round, how fast, and where.

    Consumed by ``core.scenario.ScenarioEngine`` which re-draws the
    participation mask and (under mobility) the cluster assignment B_t
    between global rounds, and by ``core.clock.EventClock`` which charges
    each round the slowest *participating* device's compute plus the
    algorithm's communication terms (eq. 8 with the max_k rule).

    With ``population`` set, the scenario describes a *virtual*
    population instead of the enumerated devices:
    ``core.scenario.PopulationEngine`` draws each round's cohort from
    the per-cluster size distributions and ``FLSimulator`` runs the
    streamed client-store engine (O(cohort) resident memory).
    """
    name: str = "homogeneous"
    # -- device-speed heterogeneity (multipliers on hw.device_flops) --------
    speed_dist: str = "homogeneous"  # one of SPEED_DISTS
    speed_spread: float = 0.0        # uniform: half-width; lognormal: sigma
    slow_fraction: float = 0.25      # bimodal: fraction of slow devices
    slow_factor: float = 0.1         # bimodal: slow devices' relative speed
    # -- per-round client sampling ------------------------------------------
    sample_fraction: float = 1.0     # fraction of devices training per round
    dropout_prob: float = 0.0        # straggler dropout among the sampled
    # -- mobility ------------------------------------------------------------
    move_prob: float = 0.0           # per-device per-round re-association prob
    seed: int = 0
    # -- fault injection (None = fault-free) ---------------------------------
    faults: "FaultConfig | None" = None
    # -- virtual population (None = enumerated devices) ----------------------
    population: "PopulationConfig | None" = None

    def validate(self) -> None:
        assert self.speed_dist in SPEED_DISTS, \
            f"unknown speed_dist {self.speed_dist!r}"
        assert self.speed_spread >= 0.0
        if self.speed_dist == "uniform":
            assert self.speed_spread < 1.0, "uniform spread must leave c>0"
        assert 0.0 <= self.slow_fraction <= 1.0
        assert 0.0 < self.slow_factor <= 1.0
        assert 0.0 < self.sample_fraction <= 1.0
        assert 0.0 <= self.dropout_prob < 1.0
        assert 0.0 <= self.move_prob <= 1.0
        if self.faults is not None:
            self.faults.validate()
        if self.population is not None:
            self.population.validate()
            assert self.faults is None or self.faults.trivial, \
                "fault injection is not supported with a virtual " \
                "population (FaultModel realizes per enumerated device)"

    @property
    def trivial(self) -> bool:
        """True iff the scenario cannot change the training trajectory
        (full participation, no mobility) — the parity regime in which the
        masked schedule must reduce to the static operators."""
        return (self.sample_fraction >= 1.0 and self.dropout_prob == 0.0
                and self.move_prob == 0.0
                and (self.faults is None or self.faults.trivial)
                and self.population is None)


# ---------------------------------------------------------------------------
# Mesh / distribution
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...] = (16, 16)
    axes: Tuple[str, ...] = ("data", "model")
    multi_pod: bool = False

    @property
    def num_devices(self) -> int:
        out = 1
        for s in self.shape:
            out *= s
        return out


# ---------------------------------------------------------------------------
# Train / serve shapes (the four assigned input shapes)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


@dataclass(frozen=True)
class TrainConfig:
    optimizer: str = "sgd"        # sgd | adamw
    learning_rate: float = 0.05
    momentum: float = 0.9
    weight_decay: float = 0.0
    lr_schedule: str = "constant"  # constant | cosine | warmup_cosine
    warmup_steps: int = 0
    total_steps: int = 1000
    batch_size: int = 50          # per-device local batch (paper: 50)
    seed: int = 0
    remat: bool = False           # activation checkpointing for the block
    use_pallas: bool = False      # route attention/ssd through Pallas kernels


@dataclass(frozen=True)
class ExperimentConfig:
    model: ModelConfig
    fl: FLConfig = field(default_factory=FLConfig)
    mesh: MeshConfig = field(default_factory=MeshConfig)
    train: TrainConfig = field(default_factory=TrainConfig)

    def replace(self, **kw) -> "ExperimentConfig":
        return dataclasses.replace(self, **kw)
