"""llama4-maverick-400b-a17b [moe] — 128 experts top-1 + shared expert,
alternating (SWA-8192 dense, full-attn MoE) layer pairs, early fusion.
[hf:meta-llama/Llama-4-Scout-17B-16E]"""
from repro.config import ModelConfig

MODEL = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8,
    d_ff=8192, vocab_size=202048, head_dim=128,
    num_experts=128, experts_per_token=1, moe_shared_expert=True,
    sliding_window=8192,
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
)
