"""Config registry: ``--arch <id>`` resolution for every assigned
architecture + the paper's own FEMNIST/CIFAR experiments."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.config import (ExperimentConfig, FLConfig, MeshConfig,
                          ModelConfig, TrainConfig, INPUT_SHAPES)

ARCHS: Dict[str, str] = {
    "whisper-medium": "whisper_medium",
    "zamba2-2.7b": "zamba2_2p7b",
    "qwen2.5-14b": "qwen2p5_14b",
    "mamba2-2.7b": "mamba2_2p7b",
    "pixtral-12b": "pixtral_12b",
    "qwen2-0.5b": "qwen2_0p5b",
    "minitron-8b": "minitron_8b",
    "mixtral-8x7b": "mixtral_8x7b",
    "mistral-large-123b": "mistral_large_123b",
    "llama4-maverick-400b-a17b": "llama4_maverick",
}

PAPER_EXPERIMENTS = ("femnist_cnn", "cifar_vgg11")


def get_model_config(arch: str) -> ModelConfig:
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; options: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.MODEL


def production_fl(multi_pod: bool = False) -> FLConfig:
    """Default FL mapping on the production mesh: 16 replicas/pod,
    4 replicas per cluster; multi-pod doubles the cluster count."""
    return FLConfig(
        algorithm="ce_fedavg",
        num_clusters=8 if multi_pod else 4,
        devices_per_cluster=4,
        tau=2, q=8, pi=10, topology="ring",
    )


def get_experiment(arch: str, *, multi_pod: bool = False,
                   fl: FLConfig | None = None,
                   train: TrainConfig | None = None) -> ExperimentConfig:
    mesh = MeshConfig(
        shape=(2, 16, 16) if multi_pod else (16, 16),
        axes=("pod", "data", "model") if multi_pod else ("data", "model"),
        multi_pod=multi_pod,
    )
    return ExperimentConfig(
        model=get_model_config(arch),
        fl=fl or production_fl(multi_pod),
        mesh=mesh,
        train=train or TrainConfig(optimizer="sgd", learning_rate=0.05,
                                   momentum=0.9),
    )


def applicable_shapes(arch: str) -> list:
    """The input shapes this arch runs (DESIGN.md §5 skip table)."""
    cfg = get_model_config(arch)
    out = []
    for name, s in INPUT_SHAPES.items():
        if name == "long_500k" and not cfg.supports_long_context:
            continue
        out.append(name)
    return out
