"""zamba2-2.7b [hybrid] — Mamba2 blocks + one *shared* attention block every
6 SSM blocks. [arXiv:2411.15242]"""
from repro.config import ModelConfig

MODEL = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    num_layers=54, d_model=2560, num_heads=32, num_kv_heads=32,
    d_ff=10240, vocab_size=32000, head_dim=80,
    ssm_state=64, ssm_expand=2, ssm_head_dim=64, attn_every=6,
    source="arXiv:2411.15242",
)
