"""pixtral-12b [vlm] — mistral-nemo decoder consuming stubbed ViT patch
embeddings (input_specs provides them). [hf:mistralai/Pixtral-12B-2409]"""
from repro.config import ModelConfig

MODEL = ModelConfig(
    name="pixtral-12b", family="vlm",
    num_layers=40, d_model=5120, num_heads=32, num_kv_heads=8,
    d_ff=14336, vocab_size=131072, head_dim=128, num_patches=1024,
    rope_theta=1000000000.0,
    source="hf:mistralai/Pixtral-12B-2409",
)
