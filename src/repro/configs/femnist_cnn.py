"""The paper's own FEMNIST experiment (§6.1): CNN, 64 devices, 8 edge
servers on a ring, tau=2, q=8, pi=10. [paper + LEAF arXiv:1812.01097]"""
from repro.config import FLConfig

FL = FLConfig(algorithm="ce_fedavg", num_clusters=8, devices_per_cluster=8,
              tau=2, q=8, pi=10, topology="ring")
MODEL_NAME = "femnist_cnn"
NUM_CLASSES = 62
IMAGE = (28, 28, 1)
PARAMS = 6_603_710
