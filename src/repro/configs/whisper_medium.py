"""whisper-medium [audio] — enc-dec transformer backbone; conv/mel frontend
is a stub (input_specs provides frame embeddings). [arXiv:2212.04356]"""
from repro.config import ModelConfig

MODEL = ModelConfig(
    name="whisper-medium", family="encdec",
    num_layers=24, encoder_layers=24, d_model=1024,
    num_heads=16, num_kv_heads=16, d_ff=4096, vocab_size=51865,
    head_dim=64, norm="layernorm", mlp_act="gelu", use_rope=False,
    qkv_bias=False, encoder_seq=1500,
    source="arXiv:2212.04356",
)
