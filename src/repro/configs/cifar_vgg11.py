"""The paper's own CIFAR-10 experiment (§6.1): modified VGG-11, 64 devices,
8 edge servers on a ring, Dirichlet(0.5) non-IID. [paper §6.1]"""
from repro.config import FLConfig

FL = FLConfig(algorithm="ce_fedavg", num_clusters=8, devices_per_cluster=8,
              tau=2, q=8, pi=10, topology="ring")
MODEL_NAME = "vgg11"
NUM_CLASSES = 10
IMAGE = (32, 32, 3)
PARAMS = 9_750_922
