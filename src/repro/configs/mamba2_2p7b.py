"""mamba2-2.7b [ssm] — attention-free SSD (state-space duality).
[arXiv:2405.21060]"""
from repro.config import ModelConfig

MODEL = ModelConfig(
    name="mamba2-2.7b", family="ssm",
    num_layers=64, d_model=2560, num_heads=0, num_kv_heads=0,
    d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_chunk=256,
    source="arXiv:2405.21060",
)
