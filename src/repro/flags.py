"""Process-wide analysis-mode switch.

``cost_analysis()`` on XLA modules counts each ``while`` body exactly once
(verified empirically), so the dry-run's roofline pass lowers an *analysis
variant* of each step: layer scans fully unrolled and attention forced onto
the non-streaming path, leaving no compute inside a while loop. Production
artifacts keep scans (small HLO, honest memory analysis).
"""
from __future__ import annotations

import contextlib
import threading

_state = threading.local()


def analysis_mode() -> bool:
    return getattr(_state, "analysis", False)


@contextlib.contextmanager
def analysis(enabled: bool = True):
    prev = analysis_mode()
    _state.analysis = enabled
    try:
        yield
    finally:
        _state.analysis = prev
