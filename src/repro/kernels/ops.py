"""Jitted public wrappers for the Pallas kernels.

On CPU hosts the kernels execute in interpret mode (kernel body run in
Python) for correctness validation; on TPU they compile to Mosaic.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import gossip_mix as _gm
from repro.kernels import ssd_scan as _ssd


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "q_offset", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal=True, window=0, q_offset=0,
                    block_q=128, block_k=128, interpret=None):
    if interpret is None:
        interpret = _interpret_default()
    return _fa.flash_attention(q, k, v, causal=causal, window=window,
                               q_offset=q_offset, block_q=block_q,
                               block_k=block_k, interpret=interpret)


def flash_attention_bshd(q, k, v, *, causal=True, window=0,
                         interpret=None):
    """(B,S,H,D) layout adapter with GQA kv expansion, matching
    repro.models.layers.attention_core semantics."""
    B, Sq, H, D = q.shape
    hk = k.shape[2]
    rep = H // hk
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    qt = q.transpose(0, 2, 1, 3).reshape(B * H, Sq, D)
    kt = k.transpose(0, 2, 1, 3).reshape(B * H, -1, D)
    vt = v.transpose(0, 2, 1, 3).reshape(B * H, -1, D)
    o = flash_attention(qt, kt, vt, causal=causal, window=window,
                        interpret=interpret)
    return o.reshape(B, H, Sq, D).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_intra_chunk(x, a_t, Bc, Cc, dtc, interpret=None):
    if interpret is None:
        interpret = _interpret_default()
    return _ssd.ssd_intra_chunk(x, a_t, Bc, Cc, dtc, interpret=interpret)


def ssd_intra_fn(interpret=None):
    if interpret is None:
        interpret = _interpret_default()
    return _ssd.make_intra_fn(interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def gossip_mix_flat(W, Y, block=2048, interpret=None):
    if interpret is None:
        interpret = _interpret_default()
    return _gm.gossip_mix_flat(W, Y, block=block, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def gossip_mix_rows(W, Y, block=2048, interpret=False):
    """Row-apply W @ Y on a flat (n, T) bank: the ModelBank mixing
    boundary. Dispatches per backend (Pallas on TPU, single XLA gemm
    elsewhere); ``interpret=True`` forces the Pallas kernel in interpret
    mode for validation."""
    return _gm.gossip_mix_rows(W, Y, block=block, interpret=interpret)


def gossip_mix_tree(W, params, block=2048, interpret=None):
    if interpret is None:
        interpret = _interpret_default()
    return _gm.gossip_mix_tree(W, params, block=block, interpret=interpret)
