"""Pallas TPU kernels: the streamed cold-row codec on device (ISSUE 10).

The streaming client store (``core/clientstore.py``) keeps paged-out
rows under a cold codec (f32/f16/int8) whose host-numpy reference lives
in ``core.compress.encode_cold_rows``/``decode_cold_rows``. PR 9 ran
that codec on the host *inside* the round loop: every streamed round
pulled the full f32 slab off the device, decoded/encoded in numpy, and
pushed f32 back — so the host↔device link carried 4x the codec width
and the codec itself serialized with compute.

These kernels move the codec into the jitted round: page-in DECODES
encoded rows into the slab on device, page-out ENCODES the slab before
D2H, and the transfer carries codec-width bytes (4x/2x less for
int8/f16). Same per-FlatLayout-segment affine scheme as the host path —
one ``scale = max(|seg|, 1e-12)/127`` per (row, leaf), deterministic
round-half-even — so a row is a re-quantization fixed point on either
side of the link and the f32 codec stays the bitwise identity.

Layout: segments are per-leaf ``(offset, size)`` column ranges of the
FlatLayout — irregular widths, so the kernels run per segment (leaf
counts are small) with a uniform column-block grid inside each:

- ``_absmax_kernel``   per-row |seg| max, accumulated across the column
                       grid in the revisited (rows, 1) output block;
- ``_affine_*_kernel`` elementwise quantize/dequantize against the
                       per-row scale block;
- ``_cast_kernel``     the f16 encode/decode (pure dtype cast).

Dispatch follows the ``gossip_mix`` idiom: Pallas on TPU backends,
the pure-jnp oracle (``kernels.ref.cold_encode_ref``/``cold_decode_ref``)
elsewhere; ``interpret=True`` runs the kernel bodies in Python on CPU —
the mode the tier-1 tests validate against the host codec.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels import ref as _ref

#: codecs a cold row may be stored under (mirrors compress.COLD_CODECS;
#: kept literal so the kernel module never imports the host path)
CODECS = ("f32", "f16", "int8")

# f32 min tile on TPU is (8, 128); 512 columns keeps each block well
# under VMEM at any cohort-bucket row count while staying tile-aligned
_BLK_ROWS = 8
_BLK_COLS = 512


def _use_pallas(use_pallas) -> bool:
    if use_pallas is None:
        return jax.default_backend() == "tpu"
    return bool(use_pallas)


def _pad2(x, rows: int, cols: int, value=0.0):
    """Pad a 2-D array up to (rows, cols) with ``value``."""
    pr, pc = rows - x.shape[0], cols - x.shape[1]
    if pr == 0 and pc == 0:
        return x
    return jnp.pad(x, ((0, pr), (0, pc)), constant_values=value)


def _ceil_to(n: int, b: int) -> int:
    return -(-n // b) * b


# -- kernel bodies -----------------------------------------------------------

def _absmax_kernel(x_ref, o_ref):
    """Per-row absmax of one (rows, cols) block, max-accumulated into
    the (rows, 1) output block revisited across the column grid."""
    j = pl.program_id(1)
    part = jnp.max(jnp.abs(x_ref[...].astype(jnp.float32)), axis=1,
                   keepdims=True)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = part

    @pl.when(j > 0)
    def _acc():
        o_ref[...] = jnp.maximum(o_ref[...], part)


def _affine_enc_kernel(x_ref, s_ref, q_ref):
    """int8 affine quantize against the per-row scale block (rows, 1):
    deterministic round-half-even, clipped to +/-127 (the host codec's
    ``np.rint`` discipline)."""
    s = s_ref[...].astype(jnp.float32)
    q = jnp.clip(jnp.round(x_ref[...].astype(jnp.float32) / s), -127, 127)
    q_ref[...] = q.astype(jnp.int8)


def _affine_dec_kernel(q_ref, s_ref, o_ref):
    o_ref[...] = q_ref[...].astype(jnp.float32) * s_ref[...]


def _cast_kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...].astype(o_ref.dtype)


# -- per-segment pallas_call wrappers ----------------------------------------

def _segment_absmax(x, interpret: bool):
    """(S, w) -> (S,) per-row absmax via the column-accumulating grid."""
    S, w = x.shape
    Sp, wp = _ceil_to(S, _BLK_ROWS), _ceil_to(w, _BLK_COLS)
    out = pl.pallas_call(
        _absmax_kernel,
        grid=(Sp // _BLK_ROWS, wp // _BLK_COLS),
        in_specs=[pl.BlockSpec((_BLK_ROWS, _BLK_COLS),
                               lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((_BLK_ROWS, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((Sp, 1), jnp.float32),
        interpret=interpret,
    )(_pad2(x, Sp, wp))
    return out[:S, 0]


def _elementwise(kernel, x, scale, out_dtype, interpret: bool):
    """Run an elementwise (x, per-row scale) -> out kernel over the
    column-block grid; ``scale=None`` drops the scale operand (casts)."""
    S, w = x.shape
    Sp, wp = _ceil_to(S, _BLK_ROWS), _ceil_to(w, _BLK_COLS)
    xspec = pl.BlockSpec((_BLK_ROWS, _BLK_COLS), lambda i, j: (i, j))
    args, in_specs = [_pad2(x, Sp, wp)], [xspec]
    if scale is not None:
        # pad rows with scale 1 so padding lanes never divide by zero
        args.append(_pad2(scale[:, None], Sp, 1, value=1.0))
        in_specs.append(pl.BlockSpec((_BLK_ROWS, 1), lambda i, j: (i, 0)))
    out = pl.pallas_call(
        kernel,
        grid=(Sp // _BLK_ROWS, wp // _BLK_COLS),
        in_specs=in_specs,
        out_specs=xspec,
        out_shape=jax.ShapeDtypeStruct((Sp, wp), out_dtype),
        interpret=interpret,
    )(*args)
    return out[:S, :w]


# -- public codec ------------------------------------------------------------

def encode_rows(rows, codec: str, segments, *, use_pallas=None,
                interpret: bool = False) -> Tuple[jax.Array, jax.Array]:
    """Encode (S, T) f32 rows for the cold store, on device.

    Returns ``(q, scale)``: ``q`` is (S, T) in the codec dtype, ``scale``
    the (S, nseg) f32 per-segment affine scales (width 0 for f32/f16) —
    the same fixed-structure pair as ``compress.encode_cold_rows``, and
    the same bytes: f32 is the identity, f16 the IEEE cast, int8 the
    per-segment ``max(|seg|, 1e-12)/127`` affine with round-half-even.
    """
    assert codec in CODECS, codec
    rows = rows.astype(jnp.float32)
    S = rows.shape[0]
    if codec == "f32":
        return rows, jnp.zeros((S, 0), jnp.float32)
    if not _use_pallas(use_pallas) and not interpret:
        return _ref.cold_encode_ref(rows, codec, segments)
    if codec == "f16":
        return (_elementwise(_cast_kernel, rows, None, jnp.float16,
                             interpret),
                jnp.zeros((S, 0), jnp.float32))
    qs, ss = [], []
    for off, size in segments:
        seg = rows[:, off:off + size]
        s = jnp.maximum(_segment_absmax(seg, interpret), 1e-12) / 127.0
        qs.append(_elementwise(_affine_enc_kernel, seg, s, jnp.int8,
                               interpret))
        ss.append(s)
    return jnp.concatenate(qs, axis=1), jnp.stack(ss, axis=1)


def decode_rows(q, scale, codec: str, segments, *, use_pallas=None,
                interpret: bool = False) -> jax.Array:
    """Decode :func:`encode_rows` output back to (S, T) f32 on device
    (exact for f32, the dequantized view for f16/int8). A zero ``q``
    row with zero scales decodes to exact zeros — a never-stored
    client's momentum, which is how the streamed page-in materializes
    first-touch lanes without a host round trip."""
    assert codec in CODECS, codec
    if codec == "f32":
        return q.astype(jnp.float32)
    if not _use_pallas(use_pallas) and not interpret:
        return _ref.cold_decode_ref(q, scale, codec, segments)
    if codec == "f16":
        return _elementwise(_cast_kernel, q, None, jnp.float32, interpret)
    outs = []
    for j, (off, size) in enumerate(segments):
        outs.append(_elementwise(_affine_dec_kernel, q[:, off:off + size],
                                 scale[:, j], jnp.float32, interpret))
    return jnp.concatenate(outs, axis=1)
