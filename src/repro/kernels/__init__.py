"""Pallas TPU kernels for the perf-critical layers (DESIGN.md §6):

flash_attention  tiled online-softmax attention (prefill hot spot)
ssd_scan         Mamba-2 SSD intra-chunk block
gossip_mix       fused W-mixing over stacked replica params (CE-FedAvg)
quantize         blocked int8 uplink quantization

Each has a jit'd wrapper in ops.py and a pure-jnp oracle in ref.py
(quantize carries its own); tests sweep shapes/dtypes in interpret mode.
"""
from repro.kernels import ops, ref  # noqa: F401
