"""Pure-jnp oracles for every Pallas kernel (the allclose ground truth)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal=True, window=0, q_offset=0):
    """q/k/v: (BH, S, D) — direct softmax attention."""
    D = q.shape[-1]
    scale = 1.0 / math.sqrt(D)
    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    q_pos = q_offset + jnp.arange(q.shape[1])
    k_pos = jnp.arange(k.shape[1])
    diff = q_pos[:, None] - k_pos[None, :]
    ok = jnp.ones(diff.shape, bool)
    if causal:
        ok &= diff >= 0
    if window > 0:
        ok &= diff < window
    s = jnp.where(ok[None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def ssd_intra_chunk_ref(x, a_t, Bc, Cc, dtc):
    """x: (BK,H,C,P); a_t/dtc: (BK,H,C); Bc/Cc: (BK,C,N).
    Returns (y_intra (BK,H,C,P) f32, states (BK,H,N,P) f32)."""
    xf = x.astype(jnp.float32)
    a = a_t.astype(jnp.float32)
    Bf = Bc.astype(jnp.float32)
    Cf = Cc.astype(jnp.float32)
    dt = dtc.astype(jnp.float32)
    C = x.shape[2]
    cum = jnp.cumsum(a, axis=-1)                      # (BK,H,C)
    diff = cum[..., :, None] - cum[..., None, :]      # (BK,H,C,C)
    mask = jnp.tril(jnp.ones((C, C), bool))
    L = jnp.where(mask, jnp.exp(diff), 0.0)
    scores = jnp.einsum("bin,bjn->bij", Cf, Bf)       # (BK,C,C)
    att = scores[:, None] * L * dt[..., None, :]      # (BK,H,C,C)
    y = jnp.einsum("bhij,bhjp->bhip", att, xf)
    decay_end = jnp.exp(cum[..., -1:] - cum)          # (BK,H,C)
    states = jnp.einsum("bjn,bhj,bhjp->bhnp", Bf, decay_end * dt, xf)
    return y, states


def gossip_mix_ref(W, Y):
    """Y: (n, T); returns WᵀY."""
    return (W.astype(jnp.float32).T @ Y.astype(jnp.float32)).astype(Y.dtype)


def gossip_mix_rows_ref(W, Y):
    """Y: (n, T); returns W @ Y (row application) — the single-pass XLA
    form of the ModelBank mixing boundary on CPU/GPU hosts."""
    return (W.astype(jnp.float32) @ Y.astype(jnp.float32)).astype(Y.dtype)


def cold_encode_ref(rows, codec, segments):
    """Pure-jnp oracle of ``kernels.cold_codec.encode_rows`` — the
    device sibling of ``core.compress.encode_cold_rows`` (same
    per-FlatLayout-segment affine int8 scheme, same deterministic
    round-half-even, identical f32/f16 casts). rows: (S, T) f32;
    returns ``(q (S, T) codec dtype, scale (S, nseg|0) f32)``."""
    rows = rows.astype(jnp.float32)
    S = rows.shape[0]
    if codec == "f32":
        return rows, jnp.zeros((S, 0), jnp.float32)
    if codec == "f16":
        return rows.astype(jnp.float16), jnp.zeros((S, 0), jnp.float32)
    assert codec == "int8", codec
    qs, ss = [], []
    for off, size in segments:
        seg = rows[:, off:off + size]
        s = jnp.maximum(jnp.max(jnp.abs(seg), axis=1), 1e-12) / 127.0
        qs.append(jnp.clip(jnp.round(seg / s[:, None]),
                           -127, 127).astype(jnp.int8))
        ss.append(s)
    return jnp.concatenate(qs, axis=1), jnp.stack(ss, axis=1)


def cold_decode_ref(q, scale, codec, segments):
    """Pure-jnp oracle of ``kernels.cold_codec.decode_rows``: inverse of
    :func:`cold_encode_ref` back to (S, T) f32 (exact for f32, the
    dequantized view for f16/int8)."""
    if codec in ("f32", "f16"):
        return q.astype(jnp.float32)
    assert codec == "int8", codec
    outs = []
    for j, (off, size) in enumerate(segments):
        outs.append(q[:, off:off + size].astype(jnp.float32)
                    * scale[:, j][:, None])
    return jnp.concatenate(outs, axis=1)
