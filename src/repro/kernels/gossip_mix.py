"""Pallas TPU kernel for fused gossip mixing — the paper-specific hot loop.

CE-FedAvg's aggregation boundaries apply a mixing operator W of eq. (11)
over the device axis of Y, which stacks the n device models row-wise
(eq. 10). Done naively (per-leaf tensordot) each parameter block is
re-read from HBM once per *leaf* per boundary; this kernel streams the
whole flattened parameter bank once: each (n × block) tile is read once,
hit with a skinny (n×n) matmul in VMEM, and written once — the op is
purely memory-bound, so one pass is the roofline.

Two call conventions:

- :func:`gossip_mix_flat` — the raw kernel, ``(W, Y) -> WᵀY``
  (column application; W[j,i] is the weight j→i).
- :func:`gossip_mix_rows` — ``(W, Y) -> W @ Y`` (row application,
  matching :func:`repro.core.cefedavg.mix` for arbitrary — including
  asymmetric row-stochastic masked — operators). This is the ModelBank
  mixing boundary: Pallas on TPU, a single XLA gemm elsewhere (the
  ``kernels/ref.py`` oracle; XLA already emits one streaming pass).

:class:`FlatLayout` is the cached concat/split plan between a pytree of
``(n, ...)`` leaves and the flat ``(n, T)`` bank; ``gossip_mix_tree``
re-uses it so external per-call concatenate/split planning happens once
per tree structure, and ``repro.core.modelbank`` re-uses it to keep the
whole simulation state flat for the run.

Validated on CPU with interpret=True against kernels/ref.py.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from repro.kernels import ref as _ref


def _kernel(w_ref, y_ref, o_ref):
    w = w_ref[...].astype(jnp.float32)        # (n, k), W[j,i] = weight j->i
    y = y_ref[...].astype(jnp.float32)        # (n, block)
    o = jax.lax.dot_general(w, y, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    o_ref[...] = o.astype(o_ref.dtype)


def gossip_mix_flat(W: jax.Array, Y: jax.Array, *, block: int = 2048,
                    interpret: bool = False) -> jax.Array:
    """Y: (n, T) flattened stacked models; W: (n, k). Returns WᵀY (k, T).

    Rectangular W supports the edge-model projection P ∈ R^{m×n} (pass
    ``P.T``) as well as the square mixing operators."""
    n, T = Y.shape
    k = W.shape[1]
    nb = -(-T // block)
    pad = nb * block - T
    if pad:
        Y = jnp.pad(Y, ((0, 0), (0, pad)))
    out = pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((n, k), lambda i: (0, 0)),
            pl.BlockSpec((n, block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((k, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((k, nb * block), Y.dtype),
        interpret=interpret,
    )(W, Y)
    return out[:, :T]


#: column tile of the CPU/GPU in-place streaming pass: n×(1<<18) f32 is a
#: 16 MB working set at n=16 — big enough to amortize loop overhead,
#: small enough that the tile's read-modify-write stays cache-friendly
_BLOCK_COLS_XLA = 1 << 18


def _mix_rows_blocked(W: jax.Array, Y: jax.Array,
                      block_cols: int = _BLOCK_COLS_XLA) -> jax.Array:
    """In-place cache-blocked ``W @ Y`` for square W — the CPU/GPU
    lowering of the fused streaming pass.

    XLA's ``dot`` cannot alias its output, so a plain bank-sized gemm
    allocates (and page-faults) a second (n, T) buffer on every boundary;
    tiling the columns and writing each ``W @ tile`` back over its own
    tile (exact: an output tile depends only on the matching input tile)
    keeps the op at one read + one write of the bank, the same roofline
    the Pallas kernel hits on TPU. ~3x faster than the gemm at the
    FEMNIST-CNN bank size on a 2-core host (BENCH_pr3.json)."""
    n, T = Y.shape
    Wj = jnp.asarray(W, jnp.float32)
    nb = T // block_cols

    def tile(blk):
        return (Wj @ blk.astype(jnp.float32)).astype(Y.dtype)

    def body(i, Y):
        off = i * block_cols
        blk = jax.lax.dynamic_slice(Y, (0, off), (n, block_cols))
        return jax.lax.dynamic_update_slice(Y, tile(blk), (0, off))

    if nb:
        Y = jax.lax.fori_loop(0, nb, body, Y)
    rem = T - nb * block_cols
    if rem:
        blk = jax.lax.dynamic_slice(Y, (0, nb * block_cols), (n, rem))
        Y = jax.lax.dynamic_update_slice(Y, tile(blk),
                                         (0, nb * block_cols))
    return Y


def gossip_mix_rows(W, Y: jax.Array, *, block: int = 2048,
                    use_pallas: bool | None = None,
                    interpret: bool = False) -> jax.Array:
    """Row-apply W (k, n) to the flat bank Y (n, T): out = W @ Y.

    One streaming pass over the bank — the ModelBank mixing boundary.
    On TPU this lowers to the fused Pallas kernel; on CPU/GPU to the
    in-place blocked pass (:func:`_mix_rows_blocked`) when W is square,
    else one XLA gemm (the rectangular edge-model projection;
    :func:`repro.kernels.ref.gossip_mix_rows_ref` is the oracle for
    both)."""
    if use_pallas is None:
        use_pallas = jax.default_backend() == "tpu"
    if use_pallas or interpret:
        Wj = jnp.asarray(W, jnp.float32)
        return gossip_mix_flat(Wj.T, Y, block=block, interpret=interpret)
    if W.shape[0] == Y.shape[0]:          # square: stream in place
        return _mix_rows_blocked(W, Y)
    return _ref.gossip_mix_rows_ref(jnp.asarray(W, jnp.float32), Y)


# ---------------------------------------------------------------------------
# FlatLayout: the cached concat/split plan between pytrees and the bank
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class FlatLayout:
    """Concat/split plan between a pytree and its flat (n, T) bank.

    Stores per-leaf trailing ``shapes`` (the device axis excluded),
    ``dtypes``, byte-order ``offsets``/``sizes`` into the flat axis, and
    the ``treedef`` — everything needed to materialize pytree views from
    the bank and to flatten trees into it. Built once per tree structure
    and memoized (:meth:`for_tree` / :meth:`for_stacked`), so repeated
    ``gossip_mix_tree`` calls and every ModelBank round re-use the same
    plan instead of rebuilding it per invocation."""
    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]   # per-leaf shape, no device axis
    dtypes: Tuple[Any, ...]
    offsets: Tuple[int, ...]
    sizes: Tuple[int, ...]
    total: int                            # T = sum(sizes)

    @property
    def segments(self) -> Tuple[Tuple[int, int], ...]:
        """Static (offset, size) per leaf — the per-leaf boundaries that
        flat-domain upload transforms (top-k, int8) preserve."""
        return tuple(zip(self.offsets, self.sizes))

    @property
    def row_nbytes(self) -> int:
        """Bytes of one f32 bank row (= one device model = one per-device
        bank shard of the sharded engine, and the |θ| multiplier in every
        boundary-traffic formula of docs/PERFORMANCE.md)."""
        return 4 * self.total

    # -- constructors (memoized) --------------------------------------------
    @classmethod
    def _build(cls, tree, strip_leading: bool) -> "FlatLayout":
        leaves, treedef = jax.tree.flatten(tree)
        shapes = tuple(tuple(l.shape[1:] if strip_leading else l.shape)
                       for l in leaves)
        dtypes = tuple(jnp.asarray(l).dtype for l in leaves)
        key = (treedef, shapes, dtypes)
        hit = _LAYOUT_CACHE.get(key)
        if hit is not None:
            return hit
        sizes = tuple(int(np.prod(s)) if s else 1 for s in shapes)
        offsets = tuple(int(o) for o in np.cumsum((0,) + sizes[:-1]))
        layout = cls(treedef, shapes, dtypes, offsets, sizes,
                     int(sum(sizes)))
        _LAYOUT_CACHE[key] = layout
        return layout

    @classmethod
    def for_tree(cls, tree) -> "FlatLayout":
        """Layout of a single model pytree (no leading device axis)."""
        return cls._build(tree, strip_leading=False)

    @classmethod
    def for_stacked(cls, tree) -> "FlatLayout":
        """Layout of a device-stacked pytree: every leaf is (n, ...) and
        the leading axis is excluded from the plan."""
        return cls._build(tree, strip_leading=True)

    # -- single model <-> (T,) ----------------------------------------------
    def flatten_one(self, tree) -> jax.Array:
        """Pytree -> (T,) f32 row (the bank stores f32, as the mixing
        algebra always computed in f32)."""
        leaves = jax.tree.leaves(tree)
        return jnp.concatenate(
            [l.reshape(-1).astype(jnp.float32) for l in leaves])

    def unflatten_one(self, vec: jax.Array):
        """(T,) -> pytree of per-leaf views (original shapes/dtypes)."""
        out = [vec[o:o + s].reshape(shape).astype(dt)
               for o, s, shape, dt in zip(self.offsets, self.sizes,
                                          self.shapes, self.dtypes)]
        return jax.tree.unflatten(self.treedef, out)

    # -- stacked models <-> (n, T) ------------------------------------------
    def flatten_stack(self, tree) -> jax.Array:
        """Pytree of (n, ...) leaves -> (n, T) f32 bank."""
        leaves = jax.tree.leaves(tree)
        n = leaves[0].shape[0]
        return jnp.concatenate(
            [l.reshape(n, -1).astype(jnp.float32) for l in leaves], axis=1)

    def unflatten_stack(self, Y: jax.Array):
        """(n, T) bank -> pytree of (n, ...) leaves."""
        n = Y.shape[0]
        out = [Y[:, o:o + s].reshape((n,) + shape).astype(dt)
               for o, s, shape, dt in zip(self.offsets, self.sizes,
                                          self.shapes, self.dtypes)]
        return jax.tree.unflatten(self.treedef, out)


_LAYOUT_CACHE: Dict[Any, FlatLayout] = {}


def gossip_mix_tree(W, params, *, block: int = 2048,
                    interpret: bool = False):
    """Row-apply W over the leading device axis of every leaf via one
    fused flattened pass (single HBM read/write of the whole stacked
    model). Matches :func:`repro.core.cefedavg.mix` for arbitrary W,
    including the asymmetric row-stochastic masked operators of
    ``core/scenario.py`` (previously this column-applied, which agreed
    only for symmetric W). The concat/split plan is cached per tree
    structure in a :class:`FlatLayout`."""
    layout = FlatLayout.for_stacked(params)
    flat = layout.flatten_stack(params)
    Wj = jnp.asarray(np.asarray(W), jnp.float32)
    mixed = gossip_mix_flat(Wj.T, flat, block=block, interpret=interpret)
    return layout.unflatten_stack(mixed)
