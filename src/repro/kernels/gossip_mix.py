"""Pallas TPU kernel for fused gossip mixing — the paper-specific hot loop.

CE-FedAvg's aggregation boundaries apply the operator  Y ← Wᵀ Y  where W is
the (n×n) mixing operator of eq. (11) and Y stacks n device models row-wise
(eq. 10). Done naively (per-leaf tensordot) each parameter block is re-read
from HBM once per gossip *step*; this kernel fuses the π steps by applying
the precomputed W = (Bᵀdiag(c)HᵖⁱB)ᵀ in a single streaming pass: each
(n × block) tile of the flattened parameter stream is read once, hit with a
skinny (n×n) matmul in VMEM, and written once — the op is purely
memory-bound, so one pass is the roofline.

Validated on CPU with interpret=True against kernels/ref.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl


def _kernel(w_ref, y_ref, o_ref):
    w = w_ref[...].astype(jnp.float32)        # (n, n), W[j,i] = weight j->i
    y = y_ref[...].astype(jnp.float32)        # (n, block)
    o = jax.lax.dot_general(w, y, (((0,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    o_ref[...] = o.astype(o_ref.dtype)


def gossip_mix_flat(W: jax.Array, Y: jax.Array, *, block: int = 2048,
                    interpret: bool = False) -> jax.Array:
    """Y: (n, T) flattened stacked models; W: (n, n). Returns WᵀY."""
    n, T = Y.shape
    nb = -(-T // block)
    pad = nb * block - T
    if pad:
        Y = jnp.pad(Y, ((0, 0), (0, pad)))
    out = pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((n, n), lambda i: (0, 0)),
            pl.BlockSpec((n, block), lambda i: (0, i)),
        ],
        out_specs=pl.BlockSpec((n, block), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((n, nb * block), Y.dtype),
        interpret=interpret,
    )(W, Y)
    return out[:, :T]


def gossip_mix_tree(W, params, *, block: int = 2048,
                    interpret: bool = False):
    """Apply W over the leading device axis of every leaf via one fused
    flattened pass (single HBM read/write of the whole stacked model)."""
    leaves, treedef = jax.tree.flatten(params)
    n = leaves[0].shape[0]
    flat = jnp.concatenate(
        [l.reshape(n, -1).astype(jnp.float32) for l in leaves], axis=1)
    Wj = jnp.asarray(np.asarray(W), jnp.float32)
    mixed = gossip_mix_flat(Wj, flat, block=block, interpret=interpret)
    out = []
    off = 0
    for l in leaves:
        size = int(np.prod(l.shape[1:]))
        out.append(mixed[:, off:off + size].reshape(l.shape).astype(l.dtype))
        off += size
    return jax.tree.unflatten(treedef, out)
