"""Pallas TPU kernel for the Mamba-2 SSD intra-chunk block.

Per (batch·chunk, head) grid cell, computes in VMEM:
    L[i,j]   = exp(cumsum(a)[i] - cumsum(a)[j])        (i >= j, else 0)
    scores   = C_chunk @ B_chunkᵀ                       (C×C on the MXU)
    y_intra  = (scores ∘ L ∘ dt_j) @ X                  (C×P on the MXU)
    state_k  = (B ∘ decay_to_end ∘ dt)ᵀ @ X             (N×P on the MXU)
The inter-chunk linear recurrence stays a lax.scan outside the kernel
(negligible FLOPs). Chunk length is a multiple of 128 for MXU alignment.

Validated on CPU with interpret=True against kernels/ref.py.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, a_ref, b_ref, c_ref, dt_ref, y_ref, st_ref):
    # shapes: x (1,1,C,P)  a (1,1,C)  b/c (1,C,N)  dt (1,1,C)
    x = x_ref[0, 0].astype(jnp.float32)          # (C, P)
    a = a_ref[0, 0].astype(jnp.float32)          # (C,)
    Bm = b_ref[0].astype(jnp.float32)            # (C, N)
    Cm = c_ref[0].astype(jnp.float32)            # (C, N)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (C,)

    C = x.shape[0]
    cum = jnp.cumsum(a)                          # (C,)
    diff = cum[:, None] - cum[None, :]           # (C, C)
    ii = jax.lax.broadcasted_iota(jnp.int32, (C, C), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (C, C), 1)
    L = jnp.where(ii >= jj, jnp.exp(diff), 0.0)

    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    att = scores * L * dt[None, :]
    y = jax.lax.dot_general(att, x, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y_ref[0, 0] = y.astype(y_ref.dtype)

    decay_end = jnp.exp(cum[-1] - cum)           # (C,)
    wB = Bm * (decay_end * dt)[:, None]          # (C, N)
    st = jax.lax.dot_general(wB, x, (((0,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (N, P)
    st_ref[0, 0] = st


def ssd_intra_chunk(x: jax.Array, a_t: jax.Array, Bc: jax.Array,
                    Cc: jax.Array, dtc: jax.Array, *,
                    interpret: bool = False):
    """x: (BK, H, C, P); a_t/dtc: (BK, H, C); Bc/Cc: (BK, C, N).

    Returns (y_intra (BK, H, C, P) f32, states (BK, H, N, P) f32).
    """
    BK, H, C, P = x.shape
    N = Bc.shape[-1]
    out_y = jax.ShapeDtypeStruct((BK, H, C, P), jnp.float32)
    out_s = jax.ShapeDtypeStruct((BK, H, N, P), jnp.float32)
    y, st = pl.pallas_call(
        _kernel,
        grid=(BK, H),
        in_specs=[
            pl.BlockSpec((1, 1, C, P), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, C), lambda b, h: (b, h, 0)),
            pl.BlockSpec((1, C, N), lambda b, h: (b, 0, 0)),
            pl.BlockSpec((1, C, N), lambda b, h: (b, 0, 0)),
            pl.BlockSpec((1, 1, C), lambda b, h: (b, h, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, C, P), lambda b, h: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h: (b, h, 0, 0)),
        ],
        out_shape=[out_y, out_s],
        interpret=interpret,
    )(x, a_t, Bc, Cc, dtc)
    return y, st


def make_intra_fn(interpret: bool = False):
    """Adapter matching repro.models.ssm.ssd_chunked's ``intra_fn`` hook:
    (xc (B,K,C,H,P), a_t (B,K,H,C), Bc (B,K,C,N), Cc, dtc (B,K,C,H))
    -> y_intra (B,K,C,H,P) f32."""
    def intra(xc, a_t, Bc, Cc, dtc):
        B, K, C, H, P = xc.shape
        N = Bc.shape[-1]
        x = xc.transpose(0, 1, 3, 2, 4).reshape(B * K, H, C, P)
        a = a_t.reshape(B * K, H, C)
        dt = dtc.transpose(0, 1, 3, 2).reshape(B * K, H, C)
        Bc2 = Bc.reshape(B * K, C, N)
        Cc2 = Cc.reshape(B * K, C, N)
        y, _ = ssd_intra_chunk(x, a, Bc2, Cc2, dt, interpret=interpret)
        return y.reshape(B, K, H, C, P).transpose(0, 1, 3, 2, 4)
    return intra
