"""Pallas TPU kernel: blocked int8 affine quantization for uplink payloads.

CE-FedAvg's device→edge uploads are pure payload movement; quantizing the
delta stream to int8 on-chip before DMA is a bandwidth-bound fused pass:
each (block,) tile is read once, its absmax/scale computed in VMEM, and the
int8 codes + per-block scale written out (4.03x payload reduction at
block=1024). Deterministic round-to-nearest in-kernel; the stochastic-
rounding variant lives in core/compress.py (host/jnp path).

Validated interpret=True against kernels/ref-style oracle in tests.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, q_ref, s_ref):
    x = x_ref[...].astype(jnp.float32)          # (block,)
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127)
    q_ref[...] = q.astype(jnp.int8)
    s_ref[0] = scale


def quantize_int8_blocked(x: jax.Array, *, block: int = 1024,
                          interpret: bool = False):
    """x: (T,) f32 -> (codes (T,) int8, scales (T//block,) f32)."""
    T = x.shape[0]
    nb = -(-T // block)
    pad = nb * block - T
    if pad:
        x = jnp.pad(x, (0, pad))
    q, s = pl.pallas_call(
        _kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=[pl.BlockSpec((block,), lambda i: (i,)),
                   pl.BlockSpec((1,), lambda i: (i,))],
        out_shape=[jax.ShapeDtypeStruct((nb * block,), jnp.int8),
                   jax.ShapeDtypeStruct((nb,), jnp.float32)],
        interpret=interpret,
    )(x)
    return q[:T], s


def dequantize_int8_blocked(q: jax.Array, scales: jax.Array, *,
                            block: int = 1024) -> jax.Array:
    T = q.shape[0]
    nb = scales.shape[0]
    pad = nb * block - T
    qp = jnp.pad(q, (0, pad)) if pad else q
    out = qp.reshape(nb, block).astype(jnp.float32) * scales[:, None]
    return out.reshape(-1)[:T]


def quantize_int8_ref(x: jax.Array, *, block: int = 1024):
    """Pure-jnp oracle."""
    T = x.shape[0]
    nb = -(-T // block)
    pad = nb * block - T
    xp = jnp.pad(x, (0, pad)).reshape(nb, block).astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xp), axis=1), 1e-12) / 127.0
    q = jnp.clip(jnp.round(xp / scale[:, None]), -127, 127).astype(jnp.int8)
    return q.reshape(-1)[:T], scale
