"""Pallas TPU flash attention (causal / sliding-window), MXU-aligned tiles.

Grid: (batch*heads, num_q_blocks, num_k_blocks) — the k-block axis is the
innermost ("arbitrary") dimension so the (m, l, acc) online-softmax state
lives in VMEM scratch and the output tile is written once on the last
k-block. Block shapes are multiples of 128 to line up with the MXU.

Targets TPU; validated on CPU with interpret=True (tests/test_kernels.py
sweeps shapes/dtypes against the pure-jnp oracle in kernels/ref.py).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, block_q: int, block_k: int, seq_q: int,
            seq_k: int, causal: bool, window: int, q_offset: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0].astype(jnp.float32)            # (block_q, d)
    k = k_ref[0].astype(jnp.float32)            # (block_k, d)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)
    ok = (k_pos < seq_k) & (q_pos < q_offset + seq_q)
    if causal:
        ok &= q_pos >= k_pos
    if window > 0:
        ok &= (q_pos - k_pos) < window
    s = jnp.where(ok, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
    v = v_ref[0].astype(jnp.float32)
    acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finish():
        o_ref[0] = (acc_scr[...] /
                    jnp.maximum(l_scr[...], 1e-30)[:, None]
                    ).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    q_offset: int = 0, block_q: int = 128,
                    block_k: int = 128,
                    interpret: bool = False) -> jax.Array:
    """q: (BH, Sq, D), k/v: (BH, Sk, D) — kv heads pre-expanded (GQA done
    by the caller). Returns (BH, Sq, D)."""
    BH, Sq, D = q.shape
    Sk = k.shape[1]
    scale = 1.0 / math.sqrt(D)
    nq = -(-Sq // block_q)
    nk = -(-Sk // block_k)
    pad_q = nq * block_q - Sq
    pad_k = nk * block_k - Sk
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0)))

    kern = functools.partial(
        _kernel, scale=scale, block_q=block_q, block_k=block_k,
        seq_q=Sq, seq_k=Sk, causal=causal, window=window, q_offset=q_offset)
    out = pl.pallas_call(
        kern,
        grid=(BH, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, D), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, D), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, nq * block_q, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]
