"""Minimal pure-JAX optimizers (no optax dependency).

Each optimizer is (init_fn, update_fn):
  init_fn(params)                         -> opt_state
  update_fn(grads, opt_state, params, lr) -> (updates, new_opt_state)
Updates are *subtracted* from params by the caller. All ops are leafwise, so
they compose with vmap over the federated replica axis.
"""
from __future__ import annotations

import math
from typing import Callable

import jax
import jax.numpy as jnp

from repro.config import TrainConfig


def sgd(momentum: float = 0.9, weight_decay: float = 0.0):
    def init(params):
        if momentum == 0.0:
            return {}
        return {"mu": jax.tree.map(
            lambda p: jnp.zeros_like(p, jnp.float32), params)}

    def update(grads, state, params, lr):
        if weight_decay:
            grads = jax.tree.map(
                lambda g, p: g + weight_decay * p.astype(g.dtype),
                grads, params)
        if momentum == 0.0:
            return jax.tree.map(lambda g: lr * g, grads), state
        mu = jax.tree.map(
            lambda v, g: momentum * v + g.astype(jnp.float32),
            state["mu"], grads)
        upd = jax.tree.map(lambda v: lr * v, mu)
        return upd, {"mu": mu}

    return init, update


def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0):
    def init(params):
        z = lambda p: jnp.zeros_like(p, jnp.float32)  # noqa: E731
        return {"m": jax.tree.map(z, params),
                "v": jax.tree.map(z, params),
                "t": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        t = state["t"] + 1
        m = jax.tree.map(
            lambda m_, g: b1 * m_ + (1 - b1) * g.astype(jnp.float32),
            state["m"], grads)
        v = jax.tree.map(
            lambda v_, g: b2 * v_ + (1 - b2) * jnp.square(
                g.astype(jnp.float32)), state["v"], grads)
        bc1 = 1 - b1 ** t.astype(jnp.float32)
        bc2 = 1 - b2 ** t.astype(jnp.float32)

        def upd_leaf(m_, v_, p):
            u = (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
            if weight_decay:
                u = u + weight_decay * p.astype(jnp.float32)
            return lr * u
        upd = jax.tree.map(upd_leaf, m, v, params)
        return upd, {"m": m, "v": v, "t": t}

    return init, update


def make_optimizer(cfg: TrainConfig):
    if cfg.optimizer == "sgd":
        return sgd(cfg.momentum, cfg.weight_decay)
    if cfg.optimizer == "adamw":
        return adamw(weight_decay=cfg.weight_decay)
    raise ValueError(cfg.optimizer)


def make_lr_schedule(cfg: TrainConfig) -> Callable[[jax.Array], jax.Array]:
    base = cfg.learning_rate

    def schedule(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
        if cfg.lr_schedule == "constant":
            return jnp.asarray(base, jnp.float32)
        warm = max(cfg.warmup_steps, 1)
        wfrac = jnp.minimum(step / warm, 1.0)
        if cfg.lr_schedule == "warmup_cosine":
            prog = jnp.clip((step - warm) / max(cfg.total_steps - warm, 1),
                            0.0, 1.0)
            cos = 0.5 * (1 + jnp.cos(math.pi * prog))
            return base * wfrac * cos
        if cfg.lr_schedule == "cosine":
            prog = jnp.clip(step / max(cfg.total_steps, 1), 0.0, 1.0)
            return base * 0.5 * (1 + jnp.cos(math.pi * prog))
        raise ValueError(cfg.lr_schedule)

    return schedule


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p - u.astype(p.dtype)), params, updates)
