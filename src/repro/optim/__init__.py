from repro.optim.optimizers import (  # noqa: F401
    make_optimizer,
    sgd,
    adamw,
    make_lr_schedule,
)
