"""Flat-npz pytree checkpointing (no external deps).

Keys encode the tree path; dtypes/shapes round-trip exactly, including
the ml_dtypes extension types (bfloat16, float8_*) that a bare
``np.save``/``np.load`` would mangle into opaque void records — those
leaves are stored viewed as same-width unsigned ints and viewed back on
load using a ``__dtypes__`` tag in the archive. Saves are atomic: the
archive is written to a temp file in the destination directory and
``os.replace``d into place, so a crash mid-save can never corrupt the
previous checkpoint. Good enough for single-host experiment drivers; a
real deployment would swap in tensorstore/orbax behind the same two
functions.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict

import jax
import ml_dtypes
import numpy as np


class CheckpointStructureError(ValueError):
    """Raised when a checkpoint's tree paths do not match ``like``'s.

    Carries the offending key sets so drivers can report exactly what
    drifted between the saved run and the restoring code (a renamed
    layer, a dropped optimizer slot, ...). Unlike the former bare
    ``assert``, this survives ``python -O``.
    """

    def __init__(self, missing, extra):
        self.missing = tuple(sorted(missing))
        self.extra = tuple(sorted(extra))
        super().__init__(
            "checkpoint structure mismatch: "
            f"missing from checkpoint: {list(self.missing) or '-'}; "
            f"unexpected in checkpoint: {list(self.extra) or '-'}")


def _flatten(tree: Any, prefix: str = "") -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}/{k}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}/#{i}"))
    else:
        out[prefix] = np.asarray(tree)
    return out


def _encode(flat: Dict[str, np.ndarray]):
    """(storable arrays, {key: dtype name}) — ml_dtypes leaves (numpy
    kind 'V') are viewed as same-width unsigned ints for the archive."""
    stored, tags = {}, {}
    for k, v in flat.items():
        if v.dtype.kind == "V":
            tags[k] = v.dtype.name
            stored[k] = v.view(f"u{v.dtype.itemsize}")
        else:
            stored[k] = v
    return stored, tags


def _decode(arr: np.ndarray, name: str | None) -> np.ndarray:
    if name is None:
        return arr
    return arr.view(np.dtype(getattr(ml_dtypes, name)))


def save_checkpoint(path: str, tree: Any, meta: Dict | None = None) -> None:
    """Atomically write ``tree`` (+ json-able ``meta``) to ``path``.

    The archive lands under exactly ``path`` (no implicit ``.npz``
    suffix), via a temp file in the same directory and ``os.replace``,
    so readers always see either the old checkpoint or the new one —
    never a torn write.
    """
    d = os.path.dirname(path) or "."
    os.makedirs(d, exist_ok=True)
    stored, tags = _encode(_flatten(tree))
    fd, tmp = tempfile.mkstemp(dir=d, prefix=".ckpt-", suffix=".tmp")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, __meta__=json.dumps(meta or {}),
                     __dtypes__=json.dumps(tags), **stored)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def load_checkpoint(path: str, like: Any = None):
    """Returns (tree, meta). If ``like`` is given, reshapes into its
    structure (raising :class:`CheckpointStructureError` naming the
    missing/extra tree paths on any mismatch); otherwise returns the
    flat {path: array} dict. Leaf dtypes are exactly as saved."""
    z = np.load(path, allow_pickle=False)
    meta = json.loads(str(z["__meta__"]))
    tags = (json.loads(str(z["__dtypes__"]))
            if "__dtypes__" in z.files else {})
    flat = {k: _decode(z[k], tags.get(k)) for k in z.files
            if k not in ("__meta__", "__dtypes__")}
    if like is None:
        return flat, meta
    leaves_like, treedef = jax.tree.flatten(like)
    flat_like = _flatten(like)
    if set(flat_like) != set(flat):
        raise CheckpointStructureError(
            missing=set(flat_like) - set(flat),
            extra=set(flat) - set(flat_like))
    ordered = [flat[k] for k in sorted(flat_like)]
    # tree.flatten of dicts sorts keys, matching _flatten's ordering
    return jax.tree.unflatten(treedef, ordered), meta
