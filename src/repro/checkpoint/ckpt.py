"""Flat-npz pytree checkpointing (no external deps).

Keys encode the tree path; dtypes/shapes round-trip exactly. Good enough
for single-host experiment drivers; a real deployment would swap in
tensorstore/orbax behind the same two functions.
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict

import jax
import numpy as np


def _flatten(tree: Any, prefix: str = "") -> Dict[str, np.ndarray]:
    out: Dict[str, np.ndarray] = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}/{k}"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}/#{i}"))
    else:
        out[prefix] = np.asarray(tree)
    return out


def save_checkpoint(path: str, tree: Any, meta: Dict | None = None) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten(tree)
    np.savez(path, __meta__=json.dumps(meta or {}),
             **{k: v for k, v in flat.items()})


def load_checkpoint(path: str, like: Any = None):
    """Returns (tree, meta). If ``like`` is given, reshapes into its
    structure; otherwise returns the flat {path: array} dict."""
    z = np.load(path, allow_pickle=False)
    meta = json.loads(str(z["__meta__"]))
    flat = {k: z[k] for k in z.files if k != "__meta__"}
    if like is None:
        return flat, meta
    leaves_like, treedef = jax.tree.flatten(like)
    flat_like = _flatten(like)
    assert set(flat_like) == set(flat), "checkpoint structure mismatch"
    ordered = [flat[k] for k in sorted(flat_like)]
    # tree.flatten of dicts sorts keys, matching _flatten's ordering
    return jax.tree.unflatten(treedef, ordered), meta
