from repro.checkpoint.ckpt import (  # noqa: F401
    CheckpointStructureError, load_checkpoint, save_checkpoint)
from repro.checkpoint.runckpt import RunCheckpoint  # noqa: F401
