"""Whole-run crash-consistent checkpointing: :class:`RunCheckpoint`.

``checkpoint/ckpt.py`` persists a single pytree; a *run* is more than
its parameters — killing a long wall-clock simulation mid-flight loses
the RNG key, the scenario cursor (mobility labels + round index), the
async clock's cross-round timeline carry, the accuracy history and any
adaptive-schedule state. RunCheckpoint captures ALL of that as one
fixed-structure tree and writes it through the atomic
``save_checkpoint`` (temp file + ``os.replace``), so a reader always
sees either the previous complete checkpoint or the new one.

Restore is *bit-identical*: every per-round draw in the simulator is
keyed by ``(seed, round, stream, entity)`` (scenario cohorts, mobility,
faults) or threaded through the saved PRNG key (minibatch/DP noise), so
a run killed at round k and resumed replays rounds k..R exactly as the
uninterrupted run would have — parameters AND recorded accuracy
history (``tests/test_resume.py`` asserts both, barrier and async).

Sharded engines restore without ever materializing the bank on one
host: the (n, T) buffers go back through
:meth:`repro.core.modelbank.ModelBank.load_rows`, which fills each
device's row shard via ``jax.make_array_from_callback`` against the
bank's resident sharding.
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.ckpt import load_checkpoint, save_checkpoint


def _host(x) -> np.ndarray:
    """Device array -> host numpy (gathers a sharded array's shards)."""
    return np.asarray(jax.device_get(x))


def _capture(sim, round_idx: int, clock, hist,
             staleness: Optional[int]) -> Dict[str, Any]:
    """The full run state as one fixed-structure tree.

    The structure is a function of the sim's *configuration* only
    (bank vs legacy engine, residual on/off, scenario attached,
    schedule kind), never of how far the run has progressed — so a
    freshly constructed sim yields the exact ``like`` tree that
    ``load_checkpoint`` validates a saved run against. That is why the
    variable-length pieces are normalized: history columns are stored
    as single arrays (their length lives in the data, not the tree)
    and the async clock carry is zero-padded to ``(k, m)`` with an
    explicit ``ncols`` count (the carry holds fewer than
    ``max(staleness, 1)`` columns early in a run).
    """
    m = sim.fl.num_clusters
    n = sim.fl.n
    state: Dict[str, Any] = {
        "round": np.int64(round_idx),
        "sim_round": np.int64(sim.round_index),
        "key": _host(sim.key),
        "labels": np.asarray(sim.labels, np.int64),
        "phases": np.asarray(
            getattr(sim, "_async_phases", np.zeros(m, dtype=int)),
            np.int64),
    }
    if sim.bank is not None:
        bank = {"params": _host(sim.bank.params),
                "mom": _host(sim.bank.mom)}
        if sim.bank.residual is not None:
            bank["residual"] = _host(sim.bank.residual)
        state["bank"] = bank
    elif getattr(sim, "store", None) is not None:
        # streamed engine: the cold store IS the model state — cluster
        # references plus the encoded momentum rows (stored encoded, so
        # a save/restore round trip reproduces identical cold bytes
        # under every codec), and the last-sync label tracker. The
        # (S,)-shaped pieces are variable-length; ckpt.py validates
        # tree *paths*, not shapes, so the structure stays fixed.
        # A pipelined driver's in-flight page-out lands first, making
        # the store round-complete at the captured round.
        drain = getattr(sim, "_drain_pipeline", None)
        if drain is not None:
            drain()
        state["store"] = sim.store.snapshot()
        state["page_labels"] = np.asarray(sim._page_labels, np.int64)
    else:
        state["params"] = jax.tree.map(_host, sim._params)
        state["mom"] = jax.tree.map(_host, sim._mom)
        if sim._residual is not None:
            state["residual"] = jax.tree.map(_host, sim._residual)
    if sim.engine is not None:
        state["engine"] = {
            "labels": np.asarray(sim.engine.labels, np.int64),
            "round": np.int64(sim.engine.round_index)}
    # adaptive-schedule state under fixed keys regardless of schedule
    # kind: pi_feedback's EMA anchor and the online speed estimator's
    # per-device rate EMA (NaN-filled when absent)
    fn = getattr(sim, "_schedule_fn", None)
    fb = getattr(fn, "state", None)
    est = getattr(fn, "estimator", None)
    state["sched"] = {
        "ref": np.float64(fb["ref"] if fb is not None else np.nan),
        "ema": np.float64(fb["ema"] if fb is not None else np.nan),
        "rate": (np.asarray(est._rate, np.float64) if est is not None
                 else np.full(n, np.nan))}
    if clock is not None:
        k = max(int(staleness or 0), 1)
        carry = clock._async_carry
        t_end = np.zeros(m)
        cols = np.zeros((k, m))
        ncols = 0
        if carry is not None:
            t_end = np.asarray(carry["T_end"], float)
            live_cols = [np.asarray(c, float) for c in carry["cols"]]
            ncols = len(live_cols)
            if ncols:
                cols[:ncols] = np.stack(live_cols)
        state["clock"] = {
            "now": np.float64(clock.now), "T_end": t_end, "cols": cols,
            "ncols": np.int64(ncols), "live": np.int64(carry is not None)}
    if hist is not None:
        state["hist"] = {c: np.asarray(v, np.float64)
                         for c, v in hist.items()}
    return state


def _assign(sim, state: Dict[str, Any], clock, hist) -> None:
    """Write a restored state tree back into the live objects."""
    if sim.bank is not None:
        b = state["bank"]
        sim.bank.load_rows(b["params"], b["mom"], b.get("residual"))
    elif getattr(sim, "store", None) is not None:
        sim.store.load(state["store"])
        sim._page_labels = np.asarray(state["page_labels"], np.int64)
        # drop any pipelined in-flight state: the device refs re-seed
        # from the restored store at the next dispatched round
        if getattr(sim, "_pipe", None) is not None:
            sim._pipe = None
    else:
        sim._params = jax.tree.map(jnp.asarray, state["params"])
        sim._mom = jax.tree.map(jnp.asarray, state["mom"])
        if "residual" in state:
            sim._residual = jax.tree.map(jnp.asarray, state["residual"])
    sim.key = jnp.asarray(state["key"])
    sim.labels = np.asarray(state["labels"], np.int64)
    sim.round_index = int(state["sim_round"])
    sim._async_phases = np.asarray(state["phases"], np.int64)
    if sim.engine is not None:
        sim.engine.labels = np.asarray(state["engine"]["labels"],
                                       np.int64)
        sim.engine.round_index = int(state["engine"]["round"])
    fn = getattr(sim, "_schedule_fn", None)
    fb = getattr(fn, "state", None)
    if fb is not None:
        fb["ref"] = float(state["sched"]["ref"])
        fb["ema"] = float(state["sched"]["ema"])
    est = getattr(fn, "estimator", None)
    if est is not None:
        est._rate = np.asarray(state["sched"]["rate"], float)
    if clock is not None and "clock" in state:
        ck = state["clock"]
        clock.now = float(ck["now"])
        if int(ck["live"]):
            ncols = int(ck["ncols"])
            clock._async_carry = {
                "T_end": np.asarray(ck["T_end"], float),
                "cols": [np.asarray(ck["cols"][i], float)
                         for i in range(ncols)]}
        else:
            clock._async_carry = None
    if hist is not None and "hist" in state:
        for c, col in state["hist"].items():
            vals = [float(v) for v in np.asarray(col)]
            if c in ("round", "participants"):
                vals = [int(v) for v in vals]
            hist[c][:] = vals


class RunCheckpoint:
    """Atomic single-file run checkpoint under ``<dir>/run.npz``.

    ``save`` captures the sim + clock + history into one tree and
    writes it crash-consistently; ``restore`` validates the archive
    against a freshly constructed sim's structure (raising
    :class:`repro.checkpoint.ckpt.CheckpointStructureError` naming any
    drifted tree paths) and writes every piece back in place. Returns
    the checkpoint meta, whose ``"round"`` is the next round to run.
    """

    FILENAME = "run.npz"

    def __init__(self, dirpath: str):
        self.dir = str(dirpath)
        self.path = os.path.join(self.dir, self.FILENAME)

    def exists(self) -> bool:
        return os.path.exists(self.path)

    def save(self, sim, *, round_idx: int, clock=None, hist=None,
             staleness: Optional[int] = None) -> None:
        state = _capture(sim, round_idx, clock, hist, staleness)
        save_checkpoint(self.path, state, meta={
            "round": int(round_idx),
            "staleness": (None if staleness is None else int(staleness)),
            "engine": ("bank" if sim.bank is not None else
                       "streamed" if getattr(sim, "store", None) is not None
                       else "legacy")})

    def restore(self, sim, *, clock=None, hist=None,
                staleness: Optional[int] = None) -> Dict[str, Any]:
        like = _capture(sim, 0, clock, hist, staleness)
        state, meta = load_checkpoint(self.path, like=like)
        _assign(sim, state, clock, hist)
        meta["round"] = int(state["round"])
        return meta
