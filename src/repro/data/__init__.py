from repro.data.federated import (  # noqa: F401
    dirichlet_partition,
    shard_by_label,
    cluster_partition,
    make_synthetic_classification,
    make_synthetic_images,
    build_fl_data,
)
from repro.data.lm import synthetic_lm_batch, TokenStream  # noqa: F401
