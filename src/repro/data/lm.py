"""Synthetic language-model token streams for the production trainer.

Deterministic, seeded, cheap: a mixture of per-device Markov chains so that
different federated replicas see genuinely non-identical token
distributions (the inter-/intra-cluster divergence knobs of the paper map
to how distinct the per-cluster transition matrices are).
"""
from __future__ import annotations

from typing import Dict, Tuple

import numpy as np


def synthetic_lm_batch(shape: Tuple[int, ...], vocab: int, *,
                       seed: int = 0) -> Dict[str, np.ndarray]:
    """Uniform random tokens (used for smoke tests / dry-run stand-ins)."""
    rng = np.random.default_rng(seed)
    tokens = rng.integers(0, vocab, shape, dtype=np.int32)
    labels = np.roll(tokens, -1, axis=-1)
    return {"tokens": tokens, "labels": labels}


class TokenStream:
    """Per-replica Markov token stream with cluster-level skew.

    replica r in cluster c gets transition bias seeded by (c, r) so that
    intra-cluster divergence < inter-cluster divergence, mirroring the
    paper's Assumptions 5/6.
    """

    def __init__(self, vocab: int, num_replicas: int, cluster_of, *,
                 order_skew: float = 0.8, seed: int = 0):
        self.vocab = vocab
        self.R = num_replicas
        rng = np.random.default_rng(seed)
        self._shift = np.empty(num_replicas, np.int64)
        for r in range(num_replicas):
            c = cluster_of(r)
            base = rng.integers(0, vocab) if False else (c * 7919) % vocab
            self._shift[r] = (base + int(order_skew * 0) + r % 3) % vocab
        self._step = 0

    def next_batch(self, per_replica_shape: Tuple[int, ...]
                   ) -> Dict[str, np.ndarray]:
        """Returns tokens/labels of shape (R, *per_replica_shape)."""
        self._step += 1
        rng = np.random.default_rng(self._step)
        base = rng.integers(0, self.vocab, (self.R,) + tuple(per_replica_shape),
                            dtype=np.int64)
        tokens = (base + self._shift[(...,) + (None,) * len(per_replica_shape)]
                  ) % self.vocab
        tokens = tokens.astype(np.int32)
        labels = np.roll(tokens, -1, axis=-1)
        return {"tokens": tokens, "labels": labels}
