"""Federated data pipeline: synthetic datasets + non-IID partitioners.

Reproduces the paper's two partition regimes (§6.1):
- device-level non-IID via Dirichlet(alpha) over label proportions [41];
- cluster-level IID / non-IID via sort-by-label sharding, where each cluster
  gets C label classes and each device within a cluster gets 2 shards.

Datasets are synthetic (no network access in this environment): Gaussian
class-conditional images whose class means make the task learnable, which is
sufficient to reproduce the paper's *relative* algorithm orderings.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import numpy as np


# ---------------------------------------------------------------------------
# synthetic datasets
# ---------------------------------------------------------------------------

def make_synthetic_classification(
        num_samples: int, d: int, num_classes: int, *, seed: int = 0,
        noise: float = 1.0, means_seed: int = 1234
        ) -> Tuple[np.ndarray, np.ndarray]:
    """Class means come from ``means_seed`` (fixed) so train/test splits
    drawn with different ``seed`` values share the same task."""
    means = np.random.default_rng(means_seed).normal(
        size=(num_classes, d)) * 2.0
    rng = np.random.default_rng(seed)
    y = rng.integers(0, num_classes, num_samples)
    x = means[y] + rng.normal(size=(num_samples, d)) * noise
    return x.astype(np.float32), y.astype(np.int32)


def make_synthetic_images(
        num_samples: int, hw: int, channels: int, num_classes: int, *,
        seed: int = 0, noise: float = 0.7, means_seed: int = 1234
        ) -> Tuple[np.ndarray, np.ndarray]:
    """Class-conditional Gaussian images, (N, H, W, C). Class means come
    from ``means_seed`` so train/test splits share the same task."""
    means = np.random.default_rng(means_seed).normal(
        size=(num_classes, hw, hw, channels)).astype(np.float32)
    rng = np.random.default_rng(seed)
    y = rng.integers(0, num_classes, num_samples)
    x = means[y] + rng.normal(size=(num_samples, hw, hw, channels)) * noise
    return x.astype(np.float32), y.astype(np.int32)


# ---------------------------------------------------------------------------
# partitioners
# ---------------------------------------------------------------------------

def dirichlet_partition(y: np.ndarray, n_devices: int, alpha: float = 0.5,
                        seed: int = 0) -> List[np.ndarray]:
    """Hsu et al. [41]: per-class Dirichlet split across devices."""
    rng = np.random.default_rng(seed)
    classes = np.unique(y)
    idx_per_device: List[List[int]] = [[] for _ in range(n_devices)]
    for c in classes:
        idx = np.nonzero(y == c)[0]
        rng.shuffle(idx)
        props = rng.dirichlet([alpha] * n_devices)
        cuts = (np.cumsum(props)[:-1] * len(idx)).astype(int)
        for dev, part in enumerate(np.split(idx, cuts)):
            idx_per_device[dev].extend(part.tolist())
    return [np.asarray(sorted(ix)) for ix in idx_per_device]


def shard_by_label(y: np.ndarray, n_devices: int, shards_per_device: int = 2,
                   seed: int = 0) -> List[np.ndarray]:
    """McMahan-style pathological non-IID: sort by label, deal shards."""
    rng = np.random.default_rng(seed)
    order = np.argsort(y, kind="stable")
    shards = np.array_split(order, n_devices * shards_per_device)
    ids = rng.permutation(len(shards))
    out = []
    for d in range(n_devices):
        take = ids[d * shards_per_device:(d + 1) * shards_per_device]
        out.append(np.concatenate([shards[t] for t in take]))
    return out


def cluster_partition(y: np.ndarray, m: int, devices_per_cluster: int, *,
                      cluster_iid: bool, labels_per_cluster: int = 2,
                      seed: int = 0) -> List[np.ndarray]:
    """Paper §6.2 'Cluster IID' / 'Cluster Non-IID' (C = labels_per_cluster).

    Returns n = m * devices_per_cluster index arrays, cluster-major order.
    """
    rng = np.random.default_rng(seed)
    n_total = len(y)
    if cluster_iid:
        perm = rng.permutation(n_total)
        cluster_chunks = np.array_split(perm, m)
    else:
        order = np.argsort(y, kind="stable")
        shards = np.array_split(order, labels_per_cluster * m)
        ids = rng.permutation(len(shards))
        cluster_chunks = []
        for i in range(m):
            take = ids[i * labels_per_cluster:(i + 1) * labels_per_cluster]
            cluster_chunks.append(np.concatenate([shards[t] for t in take]))
    out: List[np.ndarray] = []
    for chunk in cluster_chunks:
        # within each cluster: sort by label, 2 shards per device (paper)
        chunk = chunk[np.argsort(y[chunk], kind="stable")]
        dev_shards = np.array_split(chunk, devices_per_cluster * 2)
        ids2 = rng.permutation(len(dev_shards))
        for d in range(devices_per_cluster):
            take = ids2[d * 2:(d + 1) * 2]
            out.append(np.concatenate([dev_shards[t] for t in take]))
    return out


def build_fl_data(x: np.ndarray, y: np.ndarray, parts: List[np.ndarray],
                  test_x: np.ndarray, test_y: np.ndarray,
                  samples_per_device: Optional[int] = None) -> Dict:
    """Stack per-device shards to (n, N, ...) with equal N (resample)."""
    n = len(parts)
    N = samples_per_device or min(len(p) for p in parts)
    N = max(N, 1)
    xs, ys = [], []
    rng = np.random.default_rng(0)
    for p in parts:
        if len(p) >= N:
            sel = p[:N]
        else:  # resample with replacement for tiny shards
            sel = rng.choice(p, size=N, replace=True) if len(p) else \
                rng.integers(0, len(y), N)
        xs.append(x[sel])
        ys.append(y[sel])
    return {
        "xs": np.stack(xs), "ys": np.stack(ys),
        "test_x": test_x, "test_y": test_y,
    }
