"""Core neural-net layers, pure JAX (param dicts + logical sharding axes).

Every ``init_*`` returns ``(params, logical)`` where ``logical`` mirrors the
param pytree with tuples of logical axis names (see repro.sharding).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig

Params = Dict[str, Any]


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.param_dtype)


def dense_init(key, fan_in: int, shape, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def pad_to_multiple(n: int, m: int = 256) -> int:
    return ((n + m - 1) // m) * m


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(cfg: ModelConfig, d: int) -> Tuple[Params, Params]:
    p: Params = {"scale": jnp.ones((d,), _dtype(cfg))}
    l: Params = {"scale": ("embed",)}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), _dtype(cfg))
        l["bias"] = ("embed",)
    return p, l


def apply_norm(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:  # rmsnorm
        ms = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(ms + cfg.norm_eps)
        y = y * p["scale"].astype(jnp.float32)
    return y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------

def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freq = jnp.arange(half, dtype=jnp.float32)
    inv = theta ** (-freq / half)
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., S, half)
    ang = ang[..., None, :]  # broadcast over heads
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------

def init_mlp(key, cfg: ModelConfig, d: int, ff: int) -> Tuple[Params, Params]:
    ks = jax.random.split(key, 3)
    dt = _dtype(cfg)
    if cfg.mlp_act == "relu2":  # nemotron/minitron: squared-relu, no gate
        p = {"w_in": dense_init(ks[0], d, (d, ff), dt),
             "w_out": dense_init(ks[1], ff, (ff, d), dt)}
        l = {"w_in": ("embed", "ff"), "w_out": ("ff", "embed")}
    elif cfg.mlp_act == "gelu":  # whisper-style: single path + bias
        p = {"w_in": dense_init(ks[0], d, (d, ff), dt),
             "b_in": jnp.zeros((ff,), dt),
             "w_out": dense_init(ks[1], ff, (ff, d), dt),
             "b_out": jnp.zeros((d,), dt)}
        l = {"w_in": ("embed", "ff"), "b_in": ("ff",),
             "w_out": ("ff", "embed"), "b_out": ("embed",)}
    else:  # silu gated (llama-family)
        p = {"w_gate": dense_init(ks[0], d, (d, ff), dt),
             "w_up": dense_init(ks[1], d, (d, ff), dt),
             "w_out": dense_init(ks[2], ff, (ff, d), dt)}
        l = {"w_gate": ("embed", "ff"), "w_up": ("embed", "ff"),
             "w_out": ("ff", "embed")}
    return p, l


def apply_mlp(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    if cfg.mlp_act == "relu2":
        h = jnp.square(jax.nn.relu(x @ p["w_in"]))
        return h @ p["w_out"]
    if cfg.mlp_act == "gelu":
        h = jax.nn.gelu(x @ p["w_in"] + p["b_in"])
        return h @ p["w_out"] + p["b_out"]
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    return h @ p["w_out"]


def mlp_apply_fns(cfg: ModelConfig):
    return lambda p, x: apply_mlp(cfg, p, x)


# ---------------------------------------------------------------------------
# Attention (GQA, optional bias / sliding window / cross-attention)
# ---------------------------------------------------------------------------

def padded_heads(cfg: ModelConfig) -> int:
    return max(cfg.head_pad_to, cfg.num_heads) if cfg.head_pad_to \
        else cfg.num_heads


def init_attention(key, cfg: ModelConfig, *, cross: bool = False
                   ) -> Tuple[Params, Params]:
    d, h, hk = cfg.d_model, padded_heads(cfg), cfg.num_kv_heads
    hd = cfg.resolved_head_dim
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    p: Params = {
        "wq": dense_init(ks[0], d, (d, h, hd), dt),
        "wk": dense_init(ks[1], d, (d, hk, hd), dt),
        "wv": dense_init(ks[2], d, (d, hk, hd), dt),
        "wo": dense_init(ks[3], h * hd, (h, hd, d), dt),
    }
    l: Params = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dt)
        p["bk"] = jnp.zeros((hk, hd), dt)
        p["bv"] = jnp.zeros((hk, hd), dt)
        l["bq"] = ("heads", "head_dim")
        l["bk"] = ("kv_heads", "head_dim")
        l["bv"] = ("kv_heads", "head_dim")
    return p, l


def qkv_project(cfg: ModelConfig, p: Params, x: jax.Array,
                kv_input: Optional[jax.Array] = None):
    """Returns q,k,v with shapes (B,S,H,D), (B,Skv,Hkv,D), (B,Skv,Hkv,D)."""
    kv_in = x if kv_input is None else kv_input
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", kv_in, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", kv_in, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    return q, k, v


def _expand_kv(k: jax.Array, num_heads: int) -> jax.Array:
    """(B,S,Hkv,D) -> (B,S,H,D) by repeating kv heads (GQA)."""
    hk = k.shape[-2]
    rep = num_heads // hk
    if rep == 1:
        return k
    return jnp.repeat(k, rep, axis=-2)


def _band_mask(q_pos: jax.Array, k_pos: jax.Array, *, causal: bool,
               window: int) -> jax.Array:
    """True where attention is allowed. q_pos (Sq,), k_pos (Sk,)."""
    diff = q_pos[:, None] - k_pos[None, :]
    ok = jnp.ones(diff.shape, bool)
    if causal:
        ok &= diff >= 0
    if window > 0:
        ok &= diff < window
    return ok


def attention_core(q: jax.Array, k: jax.Array, v: jax.Array, *,
                   causal: bool, window: int = 0,
                   q_offset: int = 0,
                   block_q: int = 1024, block_k: int = 1024) -> jax.Array:
    """Numerically-stable attention; online-softmax block streaming when the
    sequence is long (never materializes the SxS score matrix).

    q: (B,Sq,H,D)  k/v: (B,Sk,Hkv,D) -> (B,Sq,H,D)
    """
    B, Sq, H, D = q.shape
    Sk = k.shape[1]
    k = _expand_kv(k, H)
    v = _expand_kv(v, H)
    scale = 1.0 / math.sqrt(D)
    q_pos = q_offset + jnp.arange(Sq)
    k_pos = jnp.arange(Sk)

    from repro.flags import analysis_mode
    if analysis_mode():
        # fewer, larger tiles: same matmul volume, 16x fewer HLO ops after
        # unrolling (compile time on the 1-core dry-run host)
        block_q = block_k = 2048
    if Sq <= 2048 and Sk <= 2048:
        s = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) * scale
        mask = _band_mask(q_pos, k_pos, causal=causal, window=window)
        s = jnp.where(mask[None, None], s, -1e30)
        pr = jax.nn.softmax(s, axis=-1).astype(q.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", pr, v)

    # --- flash-style double scan (XLA path; Pallas kernel mirrors this) ---
    nq = -(-Sq // block_q)
    nk = -(-Sk // block_k)
    pad_q = nq * block_q - Sq
    pad_k = nk * block_k - Sk
    qp = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    qb = qp.reshape(B, nq, block_q, H, D).transpose(1, 0, 2, 3, 4)
    kb = kp.reshape(B, nk, block_k, H, D).transpose(1, 0, 2, 3, 4)
    vb = vp.reshape(B, nk, block_k, H, D).transpose(1, 0, 2, 3, 4)

    def q_step(_, qi_blk):
        qi, qblk = qi_blk
        qpos = q_offset + qi * block_q + jnp.arange(block_q)

        def k_step(carry, kj_blk):
            m, l, acc = carry
            kj, kblk, vblk = kj_blk
            kpos = kj * block_k + jnp.arange(block_k)
            s = jnp.einsum("bqhd,bkhd->bhqk", qblk, kblk
                           ).astype(jnp.float32) * scale
            valid = _band_mask(qpos, kpos, causal=causal, window=window)
            valid &= (kpos < Sk)[None, :]
            s = jnp.where(valid[None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            pexp = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + pexp.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bhqk,bkhd->bhqd", pexp.astype(qblk.dtype), vblk
            ).astype(jnp.float32)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, H, block_q), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, H, block_q), jnp.float32)
        a0 = jnp.zeros((B, H, block_q, D), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            k_step, (m0, l0, a0), (jnp.arange(nk), kb, vb),
            unroll=nk if analysis_mode() else 1)
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (jnp.arange(nq), qb),
                           unroll=nq if analysis_mode() else 1)
    out = outs.transpose(1, 0, 3, 2, 4).reshape(B, nq * block_q, H, D)
    return out[:, :Sq]


def _mask_padded_heads(cfg: ModelConfig, out: jax.Array) -> jax.Array:
    """Zero the outputs (and thereby all gradients) of padded heads, so
    padding is permanently inert. Padding is interleaved per GQA group
    (slot % rep_new >= rep_old masked) so every real head keeps its
    original kv-head assignment."""
    hp = padded_heads(cfg)
    if hp == cfg.num_heads:
        return out
    rep_new = hp // cfg.num_kv_heads
    rep_old = cfg.num_heads // cfg.num_kv_heads
    mask = ((jnp.arange(hp) % rep_new) < rep_old).astype(out.dtype)
    return out * mask[:, None]


def apply_attention(cfg: ModelConfig, p: Params, x: jax.Array, *,
                    causal: bool = True,
                    kv_input: Optional[jax.Array] = None,
                    positions: Optional[jax.Array] = None,
                    window: Optional[int] = None) -> jax.Array:
    """Full-sequence (train / prefill) attention."""
    q, k, v = qkv_project(cfg, p, x, kv_input)
    if cfg.use_rope and kv_input is None:
        pos = positions if positions is not None else jnp.arange(x.shape[1])
        q = rope(q, pos, cfg.rope_theta)
        k = rope(k, pos, cfg.rope_theta)
    w = cfg.sliding_window if window is None else window
    if cfg.attn_seq_shard and q.shape[1] > 1:
        # context-parallel core: q-sequence over the model axis (exact —
        # each shard computes its rows against full K/V). Rescues archs
        # whose head count is not divisible by the model-parallel degree.
        from repro import sharding as shd
        q = shd.constrain(q, "?", "attn_seq", "?", "?",
                          rules={"attn_seq": "model"})
    out = attention_core(q, k, v, causal=causal and kv_input is None,
                         window=w if kv_input is None else 0)
    out = _mask_padded_heads(cfg, out)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def decode_attention(cfg: ModelConfig, p: Params, x: jax.Array,
                     k_cache: jax.Array, v_cache: jax.Array,
                     pos: jax.Array, *, window: Optional[int] = None,
                     update_cache: bool = True):
    """Single-token decode. x: (B,1,d). caches: (B,S,Hkv,D). pos: () int.

    Returns (out (B,1,d), new_k_cache, new_v_cache).
    """
    q, k, v = qkv_project(cfg, p, x)
    if cfg.use_rope:
        pq = jnp.full((x.shape[1],), pos)
        q = rope(q, pq, cfg.rope_theta)
        k = rope(k, pq, cfg.rope_theta)
    if update_cache:
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_cache, k.astype(k_cache.dtype), pos, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_cache, v.astype(v_cache.dtype), pos, axis=1)
    S = k_cache.shape[1]
    H = q.shape[2]
    kx = _expand_kv(k_cache, H)
    vx = _expand_kv(v_cache, H)
    scale = 1.0 / math.sqrt(q.shape[-1])
    s = jnp.einsum("bqhd,bkhd->bhqk", q, kx).astype(jnp.float32) * scale
    kpos = jnp.arange(S)
    ok = kpos <= pos
    w = cfg.sliding_window if window is None else window
    if w and w > 0:
        ok &= kpos > pos - w
    s = jnp.where(ok[None, None, None, :], s, -1e30)
    pr = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", pr, vx)
    out = _mask_padded_heads(cfg, out)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return out, k_cache, v_cache
