"""The paper's own experiment models (pure JAX, laptop-scale).

- FEMNIST CNN [paper §6.1 cites 6,603,710 params]: the paper's text says
  3x3/32ch/FC-1024, but that yields 1.68M params; the stated count matches
  the LEAF CNN exactly (5x5 conv 32 -> 5x5 conv 64, each + 2x2 maxpool,
  FC-2048, softmax-62) = 6,603,710 — we implement the LEAF CNN.
- VGG-11 (modified, CIFAR-10): the paper's 9,750,922 params pin the
  classifier to 512 -> 512 -> 512 -> 10 (two hidden FCs).
- A small MLP for fast unit tests of the FL optimizer algebra.

These run inside the CE-FedAvg *simulation engine* (vmapped over devices),
so apply fns take (params, images) and return logits.
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


def _conv_init(key, kh, kw, cin, cout):
    scale = 1.0 / jnp.sqrt(kh * kw * cin)
    return jax.random.normal(key, (kh, kw, cin, cout), jnp.float32) * scale


def _fc_init(key, fin, fout):
    scale = 1.0 / jnp.sqrt(fin)
    return jax.random.normal(key, (fin, fout), jnp.float32) * scale


def _conv(x, w, b):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def _maxpool(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


# ---------------------------------------------------------------------------
# FEMNIST CNN
# ---------------------------------------------------------------------------

def init_femnist_cnn(key, num_classes: int = 62,
                     image_size: int = 28) -> Params:
    ks = jax.random.split(key, 4)
    feat = (image_size // 4) ** 2 * 64
    return {
        "c1": {"w": _conv_init(ks[0], 5, 5, 1, 32), "b": jnp.zeros(32)},
        "c2": {"w": _conv_init(ks[1], 5, 5, 32, 64), "b": jnp.zeros(64)},
        "f1": {"w": _fc_init(ks[2], feat, 2048), "b": jnp.zeros(2048)},
        "f2": {"w": _fc_init(ks[3], 2048, num_classes),
               "b": jnp.zeros(num_classes)},
    }


def apply_femnist_cnn(params: Params, images: jax.Array) -> jax.Array:
    x = images  # (B,H,W,1)
    x = _maxpool(jax.nn.relu(_conv(x, params["c1"]["w"], params["c1"]["b"])))
    x = _maxpool(jax.nn.relu(_conv(x, params["c2"]["w"], params["c2"]["b"])))
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["f1"]["w"] + params["f1"]["b"])
    return x @ params["f2"]["w"] + params["f2"]["b"]


# ---------------------------------------------------------------------------
# VGG-11 (CIFAR-10, modified — paper reports 9,750,922 params)
# ---------------------------------------------------------------------------

_VGG11 = [64, "M", 128, "M", 256, 256, "M", 512, 512, "M", 512, 512, "M"]


def init_vgg11(key, num_classes: int = 10, in_ch: int = 3) -> Params:
    params: Params = {"convs": []}
    cin = in_ch
    ks = iter(jax.random.split(key, 16))
    for v in _VGG11:
        if v == "M":
            continue
        params["convs"].append(
            {"w": _conv_init(next(ks), 3, 3, cin, v), "b": jnp.zeros(v)})
        cin = v
    params["f1"] = {"w": _fc_init(next(ks), 512, 512), "b": jnp.zeros(512)}
    params["f1b"] = {"w": _fc_init(next(ks), 512, 512), "b": jnp.zeros(512)}
    params["f2"] = {"w": _fc_init(next(ks), 512, num_classes),
                    "b": jnp.zeros(num_classes)}
    return params


def apply_vgg11(params: Params, images: jax.Array) -> jax.Array:
    x = images  # (B,32,32,3)
    ci = 0
    for v in _VGG11:
        if v == "M":
            x = _maxpool(x)
        else:
            c = params["convs"][ci]
            x = jax.nn.relu(_conv(x, c["w"], c["b"]))
            ci += 1
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["f1"]["w"] + params["f1"]["b"])
    x = jax.nn.relu(x @ params["f1b"]["w"] + params["f1b"]["b"])
    return x @ params["f2"]["w"] + params["f2"]["b"]


# ---------------------------------------------------------------------------
# tiny MLP (unit tests)
# ---------------------------------------------------------------------------

def init_mlp_classifier(key, d_in: int, d_hidden: int,
                        num_classes: int) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "f1": {"w": _fc_init(ks[0], d_in, d_hidden), "b": jnp.zeros(d_hidden)},
        "f2": {"w": _fc_init(ks[1], d_hidden, num_classes),
               "b": jnp.zeros(num_classes)},
    }


def apply_mlp_classifier(params: Params, x: jax.Array) -> jax.Array:
    h = jax.nn.relu(x @ params["f1"]["w"] + params["f1"]["b"])
    return h @ params["f2"]["w"] + params["f2"]["b"]


def softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(lse - picked)


def accuracy(logits: jax.Array, labels: jax.Array) -> jax.Array:
    return jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))


MODEL_REGISTRY = {
    "femnist_cnn": (init_femnist_cnn, apply_femnist_cnn),
    "vgg11": (init_vgg11, apply_vgg11),
    "mlp": (init_mlp_classifier, apply_mlp_classifier),
}
