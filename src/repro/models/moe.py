"""Mixture-of-Experts layer: top-k routing with sort-based capacity dispatch.

Memory-safe dispatch (never materializes the (tokens, experts, capacity)
one-hot): assignments are argsorted by expert id, position-in-expert is
computed from the sorted order, and tokens are scattered into a per-expert
capacity buffer. Experts shard over the ``model`` mesh axis when divisible
(llama4: 128 experts / 16 = 8 per chip), otherwise the expert FFN dim does
(mixtral: 8 experts, d_ff sharded).

Capacity priority is RECENCY: within an expert, the newest tokens keep
their slots and the *oldest* assignments are dropped when capacity binds.
For a causal model this keeps whether token t is served independent of any
earlier token's routing (only tokens after t can displace it), so
perturbing tokens outside a sliding-attention window can never change an
in-window output through the dispatch path — sequence-order priority
(drop-newest) leaked exactly that way.

Tradeoff, stated plainly: some priority order must exist, and either
direction violates an invariant *when capacity binds*. Drop-newest is
causal but non-local (old tokens displace new ones — the sliding-window
leak). Drop-oldest is local but lets a later token's routing decide
whether t is served, an anti-causal bit in t's training logits. We pick
locality: binding capacity is already a lossy regime, the decode path
(single position) never binds, and exactness tests run with non-binding
capacity where both orders coincide (zero drops).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import dense_init

Params = Dict[str, Any]


def init_moe(key, cfg: ModelConfig, d: int, ff: int) -> Tuple[Params, Params]:
    E = cfg.num_experts
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    p: Params = {
        "router": dense_init(ks[0], d, (d, E), jnp.float32),
        "w_gate": dense_init(ks[1], d, (E, d, ff), dt),
        "w_up": dense_init(ks[2], d, (E, d, ff), dt),
        "w_out": dense_init(ks[3], ff, (E, ff, d), dt),
    }
    l: Params = {
        "router": ("embed", "experts"),
        "w_gate": ("experts", "embed", "ff"),
        "w_up": ("experts", "embed", "ff"),
        "w_out": ("experts", "ff", "embed"),
    }
    if cfg.moe_shared_expert:
        ks2 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "w_gate": dense_init(ks2[0], d, (d, ff), dt),
            "w_up": dense_init(ks2[1], d, (d, ff), dt),
            "w_out": dense_init(ks2[2], ff, (ff, d), dt),
        }
        l["shared"] = {"w_gate": ("embed", "ff"), "w_up": ("embed", "ff"),
                       "w_out": ("ff", "embed")}
    return p, l


def _capacity(tokens: int, cfg: ModelConfig) -> int:
    E, k = cfg.num_experts, cfg.experts_per_token
    cap = int(tokens * k * cfg.capacity_factor / E) + 1
    return max(8, -(-cap // 8) * 8)  # round up to 8


def apply_moe(cfg: ModelConfig, p: Params, x: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """x: (B,S,d) -> (y (B,S,d), aux_load_balance_loss ())."""
    B, S, d = x.shape
    if cfg.moe_local_dispatch:
        # per-batch-row dispatch: capacity buffers stay sharded with the
        # batch, so no cross-shard all-reduce of the (E,cap,d) buffer
        y, aux = _moe_tokens_batched(cfg, p, x)
        y = y + _shared(cfg, p, x)
        return y, jnp.mean(aux)
    y, aux = _moe_tokens(cfg, p, x.reshape(B * S, d))
    y = y.reshape(B, S, d) + _shared(cfg, p, x)
    return y, aux


def _shared(cfg: ModelConfig, p: Params, x: jax.Array) -> jax.Array:
    if not cfg.moe_shared_expert:
        return jnp.zeros((), x.dtype)
    sp = p["shared"]
    hs = jax.nn.silu(x @ sp["w_gate"]) * (x @ sp["w_up"])
    return hs @ sp["w_out"]


def _moe_tokens_batched(cfg: ModelConfig, p: Params, x: jax.Array
                        ) -> Tuple[jax.Array, jax.Array]:
    """Batch-local dispatch: x (B,S,d) -> (y (B,S,d), aux (B,)).

    The capacity buffer carries the batch dim and is constrained to stay
    sharded with it ("data"), so dispatch/combine never cross shards."""
    from repro import sharding as shd
    B, S, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token

    logits = (x @ p["router"]).astype(jnp.float32)          # (B,S,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)         # (B,S,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    me = probs.mean(axis=1)                                 # (B,E)
    ce = jnp.zeros((B, E), jnp.float32).at[
        jnp.arange(B)[:, None], expert_ids.reshape(B, -1)].add(
        1.0 / (S * k))
    aux = E * jnp.sum(me * ce, axis=-1)                     # (B,)

    A = S * k
    flat_e = expert_ids.reshape(B, A)
    flat_g = gate_vals.reshape(B, A)
    flat_tok = jnp.broadcast_to(
        jnp.repeat(jnp.arange(S), k)[None], (B, A))
    # sort by (expert, newest-first) so capacity drops the oldest tokens
    order = jnp.argsort(flat_e * A + (A - 1 - jnp.arange(A))[None], axis=1)
    rows = jnp.arange(B)[:, None]
    e_sorted = jnp.take_along_axis(flat_e, order, axis=1)
    tok_sorted = jnp.take_along_axis(flat_tok, order, axis=1)
    counts = jnp.zeros((B, E), jnp.int32).at[rows, e_sorted].add(1)
    seg_start = jnp.concatenate(
        [jnp.zeros((B, 1), jnp.int32), jnp.cumsum(counts, 1)[:, :-1]], 1)
    pos_in_e = jnp.arange(A, dtype=jnp.int32)[None] - \
        jnp.take_along_axis(seg_start, e_sorted, axis=1)
    cap = _capacity(S, cfg)
    keep = pos_in_e < cap
    dest = jnp.where(keep, e_sorted * cap + pos_in_e, E * cap)

    # scatter only the small int32 slot map; move the big tensors with
    # gathers (take_along_axis), which stay local to the batch shard —
    # scatter-adds on batch-sharded activations otherwise lower to a full
    # cross-shard gather of the (B, S*k, d) combine buffer.
    slot_tok = jnp.full((B, E * cap + 1), S, jnp.int32)  # S = sentinel
    slot_tok = slot_tok.at[rows, dest].set(
        jnp.where(keep, tok_sorted, S))
    x_pad = jnp.concatenate([x, jnp.zeros((B, 1, d), x.dtype)], axis=1)
    xe = jnp.take_along_axis(
        x_pad, slot_tok[:, :-1, None], axis=1).reshape(B, E, cap, d)
    xe = shd.constrain(xe, "batch", None, None, None)

    h = jax.nn.silu(jnp.einsum("becd,edf->becf", xe, p["w_gate"])) * \
        jnp.einsum("becd,edf->becf", xe, p["w_up"])
    ye = jnp.einsum("becf,efd->becd", h, p["w_out"])
    ye = shd.constrain(ye, "batch", None, None, None)

    got = ye.reshape(B, E * cap, d)
    got = jnp.concatenate([got, jnp.zeros((B, 1, d), got.dtype)], axis=1)
    per_assign = jnp.take_along_axis(got, dest[..., None], axis=1) * \
        jnp.take_along_axis(flat_g, order, axis=1)[..., None].astype(x.dtype)
    # un-sort with a gather (inverse permutation), then sum k contributions
    inv_order = jnp.argsort(order, axis=1)
    per_tok = jnp.take_along_axis(per_assign, inv_order[..., None], axis=1)
    y = per_tok.reshape(B, S, k, d).sum(axis=2)
    return y, aux


def _moe_tokens(cfg: ModelConfig, p: Params, xt: jax.Array
                ) -> Tuple[jax.Array, jax.Array]:
    """xt: (T,d) -> (y (T,d), aux ())."""
    T, d = xt.shape
    E, k = cfg.num_experts, cfg.experts_per_token

    logits = (xt @ p["router"]).astype(jnp.float32)        # (T,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)         # (T,k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # load-balance aux loss (Switch-style)
    me = probs.mean(axis=0)                                 # (E,)
    ce = jnp.zeros((E,), jnp.float32).at[expert_ids.reshape(-1)].add(
        1.0 / (T * k))
    aux = E * jnp.sum(me * ce)

    # ---- sort-based dispatch ----
    A = T * k
    flat_e = expert_ids.reshape(A)                          # (A,)
    flat_g = gate_vals.reshape(A)
    flat_tok = jnp.repeat(jnp.arange(T), k)
    # sort by (expert, newest-first) so capacity drops the oldest tokens
    order = jnp.argsort(flat_e * A + (A - 1 - jnp.arange(A)))
    e_sorted = flat_e[order]
    tok_sorted = flat_tok[order]
    # position within expert = index - start-of-segment
    counts = jnp.zeros((E,), jnp.int32).at[e_sorted].add(1)
    seg_start = jnp.concatenate(
        [jnp.zeros((1,), jnp.int32), jnp.cumsum(counts)[:-1]])
    pos_in_e = jnp.arange(A, dtype=jnp.int32) - seg_start[e_sorted]
    cap = _capacity(T, cfg)
    keep = pos_in_e < cap
    dest = jnp.where(keep, e_sorted * cap + pos_in_e, E * cap)  # overflow slot

    buf = jnp.zeros((E * cap + 1, d), xt.dtype)
    buf = buf.at[dest].set(xt[tok_sorted] * keep[:, None].astype(xt.dtype))
    xe = buf[:-1].reshape(E, cap, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, p["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", xe, p["w_up"])
    ye = jnp.einsum("ecf,efd->ecd", h, p["w_out"])          # (E,cap,d)

    # ---- combine ----
    got = ye.reshape(E * cap, d)
    got = jnp.concatenate([got, jnp.zeros((1, d), got.dtype)])
    per_assign = got[dest] * flat_g[order][:, None].astype(xt.dtype)
    # un-sort and sum the k contributions per token
    y = jnp.zeros((T, d), xt.dtype).at[tok_sorted].add(per_assign)
    return y, aux
