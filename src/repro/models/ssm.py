"""Mamba-2 (SSD, state-space duality) block in pure JAX. [arXiv:2405.21060]

Chunked dual form: intra-chunk quadratic attention-like block (the part the
Pallas kernel ``repro.kernels.ssd_scan`` accelerates) + inter-chunk linear
state recurrence via ``lax.scan``. Single B/C group shared across heads
(ngroups=1), per-head scalar A, depthwise causal conv on (x, B, C).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models.layers import dense_init

Params = Dict[str, Any]


def init_mamba(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    d = cfg.d_model
    inner = cfg.ssm_inner
    H = cfg.ssm_heads
    N = cfg.ssm_state
    cw = cfg.ssm_conv_width
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    conv_ch = inner + 2 * N
    p: Params = {
        "wz": dense_init(ks[0], d, (d, inner), dt),
        "wx": dense_init(ks[1], d, (d, inner), dt),
        "wB": dense_init(ks[2], d, (d, N), dt),
        "wC": dense_init(ks[3], d, (d, N), dt),
        "wdt": dense_init(ks[4], d, (d, H), dt),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "A_log": jnp.zeros((H,), jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "conv_w": dense_init(ks[5], cw, (cw, conv_ch), dt),
        "conv_b": jnp.zeros((conv_ch,), dt),
        "norm_scale": jnp.ones((inner,), dt),
        "wo": dense_init(ks[6], inner, (inner, d), dt),
    }
    l: Params = {
        "wz": ("embed", "ssm_inner"),
        "wx": ("embed", "ssm_inner"),
        "wB": ("embed", "state"),
        "wC": ("embed", "state"),
        "wdt": ("embed", "ssm_heads"),
        "dt_bias": ("ssm_heads",),
        "A_log": ("ssm_heads",),
        "D": ("ssm_heads",),
        "conv_w": ("conv", None),
        "conv_b": (None,),
        "norm_scale": ("ssm_inner",),
        "wo": ("ssm_inner", "embed"),
    }
    return p, l


def _causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                 state: jax.Array | None = None):
    """Depthwise causal conv. x: (B,S,C), w: (K,C). Returns (y, new_state)
    where state holds the last K-1 inputs for streaming decode."""
    K = w.shape[0]
    if state is None:
        xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(K)) + b
    new_state = xp[:, -(K - 1):] if K > 1 else xp[:, :0]
    return jax.nn.silu(y), new_state


def _segsum(a: jax.Array) -> jax.Array:
    """a: (..., C). Returns (..., C, C) with out[i,j] = sum_{j<l<=i} a_l,
    -inf above the diagonal."""
    C = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((C, C), bool))
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x: jax.Array, dtv: jax.Array, A: jax.Array, Bm: jax.Array,
                Cm: jax.Array, chunk: int, initial_state=None,
                intra_fn=None):
    """SSD over a full sequence.

    x: (B,S,H,P)  dtv: (B,S,H)  A: (H,) negative  Bm/Cm: (B,S,N)
    Returns (y (B,S,H,P), final_state (B,H,P,N)).

    ``intra_fn`` optionally overrides the intra-chunk computation (the Pallas
    kernel hook); signature (xc, ac, Bc, Cc, dtc) -> y_intra per chunk batch.
    """
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dtv = jnp.pad(dtv, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0)))
    K = x.shape[1] // chunk
    xc = x.reshape(Bsz, K, chunk, H, P)
    dtc = dtv.reshape(Bsz, K, chunk, H)
    Bc = Bm.reshape(Bsz, K, chunk, N)
    Cc = Cm.reshape(Bsz, K, chunk, N)
    a = dtc * A  # (B,K,C,H) negative decay logits
    a_t = a.transpose(0, 1, 3, 2)  # (B,K,H,C)
    seg = _segsum(a_t)  # (B,K,H,C,C)
    cum = jnp.cumsum(a_t, axis=-1)  # (B,K,H,C)
    total = cum[..., -1]  # (B,K,H)

    # ---- intra-chunk (quadratic within chunk) ----
    if intra_fn is None:
        scores = jnp.einsum("bkin,bkjn->bkij", Cc.astype(jnp.float32),
                            Bc.astype(jnp.float32))
        att = scores[:, :, None] * jnp.exp(seg)  # (B,K,H,C,C)
        y_intra = jnp.einsum("bkhij,bkjh,bkjhp->bkihp", att, dtc,
                             xc.astype(jnp.float32))
    else:
        y_intra = intra_fn(xc, a_t, Bc, Cc, dtc)

    # ---- chunk-final states ----
    decay_to_end = jnp.exp(total[..., None] - cum)  # (B,K,H,C)
    states = jnp.einsum("bkjn,bkhj,bkjh,bkjhp->bkhpn",
                        Bc.astype(jnp.float32), decay_to_end, dtc,
                        xc.astype(jnp.float32))  # (B,K,H,P,N)

    # ---- inter-chunk recurrence ----
    chunk_decay = jnp.exp(total)  # (B,K,H)

    def step(s, inp):
        st_k, dec_k = inp  # (B,H,P,N), (B,H)
        s_new = s * dec_k[..., None, None] + st_k
        return s_new, s  # emit state *entering* the chunk

    s0 = (jnp.zeros((Bsz, H, P, N), jnp.float32)
          if initial_state is None else initial_state.astype(jnp.float32))
    final_state, prev_states = jax.lax.scan(
        step, s0, (states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)  # (B,K,H,P,N)

    y_inter = jnp.einsum("bkin,bkhi,bkhpn->bkihp", Cc.astype(jnp.float32),
                         jnp.exp(cum), prev_states)
    y = (y_intra + y_inter).reshape(Bsz, K * chunk, H, P)
    return y[:, :S].astype(x.dtype), final_state


def apply_mamba(cfg: ModelConfig, p: Params, u: jax.Array,
                intra_fn=None) -> jax.Array:
    """Full-sequence Mamba-2 block. u: (B,S,d) -> (B,S,d)."""
    B_, S, _ = u.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    z = u @ p["wz"]
    xBC = jnp.concatenate([u @ p["wx"], u @ p["wB"], u @ p["wC"]], axis=-1)
    xBC, _ = _causal_conv(xBC, p["conv_w"], p["conv_b"])
    inner = cfg.ssm_inner
    x, Bm, Cm = jnp.split(xBC, [inner, inner + N], axis=-1)
    x = x.reshape(B_, S, H, P)
    dtv = jax.nn.softplus((u @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, _ = ssd_chunked(x, dtv, A, Bm, Cm, cfg.ssm_chunk, intra_fn=intra_fn)
    y = y + (p["D"][:, None] * x.astype(jnp.float32)).astype(y.dtype)
    y = y.reshape(B_, S, inner)
    # gated RMSNorm (mamba2 style)
    y = y * jax.nn.silu(z)
    ms = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(ms + 1e-5)).astype(u.dtype)
    y = y * p["norm_scale"]
    return y @ p["wo"]


def init_mamba_cache(cfg: ModelConfig, num_layers: int, batch: int,
                     dtype=jnp.float32) -> Dict[str, jax.Array]:
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    conv_ch = cfg.ssm_inner + 2 * N
    return {
        "ssm_state": jnp.zeros((num_layers, batch, H, P, N), jnp.float32),
        "conv_state": jnp.zeros(
            (num_layers, batch, cfg.ssm_conv_width - 1, conv_ch), dtype),
    }


def decode_mamba(cfg: ModelConfig, p: Params, u: jax.Array,
                 ssm_state: jax.Array, conv_state: jax.Array):
    """Single-token recurrent update. u: (B,1,d). ssm_state: (B,H,P,N)."""
    B_, _, _ = u.shape
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    inner = cfg.ssm_inner
    z = u @ p["wz"]
    xBC = jnp.concatenate([u @ p["wx"], u @ p["wB"], u @ p["wC"]], axis=-1)
    xBC, conv_state = _causal_conv(xBC, p["conv_w"], p["conv_b"], conv_state)
    x, Bm, Cm = jnp.split(xBC[:, 0], [inner, inner + N], axis=-1)
    x = x.reshape(B_, H, P).astype(jnp.float32)
    dtv = jax.nn.softplus(
        (u[:, 0] @ p["wdt"]).astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dtv * A)  # (B,H)
    upd = jnp.einsum("bn,bh,bhp->bhpn", Bm.astype(jnp.float32), dtv, x)
    ssm_state = ssm_state * decay[..., None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32), ssm_state)
    y = y + p["D"][:, None] * x
    y = y.reshape(B_, 1, inner).astype(u.dtype)
    y = y * jax.nn.silu(z)
    ms = jnp.mean(jnp.square(y.astype(jnp.float32)), axis=-1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(ms + 1e-5)).astype(u.dtype)
    y = y * p["norm_scale"]
    return y @ p["wo"], ssm_state, conv_state
