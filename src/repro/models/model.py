"""Unified model zoo: dense | moe | ssm | hybrid | encdec | vlm.

All families share one interface:
  init_model(key, cfg)                  -> (params, logical_axes)
  forward(cfg, params, batch, ...)      -> (logits, aux)
  lm_loss(cfg, params, batch, ...)      -> scalar
  init_decode_cache(cfg, batch, seq)    -> cache pytree (+ logical axes)
  decode_step(cfg, params, cache, tok, pos) -> (logits, new_cache)

Layers are stacked (leading "layers" axis) and applied with ``lax.scan`` so
even 88-layer models lower to a small HLO (critical for the 512-device
dry-run on a CPU host).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.config import ModelConfig
from repro.models import layers as L
from repro.models import moe as M
from repro.models import ssm as S

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _stack_init(fn, key, n: int):
    """vmap an init fn over n layer keys -> (stacked params, logical+layers)."""
    keys = jax.random.split(key, n)
    params = jax.vmap(lambda k: fn(k)[0])(keys)
    _, logical = fn(key)  # structure only (cheap: single-layer init)
    logical = jax.tree.map(
        lambda l: ("layers",) + tuple(l), logical,
        is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(e, (str, type(None))) for e in x))
    return params, logical


def _sinusoidal(positions: jax.Array, d: int) -> jax.Array:
    half = d // 2
    freq = jnp.exp(-math.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    ang = positions[:, None].astype(jnp.float32) * freq[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def padded_vocab(cfg: ModelConfig) -> int:
    return L.pad_to_multiple(cfg.vocab_size, 256)


# ---------------------------------------------------------------------------
# per-family blocks
# ---------------------------------------------------------------------------

def _init_dense_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    pa, la = L.init_attention(ks[0], cfg)
    pm, lm = L.init_mlp(ks[1], cfg, cfg.d_model, cfg.d_ff)
    pn1, ln1 = L.init_norm(cfg, cfg.d_model)
    pn2, ln2 = L.init_norm(cfg, cfg.d_model)
    return ({"attn": pa, "mlp": pm, "norm1": pn1, "norm2": pn2},
            {"attn": la, "mlp": lm, "norm1": ln1, "norm2": ln2})


def _apply_dense_block(cfg: ModelConfig, lp: Params, x: jax.Array,
                       window: Optional[int] = None) -> jax.Array:
    h = L.apply_norm(cfg, lp["norm1"], x)
    x = x + L.apply_attention(cfg, lp["attn"], h, causal=True, window=window)
    h = L.apply_norm(cfg, lp["norm2"], x)
    x = x + L.apply_mlp(cfg, lp["mlp"], h)
    return x


def _init_moe_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    pa, la = L.init_attention(ks[0], cfg)
    pm, lm = M.init_moe(ks[1], cfg, cfg.d_model, cfg.d_ff)
    pn1, ln1 = L.init_norm(cfg, cfg.d_model)
    pn2, ln2 = L.init_norm(cfg, cfg.d_model)
    return ({"attn": pa, "moe": pm, "norm1": pn1, "norm2": pn2},
            {"attn": la, "moe": lm, "norm1": ln1, "norm2": ln2})


def _apply_moe_block(cfg: ModelConfig, lp: Params, x: jax.Array,
                     window: Optional[int] = None):
    h = L.apply_norm(cfg, lp["norm1"], x)
    x = x + L.apply_attention(cfg, lp["attn"], h, causal=True, window=window)
    h = L.apply_norm(cfg, lp["norm2"], x)
    y, aux = M.apply_moe(cfg, lp["moe"], h)
    return x + y, aux


def _init_ssm_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    pm, lm = S.init_mamba(ks[0], cfg)
    pn, ln = L.init_norm(cfg, cfg.d_model)
    return {"mamba": pm, "norm1": pn}, {"mamba": lm, "norm1": ln}


def _apply_ssm_block(cfg: ModelConfig, lp: Params, x: jax.Array,
                     intra_fn=None) -> jax.Array:
    h = L.apply_norm(cfg, lp["norm1"], x)
    return x + S.apply_mamba(cfg, lp["mamba"], h, intra_fn=intra_fn)


def _init_encdec_dec_block(key, cfg: ModelConfig):
    ks = jax.random.split(key, 6)
    psa, lsa = L.init_attention(ks[0], cfg)
    pca, lca = L.init_attention(ks[1], cfg, cross=True)
    pm, lm = L.init_mlp(ks[2], cfg, cfg.d_model, cfg.d_ff)
    pn = {}
    ln = {}
    for i in (1, 2, 3):
        pn[f"norm{i}"], ln[f"norm{i}"] = L.init_norm(cfg, cfg.d_model)
    return ({"self_attn": psa, "cross_attn": pca, "mlp": pm, **pn},
            {"self_attn": lsa, "cross_attn": lca, "mlp": lm, **ln})


# ---------------------------------------------------------------------------
# init_model
# ---------------------------------------------------------------------------

def init_model(key, cfg: ModelConfig) -> Tuple[Params, Params]:
    ks = jax.random.split(key, 8)
    V = padded_vocab(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    params: Params = {
        "tok_embed": L.dense_init(ks[0], cfg.d_model, (V, cfg.d_model), dt),
    }
    logical: Params = {"tok_embed": ("vocab", "embed")}
    pn, ln = L.init_norm(cfg, cfg.d_model)
    params["final_norm"], logical["final_norm"] = pn, ln
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(
            ks[1], cfg.d_model, (cfg.d_model, V), dt)
        logical["lm_head"] = ("embed", "vocab")

    fam = cfg.family
    if fam in ("dense", "vlm"):
        params["layers"], logical["layers"] = _stack_init(
            lambda k: _init_dense_block(k, cfg), ks[2], cfg.num_layers)
        if fam == "vlm":
            params["vision_proj"] = L.dense_init(
                ks[3], cfg.d_model, (cfg.d_model, cfg.d_model), dt)
            logical["vision_proj"] = ("embed", "embed")
    elif fam == "moe":
        if cfg.moe_shared_expert:  # llama4-style: alternating dense/moe pairs
            assert cfg.num_layers % 2 == 0
            pd, ld = _stack_init(lambda k: _init_dense_block(k, cfg),
                                 ks[2], cfg.num_layers // 2)
            pm, lm = _stack_init(lambda k: _init_moe_block(k, cfg),
                                 ks[3], cfg.num_layers // 2)
            params["layers"] = {"dense": pd, "moe": pm}
            logical["layers"] = {"dense": ld, "moe": lm}
        else:  # mixtral-style: every layer MoE
            params["layers"], logical["layers"] = _stack_init(
                lambda k: _init_moe_block(k, cfg), ks[2], cfg.num_layers)
    elif fam == "ssm":
        params["layers"], logical["layers"] = _stack_init(
            lambda k: _init_ssm_block(k, cfg), ks[2], cfg.num_layers)
    elif fam == "hybrid":
        assert cfg.attn_every > 0 and cfg.num_layers % cfg.attn_every == 0
        groups = cfg.num_layers // cfg.attn_every

        def group_init(k):
            return _stack_init(lambda kk: _init_ssm_block(kk, cfg),
                               k, cfg.attn_every)
        gkeys = jax.random.split(ks[2], groups)
        gp = jax.vmap(lambda k: group_init(k)[0])(gkeys)
        _, gl = group_init(ks[2])
        gl = jax.tree.map(
            lambda l: ("layers",) + tuple(l), gl,
            is_leaf=lambda x: isinstance(x, tuple) and all(
                isinstance(e, (str, type(None))) for e in x))
        params["layers"], logical["layers"] = gp, gl
        sp, sl = _init_dense_block(ks[3], cfg)  # the *shared* attention block
        params["shared_block"], logical["shared_block"] = sp, sl
    elif fam == "encdec":
        penc, lenc = _stack_init(lambda k: _init_dense_block(k, cfg),
                                 ks[2], cfg.encoder_layers)
        pdec, ldec = _stack_init(lambda k: _init_encdec_dec_block(k, cfg),
                                 ks[3], cfg.num_layers)
        params["enc_layers"], logical["enc_layers"] = penc, lenc
        params["dec_layers"], logical["dec_layers"] = pdec, ldec
        pn2, ln2 = L.init_norm(cfg, cfg.d_model)
        params["enc_final_norm"], logical["enc_final_norm"] = pn2, ln2
    else:
        raise ValueError(f"unknown family {fam}")
    return params, logical


def param_count(params: Params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# forward (train / prefill)
# ---------------------------------------------------------------------------

def _embed(cfg: ModelConfig, params: Params, tokens: jax.Array) -> jax.Array:
    x = params["tok_embed"][tokens].astype(jnp.dtype(cfg.dtype))
    if not cfg.use_rope:  # sinusoidal positions (whisper-style)
        pos = _sinusoidal(jnp.arange(tokens.shape[1]), cfg.d_model)
        x = x + pos[None].astype(x.dtype)
    return x


def _logits(cfg: ModelConfig, params: Params, x: jax.Array) -> jax.Array:
    x = L.apply_norm(cfg, params["final_norm"], x)
    head = (params["tok_embed"].T if cfg.tie_embeddings
            else params["lm_head"])
    return x @ head


def _scan(body, x0, stacked, remat: bool):
    from repro.flags import analysis_mode
    fn = jax.checkpoint(body) if remat else body

    def step(carry, lp):
        return fn(carry, lp)
    if analysis_mode():  # unroll layers so cost_analysis counts every layer
        n = jax.tree.leaves(stacked)[0].shape[0]
        return jax.lax.scan(step, x0, stacked, unroll=n)
    return jax.lax.scan(step, x0, stacked)


def _dscan(body, x0, xs):
    """Layer scan for decode paths; unrolled under analysis mode."""
    from repro.flags import analysis_mode
    if analysis_mode():
        n = jax.tree.leaves(xs)[0].shape[0]
        return jax.lax.scan(body, x0, xs, unroll=n)
    return jax.lax.scan(body, x0, xs)


def forward(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
            *, remat: bool = False, intra_fn=None
            ) -> Tuple[jax.Array, jax.Array]:
    """Returns (logits, aux_loss). batch keys per family (see configs)."""
    fam = cfg.family
    dt = jnp.dtype(cfg.dtype)
    aux0 = jnp.zeros((), jnp.float32)

    if fam == "encdec":
        enc = batch["frames"].astype(dt)  # stub frontend embeddings
        pos = _sinusoidal(jnp.arange(enc.shape[1]), cfg.d_model)
        enc = enc + pos[None].astype(dt)

        def enc_body(x, lp):
            h = L.apply_norm(cfg, lp["norm1"], x)
            x = x + L.apply_attention(cfg, lp["attn"], h, causal=False)
            h = L.apply_norm(cfg, lp["norm2"], x)
            x = x + L.apply_mlp(cfg, lp["mlp"], h)
            return x, None
        enc, _ = _scan(enc_body, enc, params["enc_layers"], remat)
        enc = L.apply_norm(cfg, params["enc_final_norm"], enc)

        x = _embed(cfg, params, batch["tokens"])

        def dec_body(x, lp):
            h = L.apply_norm(cfg, lp["norm1"], x)
            x = x + L.apply_attention(cfg, lp["self_attn"], h, causal=True)
            h = L.apply_norm(cfg, lp["norm2"], x)
            x = x + L.apply_attention(cfg, lp["cross_attn"], h,
                                      kv_input=enc)
            h = L.apply_norm(cfg, lp["norm3"], x)
            x = x + L.apply_mlp(cfg, lp["mlp"], h)
            return x, None
        x, _ = _scan(dec_body, x, params["dec_layers"], remat)
        return _logits(cfg, params, x), aux0

    if fam == "vlm":
        tok = _embed(cfg, params, batch["tokens"])
        patches = batch["patch_embeds"].astype(dt) @ params["vision_proj"]
        x = jnp.concatenate([patches, tok], axis=1)
    else:
        x = _embed(cfg, params, batch["tokens"])

    if fam in ("dense", "vlm"):
        def body(x, lp):
            return _apply_dense_block(cfg, lp, x), None
        x, _ = _scan(body, x, params["layers"], remat)
    elif fam == "moe":
        if cfg.moe_shared_expert:  # llama4: (dense SWA, moe full) pairs
            def body(carry, lp):
                x, aux = carry
                x = _apply_dense_block(cfg, lp["dense"], x,
                                       window=cfg.sliding_window)
                x, a = _apply_moe_block(cfg, lp["moe"], x, window=0)
                return (x, aux + a), None
            (x, aux0), _ = _scan(body, (x, aux0), params["layers"], remat)
        else:
            def body(carry, lp):
                x, aux = carry
                x, a = _apply_moe_block(cfg, lp, x)
                return (x, aux + a), None
            (x, aux0), _ = _scan(body, (x, aux0), params["layers"], remat)
    elif fam == "ssm":
        def body(x, lp):
            return _apply_ssm_block(cfg, lp, x, intra_fn=intra_fn), None
        x, _ = _scan(body, x, params["layers"], remat)
    elif fam == "hybrid":
        shared = params["shared_block"]

        def group_body(x, gp):
            def inner(x2, lp):
                return _apply_ssm_block(cfg, lp, x2, intra_fn=intra_fn), None
            x, _ = _dscan(inner, x, gp)
            x = _apply_dense_block(cfg, shared, x)
            return x, None
        x, _ = _scan(group_body, x, params["layers"], remat)
    else:
        raise ValueError(fam)
    return _logits(cfg, params, x), aux0


def lm_loss(cfg: ModelConfig, params: Params, batch: Dict[str, jax.Array],
            *, remat: bool = False, aux_weight: float = 0.01) -> jax.Array:
    logits, aux = forward(cfg, params, batch, remat=remat)
    labels = batch["labels"]
    if cfg.family == "vlm":  # loss only on the text positions
        logits = logits[:, -labels.shape[1]:]
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    picked = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(lse - picked) + aux_weight * aux


# ---------------------------------------------------------------------------
# decode (serve)
# ---------------------------------------------------------------------------

def init_decode_cache(cfg: ModelConfig, batch: int, seq: int,
                      dtype=None) -> Tuple[Params, Params]:
    """Returns (cache, logical_axes). ``seq`` is the max/present KV length."""
    dt = jnp.dtype(dtype or cfg.dtype)
    fam = cfg.family
    hk, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    kv_logical = ("layers", "batch", "kv_seq", "kv_heads", "head_dim")

    def kv(nl):
        return jnp.zeros((nl, batch, seq, hk, hd), dt)

    if fam in ("dense", "vlm"):
        c = {"k": kv(cfg.num_layers), "v": kv(cfg.num_layers)}
        l = {"k": kv_logical, "v": kv_logical}
    elif fam == "moe":
        if cfg.moe_shared_expert:
            half = cfg.num_layers // 2
            c = {"k": jnp.zeros((half, 2, batch, seq, hk, hd), dt),
                 "v": jnp.zeros((half, 2, batch, seq, hk, hd), dt)}
            l6 = ("layers", None, "batch", "kv_seq", "kv_heads", "head_dim")
            l = {"k": l6, "v": l6}
        else:
            c = {"k": kv(cfg.num_layers), "v": kv(cfg.num_layers)}
            l = {"k": kv_logical, "v": kv_logical}
    elif fam == "ssm":
        c = S.init_mamba_cache(cfg, cfg.num_layers, batch, dt)
        l = {"ssm_state": ("layers", "batch", "ssm_heads", None, "state"),
             "conv_state": ("layers", "batch", None, None)}
    elif fam == "hybrid":
        groups = cfg.num_layers // cfg.attn_every
        mc = S.init_mamba_cache(cfg, groups * cfg.attn_every, batch, dt)
        mc = {k: v.reshape((groups, cfg.attn_every) + v.shape[1:])
              for k, v in mc.items()}
        c = {**mc,
             "k": jnp.zeros((groups, batch, seq, hk, hd), dt),
             "v": jnp.zeros((groups, batch, seq, hk, hd), dt)}
        l = {"ssm_state": ("layers", None, "batch", "ssm_heads", None,
                           "state"),
             "conv_state": ("layers", None, "batch", None, None),
             "k": kv_logical, "v": kv_logical}
    elif fam == "encdec":
        c = {"k": kv(cfg.num_layers), "v": kv(cfg.num_layers),
             "xk": jnp.zeros((cfg.num_layers, batch, cfg.encoder_seq,
                              cfg.num_heads, hd), dt),
             "xv": jnp.zeros((cfg.num_layers, batch, cfg.encoder_seq,
                              cfg.num_heads, hd), dt)}
        xl = ("layers", "batch", None, "heads", "head_dim")
        l = {"k": kv_logical, "v": kv_logical, "xk": xl, "xv": xl}
    else:
        raise ValueError(fam)
    return c, l


def decode_step(cfg: ModelConfig, params: Params, cache: Params,
                tokens: jax.Array, pos: jax.Array
                ) -> Tuple[jax.Array, Params]:
    """One decode step. tokens: (B,1) int32, pos: () int32 (current length).

    Returns (logits (B,1,V), new_cache)."""
    fam = cfg.family
    x = params["tok_embed"][tokens].astype(jnp.dtype(cfg.dtype))
    if not cfg.use_rope:
        x = x + _sinusoidal(pos[None], cfg.d_model)[None].astype(x.dtype)

    if fam in ("dense", "vlm"):
        def body(x, sl):
            lp, kc, vc = sl
            h = L.apply_norm(cfg, lp["norm1"], x)
            a, kc, vc = L.decode_attention(cfg, lp["attn"], h, kc, vc, pos)
            x = x + a
            h = L.apply_norm(cfg, lp["norm2"], x)
            x = x + L.apply_mlp(cfg, lp["mlp"], h)
            return x, (kc, vc)
        x, (nk, nv) = _dscan(
            body, x, (params["layers"], cache["k"], cache["v"]))
        cache = {"k": nk, "v": nv}
    elif fam == "moe":
        if cfg.moe_shared_expert:
            def body(x, sl):
                lp, kc, vc = sl
                h = L.apply_norm(cfg, lp["dense"]["norm1"], x)
                a, k0, v0 = L.decode_attention(
                    cfg, lp["dense"]["attn"], h, kc[0], vc[0], pos,
                    window=cfg.sliding_window)
                x = x + a
                h = L.apply_norm(cfg, lp["dense"]["norm2"], x)
                x = x + L.apply_mlp(cfg, lp["dense"]["mlp"], h)
                h = L.apply_norm(cfg, lp["moe"]["norm1"], x)
                a, k1, v1 = L.decode_attention(
                    cfg, lp["moe"]["attn"], h, kc[1], vc[1], pos, window=0)
                x = x + a
                h = L.apply_norm(cfg, lp["moe"]["norm2"], x)
                y, _ = M.apply_moe(cfg, lp["moe"]["moe"], h)
                x = x + y
                return x, (jnp.stack([k0, k1]), jnp.stack([v0, v1]))
            x, (nk, nv) = jax.lax.scan(
                body, x, ({"dense": params["layers"]["dense"],
                           "moe": params["layers"]["moe"]},
                          cache["k"], cache["v"]))
        else:
            def body(x, sl):
                lp, kc, vc = sl
                h = L.apply_norm(cfg, lp["norm1"], x)
                a, kc, vc = L.decode_attention(cfg, lp["attn"], h, kc, vc,
                                               pos)
                x = x + a
                h = L.apply_norm(cfg, lp["norm2"], x)
                y, _ = M.apply_moe(cfg, lp["moe"], h)
                x = x + y
                return x, (kc, vc)
            x, (nk, nv) = jax.lax.scan(
                body, x, (params["layers"], cache["k"], cache["v"]))
        cache = {"k": nk, "v": nv}
    elif fam == "ssm":
        def body(x, sl):
            lp, st, cs = sl
            h = L.apply_norm(cfg, lp["norm1"], x)
            y, st, cs = S.decode_mamba(cfg, lp["mamba"], h, st, cs)
            return x + y, (st, cs)
        x, (ns, nc) = _dscan(
            body, x, (params["layers"], cache["ssm_state"],
                      cache["conv_state"]))
        cache = {"ssm_state": ns, "conv_state": nc}
    elif fam == "hybrid":
        shared = params["shared_block"]

        def body(x, sl):
            gp, st, cs, kc, vc = sl

            def inner(x2, isl):
                lp, st1, cs1 = isl
                h = L.apply_norm(cfg, lp["norm1"], x2)
                y, st1, cs1 = S.decode_mamba(cfg, lp["mamba"], h, st1, cs1)
                return x2 + y, (st1, cs1)
            x, (st, cs) = _dscan(inner, x, (gp, st, cs))
            h = L.apply_norm(cfg, shared["norm1"], x)
            a, kc, vc = L.decode_attention(cfg, shared["attn"], h, kc, vc,
                                           pos)
            x = x + a
            h = L.apply_norm(cfg, shared["norm2"], x)
            x = x + L.apply_mlp(cfg, shared["mlp"], h)
            return x, (st, cs, kc, vc)
        x, (ns, nc, nk, nv) = _dscan(
            body, x, (params["layers"], cache["ssm_state"],
                      cache["conv_state"], cache["k"], cache["v"]))
        cache = {"ssm_state": ns, "conv_state": nc, "k": nk, "v": nv}
    elif fam == "encdec":
        def body(x, sl):
            lp, kc, vc, xk, xv = sl
            h = L.apply_norm(cfg, lp["norm1"], x)
            a, kc, vc = L.decode_attention(cfg, lp["self_attn"], h, kc, vc,
                                           pos)
            x = x + a
            h = L.apply_norm(cfg, lp["norm2"], x)
            a, _, _ = L.decode_attention(cfg, lp["cross_attn"], h, xk, xv,
                                         xk.shape[1] - 1, update_cache=False)
            x = x + a
            h = L.apply_norm(cfg, lp["norm3"], x)
            x = x + L.apply_mlp(cfg, lp["mlp"], h)
            return x, (kc, vc)
        x, (nk, nv) = _dscan(
            body, x, (params["dec_layers"], cache["k"], cache["v"],
                      cache["xk"], cache["xv"]))
        cache = {"k": nk, "v": nv, "xk": cache["xk"], "xv": cache["xv"]}
    else:
        raise ValueError(fam)
    return _logits(cfg, params, x), cache
