from repro.models.model import (  # noqa: F401
    init_model,
    forward,
    lm_loss,
    init_decode_cache,
    decode_step,
)
