"""Ablation driver: how backhaul topology and gossip steps interact
(paper Fig. 6 + Theorem 1's Ω terms), on the simulation engine.

  PYTHONPATH=src python examples/topology_study.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp  # noqa: E402

from repro.config import FLConfig  # noqa: E402
from repro.core.cefedavg import FLSimulator, make_w_schedule  # noqa: E402
from repro.core.topology import omega1, omega2  # noqa: E402
from repro.data.federated import (build_fl_data,  # noqa: E402
                                  dirichlet_partition,
                                  make_synthetic_classification)
from repro.models.cnn import (apply_mlp_classifier,  # noqa: E402
                              init_mlp_classifier)


def main():
    print(f"{'topology':12s} {'pi':>3s} {'zeta':>6s} {'Omega1':>8s} "
          f"{'Omega2':>8s} {'acc@6':>6s}")
    for topo, pi in [("ring", 1), ("ring", 10), ("erdos_renyi", 1),
                     ("complete", 1)]:
        fl = FLConfig(num_clusters=8, devices_per_cluster=2, tau=2, q=2,
                      pi=pi, topology=topo, er_prob=0.4)
        sched = make_w_schedule(fl)
        x, y = make_synthetic_classification(1600, 16, 8, seed=0)
        tx, ty = make_synthetic_classification(400, 16, 8, seed=1)
        parts = dirichlet_partition(y, fl.n, 0.5, seed=2)
        data = {k: jnp.asarray(v) for k, v in
                build_fl_data(x, y, parts, tx, ty, 64).items()}
        sim = FLSimulator(lambda k: init_mlp_classifier(k, 16, 32, 8),
                          apply_mlp_classifier, fl, data, lr=0.1,
                          batch_size=16)
        hist = sim.run(6)
        z = sched.zeta
        print(f"{topo:12s} {pi:3d} {z:6.3f} {omega1(z, pi):8.3f} "
              f"{omega2(z, pi):8.3f} {hist['acc'][-1]:6.3f}")
    print("\nsmaller zeta / larger pi => smaller Omega terms => tighter "
          "Theorem-1 bound (and empirically faster convergence).")


if __name__ == "__main__":
    main()
