"""Ablation driver: how backhaul topology and gossip steps interact
(paper Fig. 6 + Theorem 1's Ω terms), on the simulation engine — plus what
each topology costs the sharded trainer's gossip backends (bytes/round per
``gossip_impl``, from the same GossipSchedule the trainer lowers).

  PYTHONPATH=src python examples/topology_study.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp  # noqa: E402

from repro.config import FLConfig  # noqa: E402
from repro.core.cefedavg import FLSimulator, make_w_schedule  # noqa: E402
from repro.core.gossip import GossipSchedule  # noqa: E402
from repro.core.runtime import gossip_traffic_per_round  # noqa: E402
from repro.core.topology import omega1, omega2  # noqa: E402
from repro.data.federated import (build_fl_data,  # noqa: E402
                                  dirichlet_partition,
                                  make_synthetic_classification)
from repro.models.cnn import (apply_mlp_classifier,  # noqa: E402
                              init_mlp_classifier)


MODEL_BITS = 6_603_710 * 32.0      # the paper's FEMNIST CNN, fp32


def main(rounds: int = 6):
    acc_col = f"acc@{rounds}"
    print(f"{'topology':12s} {'pi':>3s} {'zeta':>6s} {'Omega1':>8s} "
          f"{'Omega2':>8s} {acc_col:>6s} {'sparse_MB':>9s} "
          f"{'exact_MB':>8s} {'dense_MB':>8s}")
    for topo, pi in [("ring", 1), ("ring", 10), ("erdos_renyi", 1),
                     ("complete", 1)]:
        fl = FLConfig(num_clusters=8, devices_per_cluster=2, tau=2, q=2,
                      pi=pi, topology=topo, er_prob=0.4)
        sched = make_w_schedule(fl)
        x, y = make_synthetic_classification(1600, 16, 8, seed=0)
        tx, ty = make_synthetic_classification(400, 16, 8, seed=1)
        parts = dirichlet_partition(y, fl.n, 0.5, seed=2)
        data = {k: jnp.asarray(v) for k, v in
                build_fl_data(x, y, parts, tx, ty, 64).items()}
        sim = FLSimulator(lambda k: init_mlp_classifier(k, 16, 32, 8),
                          apply_mlp_classifier, fl, data, lr=0.1,
                          batch_size=16)
        hist = sim.run(rounds)
        z = sched.zeta
        # what this backhaul costs each sharded gossip backend per round
        mb = {}
        for impl in ("sparse", "ringweight", "dense"):
            tr = gossip_traffic_per_round(
                impl, num_clusters=fl.num_clusters,
                devices_per_cluster=fl.devices_per_cluster, pi=pi,
                degrees=sched.degrees, model_bits=MODEL_BITS)
            mb[impl] = tr["total_bits"] / 8e6
        gs = GossipSchedule.build(sched.H, pi, fl.devices_per_cluster)
        assert gs.models_received_total(fl.n) * MODEL_BITS / 8e6 == \
            mb["sparse"]
        print(f"{topo:12s} {pi:3d} {z:6.3f} {omega1(z, pi):8.3f} "
              f"{omega2(z, pi):8.3f} {hist['acc'][-1]:6.3f} "
              f"{mb['sparse']:9.0f} {mb['ringweight']:8.0f} "
              f"{mb['dense']:8.0f}")
    print("\nsmaller zeta / larger pi => smaller Omega terms => tighter "
          "Theorem-1 bound (and empirically faster convergence); the MB "
          "columns are per-global-round backhaul traffic of each "
          "gossip_impl backend on that topology.")


if __name__ == "__main__":
    main()
