"""Ablation: uplink compression × CE-FedAvg (paper §2 composability).

Runs CE-FedAvg with exact, int8, and top-k(5%) uplinks, and reports final
accuracy plus the eq.-(8) round time with the compressed payload — showing
the compression/convergence trade the paper cites [8], [24], [25].

  PYTHONPATH=src python examples/compressed_federated.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp  # noqa: E402

from repro.config import FLConfig  # noqa: E402
from repro.core.cefedavg import FLSimulator  # noqa: E402
from repro.core.compress import (CompressionConfig,  # noqa: E402
                                 compression_ratio)
from repro.core.privacy import DPConfig, gaussian_epsilon  # noqa: E402
from repro.core.runtime import (HardwareProfile, RuntimeModel,  # noqa: E402
                                WorkloadProfile)
from repro.data.federated import (build_fl_data,  # noqa: E402
                                  dirichlet_partition,
                                  make_synthetic_classification)
from repro.models.cnn import (apply_mlp_classifier,  # noqa: E402
                              init_mlp_classifier)


def run(compression=None, dp=None, rounds=8):
    fl = FLConfig(num_clusters=4, devices_per_cluster=4, tau=2, q=4, pi=10,
                  topology="ring")
    x, y = make_synthetic_classification(1600, 16, 8, seed=0)
    tx, ty = make_synthetic_classification(400, 16, 8, seed=1)
    parts = dirichlet_partition(y, fl.n, 0.5, 2)
    data = {k: jnp.asarray(v) for k, v in
            build_fl_data(x, y, parts, tx, ty, 64).items()}
    sim = FLSimulator(lambda k: init_mlp_classifier(k, 16, 32, 8),
                      apply_mlp_classifier, fl, data, lr=0.1,
                      batch_size=16, compression=compression, dp=dp)
    hist = sim.run(rounds)
    rt = RuntimeModel(HardwareProfile(),
                      WorkloadProfile(6_603_710, 13.3e6 * 50 * 3))
    ratio = compression_ratio(compression) if compression else 1.0
    t = rt.round_time("ce_fedavg", fl.tau, fl.q, fl.pi, uplink_ratio=ratio)
    return hist["acc"][-1], t


def main(rounds: int = 8):
    print(f"{'variant':24s} {'final_acc':>9s} {'round_s':>9s} {'notes'}")
    acc, t = run(rounds=rounds)
    print(f"{'exact (f32 uplink)':24s} {acc:9.3f} {t:9.1f}")
    acc, t = run(CompressionConfig('int8'), rounds=rounds)
    print(f"{'int8 uplink (4x)':24s} {acc:9.3f} {t:9.1f}")
    acc, t = run(CompressionConfig('topk', topk_frac=0.05), rounds=rounds)
    print(f"{'topk 5% + err-feedback':24s} {acc:9.3f} {t:9.1f}")
    dp = DPConfig(clip_norm=1.0, noise_multiplier=0.5)
    acc, t = run(dp=dp, rounds=rounds)
    print(f"{'local DP (sigma=0.5)':24s} {acc:9.3f} {t:9.1f} "
          f"eps~{gaussian_epsilon(0.5):.1f} per release")


if __name__ == "__main__":
    main()
