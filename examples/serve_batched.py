"""Batched serving example: prefill + decode with per-family caches for a
reduced SSM (mamba2) and a reduced GQA (qwen2) model — the serve path the
decode_32k / long_500k dry-run shapes lower.

  PYTHONPATH=src python examples/serve_batched.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main as serve_main  # noqa: E402


def main(archs=("mamba2-2.7b", "qwen2-0.5b")):
    for arch in archs:
        print(f"\n=== serving {arch} (reduced) ===")
        serve_main(["--arch", arch, "--reduced", "--batch", "2",
                    "--prompt-len", "16", "--decode-tokens", "8",
                    "--max-seq", "64"])


if __name__ == "__main__":
    main()
