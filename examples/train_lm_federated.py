"""End-to-end driver: federated training of a ~100M-param qwen2-style LM
with the *production* sharded CE-FedAvg trainer (the same code path the
multi-pod dry-run lowers), for a few hundred local steps on CPU.

  PYTHONPATH=src python examples/train_lm_federated.py [--rounds 25]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.config import (ExperimentConfig, FLConfig,  # noqa: E402
                          TrainConfig)
from repro.configs import get_model_config  # noqa: E402
from repro.core.sharded import ShardedCEFedAvg  # noqa: E402
from repro.launch.mesh import make_mesh  # noqa: E402


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=50)  # 200 local steps
    ap.add_argument("--tau", type=int, default=2)
    ap.add_argument("--q", type=int, default=2)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny model + 2 rounds (the example smoke test)")
    args = ap.parse_args(argv)

    # ~100M-param config: qwen2-0.5b family at modest width/depth
    cfg = dataclasses.replace(
        get_model_config("qwen2-0.5b"),
        num_layers=8, d_model=768, num_heads=12, num_kv_heads=4,
        d_ff=3072, head_dim=64, vocab_size=32000,
        dtype="float32", param_dtype="float32")
    if args.smoke:
        cfg = get_model_config("qwen2-0.5b").reduced()
        args.rounds, args.seq, args.batch = 2, 32, 2
    mesh = make_mesh((1, 1), ("data", "model"))  # 1 CPU device
    exp = ExperimentConfig(
        model=cfg,
        fl=FLConfig(num_clusters=1, devices_per_cluster=1, tau=args.tau,
                    q=args.q, pi=2, topology="ring"),
        train=TrainConfig(optimizer="adamw", learning_rate=1e-3))
    tr = ShardedCEFedAvg(exp, mesh)
    n_params = sum(int(np.prod(s.shape)) for s in
                   jax.tree.leaves(tr.param_shapes))
    print(f"model: {n_params/1e6:.1f}M params (stacked over "
          f"{tr.geo.num_replicas} replica(s))")

    # synthetic next-token task with learnable structure: tok_{t+1} =
    # (tok_t * 31 + 7) % V on half the stream, uniform noise on the rest
    def batch_for(step):
        rng = np.random.default_rng(step)
        R = tr.geo.num_replicas
        toks = rng.integers(0, cfg.vocab_size,
                            (args.q, args.tau, R, args.batch, args.seq),
                            dtype=np.int64)
        toks = np.cumsum(toks, axis=-1) * 0 + toks  # keep dtype path simple
        for t in range(1, args.seq):
            toks[..., t] = (toks[..., t - 1] * 31 + 7) % cfg.vocab_size
        labels = np.roll(toks, -1, axis=-1)
        return {"tokens": jnp.asarray(toks, jnp.int32),
                "labels": jnp.asarray(labels, jnp.int32)}

    with mesh:
        params, opt = jax.jit(tr.init_fn())(jax.random.PRNGKey(0))
        round_fn = jax.jit(tr.make_global_round(), donate_argnums=(0, 1))
        step = jnp.zeros((), jnp.int32)
        t0 = time.time()
        for r in range(args.rounds):
            params, opt, metrics, step = round_fn(params, opt,
                                                  batch_for(r), step)
            if r % 5 == 0 or r == args.rounds - 1:
                print(f"round {r:3d} (local step {int(step):4d}): "
                      f"loss={float(metrics['loss']):.4f} "
                      f"[{time.time()-t0:.0f}s]", flush=True)
    print("done — loss should fall well below ln(V) =",
          f"{np.log(cfg.vocab_size):.2f}")


if __name__ == "__main__":
    main()
