"""Quickstart: CE-FedAvg (Algorithm 1) on a synthetic federated task.

Runs the paper-faithful simulation engine — 16 devices, 4 edge servers on a
ring backhaul — under the wall-clock event clock (core/clock.py), and
reports time-to-accuracy under the paper's §6.1 network model for
CE-FedAvg and the three baselines. See docs/SCENARIOS.md for running the
same comparison with heterogeneous/mobile devices.

  PYTHONPATH=src python examples/quickstart.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp  # noqa: E402

from repro.config import FLConfig  # noqa: E402
from repro.core.cefedavg import FLSimulator  # noqa: E402
from repro.core.clock import (run_wall_clock,  # noqa: E402
                              time_to_accuracy)
from repro.core.runtime import paper_runtime_model  # noqa: E402
from repro.data.federated import (build_fl_data,  # noqa: E402
                                  dirichlet_partition,
                                  make_synthetic_classification)
from repro.models.cnn import (apply_mlp_classifier,  # noqa: E402
                              init_mlp_classifier)


def main(rounds: int = 8, target: float = 0.9, schedule=None):
    """``rounds``/``target`` are exposed so the example smoke test can
    dry-run one round; ``schedule`` accepts a ``core.program`` schedule
    name (e.g. "adaptive_tau") to run CE-FedAvg on a non-canonical
    RoundProgram — see docs/SCENARIOS.md."""
    print("=== CFEL quickstart: 16 devices, 4 edge servers, ring backhaul")
    results = {}
    rt = paper_runtime_model()
    for algo, m, dpc in [("ce_fedavg", 4, 4), ("hier_favg", 4, 4),
                         ("fedavg", 1, 16), ("local_edge", 4, 4)]:
        fl = FLConfig(algorithm=algo, num_clusters=m,
                      devices_per_cluster=dpc, tau=2, q=4, pi=10,
                      topology="ring")
        x, y = make_synthetic_classification(1600, 16, 8, seed=0)
        tx, ty = make_synthetic_classification(400, 16, 8, seed=1)
        parts = dirichlet_partition(y, fl.n, 0.5, seed=2)
        data = {k: jnp.asarray(v) for k, v in
                build_fl_data(x, y, parts, tx, ty, 64).items()}
        sim = FLSimulator(lambda k: init_mlp_classifier(k, 16, 32, 8),
                          apply_mlp_classifier, fl, data, lr=0.1,
                          batch_size=16,
                          schedule=schedule if algo == "ce_fedavg"
                          else None)
        hist = run_wall_clock(sim, rt, rounds)
        tta = time_to_accuracy(hist, target)
        results[algo] = tta
        print(f"  {algo:13s} final_acc={hist['acc'][-1]:.3f} "
              f"round={hist['wall_time'][0]:7.1f}s "
              f"time_to_{target:.0%}="
              f"{'never' if tta is None else f'{tta:,.0f}s'}")
    ce, fa = results["ce_fedavg"], results["fedavg"]
    if ce and fa:
        print(f"\nCE-FedAvg reaches {target:.0%} in "
              f"{(1 - ce / fa) * 100:.1f}% less time than cloud FedAvg "
              f"(paper reports ~62.5% less on FEMNIST)")


if __name__ == "__main__":
    main()
