"""Async bounded-staleness execution (ISSUE 7): parity, invariants, edges.

The correctness anchor is the s = 0 degeneracy: one async round with
staleness 0 is a global barrier, so the trajectory must match the
barrier engines — bitwise against the plain flat bank, and to fp
tolerance against the cohort-compacted path. For s > 0 the contract is
the staleness invariant: every realized gossip edge (i, j) in the
recorded event trace satisfies |phase_i − phase_j| <= s.

Fuzzing: configs are drawn from seeded numpy generators (deterministic
"fuzz" that needs no extra deps); when ``hypothesis`` is installed an
extra property-based sweep of the pure timeline/mask layer runs too.
The sharded-engine parity test is marked ``multidevice`` (in-process,
needs 8 devices); the CLI end-to-end test spawns a subprocess and is
marked ``slow`` — matching the lanes in ci.yml.
"""
import dataclasses
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FLConfig, ScenarioConfig
from repro.core import gossip as gsp
from repro.core import program as prg
from repro.core.cefedavg import FLSimulator
from repro.core.runtime import compute_bound_runtime_model
from repro.core.scenario import get_scenario
from repro.data.federated import (build_fl_data, dirichlet_partition,
                                  make_synthetic_classification)
from repro.models.cnn import apply_mlp_classifier, init_mlp_classifier

RT = compute_bound_runtime_model()


def _data(fl):
    x, y = make_synthetic_classification(800, 16, 4, seed=3)
    tx, ty = make_synthetic_classification(400, 16, 4, seed=4)
    parts = dirichlet_partition(y, fl.n, alpha=0.5, seed=5)
    data = build_fl_data(x, y, parts, tx, ty, samples_per_device=64)
    return {k: jnp.asarray(v) for k, v in data.items()}


def _sim(fl, *, scenario=None, seed=0, lr=0.1):
    return FLSimulator(
        lambda k: init_mlp_classifier(k, 16, 32, 4),
        apply_mlp_classifier, fl, _data(fl), lr=lr, batch_size=16,
        seed=seed, scenario=scenario)


def _maxdiff(a, b):
    return float(jnp.max(jnp.abs(a - b)))


def _fuzz_fl(seed):
    """Deterministically fuzzed FL geometry/schedule from one seed."""
    rng = np.random.default_rng(seed)
    algo = rng.choice(["ce_fedavg", "hier_favg", "dec_local_sgd"])
    m = int(rng.integers(2, 5))
    dpc = 1 if algo == "dec_local_sgd" else int(rng.integers(1, 4))
    if algo == "dec_local_sgd":
        m = max(m, 3)
    return FLConfig(algorithm=str(algo), num_clusters=m,
                    devices_per_cluster=dpc,
                    tau=int(rng.integers(1, 4)), q=int(rng.integers(1, 4)),
                    pi=int(rng.integers(2, 8)),
                    topology=str(rng.choice(["ring", "complete"])))


def _check_trace(sim, staleness):
    """Every realized cross-cluster edge respects the staleness bound,
    and every event's advancing clusters sit exactly at its block."""
    trace = sim.last_async["trace"]
    assert trace, "async round recorded no events"
    for ev in trace:
        ph = np.asarray(ev["phases"])
        assert (ph[list(ev["clusters"])] == ev["block"]).all()
        for (i, j) in ev["edges"]:
            assert abs(int(ph[i]) - int(ph[j])) <= staleness, \
                f"edge ({i},{j}) gap {abs(int(ph[i]) - int(ph[j]))} > " \
                f"{staleness} at block {ev['block']}"


# ---------------------------------------------------------------------------
# s = 0 degeneracy: async is the barrier, bit for bit
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
def test_s0_parity_flat_fuzzed(seed):
    """Async s=0 == barrier flat-bank trajectory exactly, across fuzzed
    geometries/schedules (the correctness anchor)."""
    fl = _fuzz_fl(seed)
    sb, sa = _sim(fl, seed=seed), _sim(fl, seed=seed)
    sb._compact_enabled = False   # plain flat path: bitwise comparable
    for _ in range(3):
        sb.step_round()
        sa.step_round_async(0, RT)
    assert _maxdiff(sb.bank.params, sa.bank.params) == 0.0
    assert _maxdiff(sb.bank.mom, sa.bank.mom) == 0.0


@pytest.mark.parametrize("sname", ["lognormal", "sampled", "mobility"])
def test_s0_parity_compact_scenario(sname):
    """Async s=0 matches the cohort-compacted barrier path to fp
    tolerance under sampling/mobility scenarios (identical keyed plan
    draws on both sides)."""
    fl = FLConfig(algorithm="ce_fedavg", num_clusters=4,
                  devices_per_cluster=2, tau=2, q=2, pi=4,
                  topology="ring")
    sc = dataclasses.replace(get_scenario(sname), seed=7)
    sb, sa = _sim(fl, scenario=sc), _sim(fl, scenario=sc)
    for _ in range(3):
        sb.step_round()
        sa.step_round_async(0, RT)
    assert _maxdiff(sb.bank.params, sa.bank.params) < 2e-4
    assert _maxdiff(sb.bank.mom, sa.bank.mom) < 2e-4


def test_s0_resets_async_carry():
    """s=0 rounds are pure barriers: no carry survives into a later
    async round's timeline (its block 0 starts from a common front)."""
    fl = FLConfig(algorithm="ce_fedavg", num_clusters=4,
                  devices_per_cluster=2, tau=2, q=2, pi=4,
                  topology="ring")
    sa = _sim(fl)
    sa.step_round_async(2, RT)
    sa.step_round_async(0, RT)
    assert sa._async_carry is None


# ---------------------------------------------------------------------------
# s > 0: staleness invariant on the realized event trace
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(4))
@pytest.mark.parametrize("staleness", [1, 2, 3])
def test_staleness_invariant_fuzzed(seed, staleness):
    fl = _fuzz_fl(seed)
    sc = ScenarioConfig(name="fuzz", speed_dist="lognormal",
                        speed_spread=0.6, sample_fraction=0.5,
                        seed=seed)
    sa = _sim(fl, scenario=sc, seed=seed)
    for _ in range(3):
        sa.step_round_async(staleness, RT)
        _check_trace(sa, staleness)


def test_async_round_completes_all_phases():
    """Every cluster ends the round having cleared every block (the
    round-serialized executor never strands a cluster mid-phase)."""
    fl = FLConfig(algorithm="ce_fedavg", num_clusters=4,
                  devices_per_cluster=2, tau=2, q=3, pi=4,
                  topology="ring")
    sa = _sim(fl)
    nblocks = None
    for r in range(2):
        sa.step_round_async(2, RT)
        nblocks = len(prg.block_programs(sa.last_program))
    assert (sa.last_async["phases"] == 2 * nblocks).all()


def test_async_learns():
    """Sanity: s=2 async training still converges on the toy task."""
    fl = FLConfig(algorithm="ce_fedavg", num_clusters=4,
                  devices_per_cluster=2, tau=2, q=2, pi=4,
                  topology="ring")
    sa = _sim(fl)
    for _ in range(8):
        sa.step_round_async(2, RT)
    acc, loss = sa.evaluate(256)
    assert np.isfinite(loss) and acc > 0.5


# ---------------------------------------------------------------------------
# edge cases: dropout mid-round, mobility re-draws at differing phases
# ---------------------------------------------------------------------------

def test_cluster_dropout_mid_block():
    """A whole cluster sampled out mid-round: its identity rows must
    keep the operator row-stochastic and the round must still complete
    every phase (no deadlock, no weight leakage)."""
    fl = FLConfig(algorithm="ce_fedavg", num_clusters=4,
                  devices_per_cluster=2, tau=2, q=2, pi=4,
                  topology="ring")
    sc = ScenarioConfig(name="harsh", speed_dist="lognormal",
                        speed_spread=0.8, sample_fraction=0.25,
                        dropout_prob=0.4, seed=11)
    sa = _sim(fl, scenario=sc)
    saw_dropout = False
    for _ in range(6):
        plan = sa.step_round_async(2, RT)
        _check_trace(sa, 2)
        mask = np.asarray(plan.mask)
        labels = np.asarray(plan.labels)
        for c in range(fl.num_clusters):
            if mask[labels == c].sum() == 0:
                saw_dropout = True
    assert saw_dropout, "scenario never dropped a full cluster; the " \
                        "edge case was not exercised (tune seed)"
    assert np.isfinite(float(jnp.abs(sa.bank.params).max()))


def test_mobility_redraw_at_differing_phases():
    """Mobility re-draws B_t between rounds while clusters carry
    staggered timelines across the round boundary: no staleness
    violation and no deadlock."""
    fl = FLConfig(algorithm="ce_fedavg", num_clusters=4,
                  devices_per_cluster=2, tau=2, q=2, pi=4,
                  topology="ring")
    sc = dataclasses.replace(get_scenario("mobile_sampled"), seed=5,
                             speed_spread=0.6)
    sa = _sim(fl, scenario=sc)
    labels_seen = set()
    for _ in range(6):
        plan = sa.step_round_async(2, RT)
        _check_trace(sa, 2)
        labels_seen.add(tuple(int(c) for c in plan.labels))
    assert len(labels_seen) > 1, "mobility never re-drew B_t"
    # staggered carry really crossed round boundaries
    carry = sa._async_carry
    assert carry is not None and len(np.unique(carry["T_end"])) > 1


def test_upload_programs_rejected():
    """EF-residual uploads are not staleness-safe; the executor must
    refuse rather than silently corrupt the residual state."""
    from repro.core.compress import CompressionConfig
    fl = FLConfig(algorithm="ce_fedavg", num_clusters=2,
                  devices_per_cluster=2, tau=1, q=1, pi=2,
                  topology="ring")
    x, y = make_synthetic_classification(200, 16, 4, seed=3)
    parts = dirichlet_partition(y, fl.n, alpha=0.5, seed=5)
    data = build_fl_data(x, y, parts, x[:50], y[:50],
                         samples_per_device=32)
    data = {k: jnp.asarray(v) for k, v in data.items()}
    sim = FLSimulator(
        lambda k: init_mlp_classifier(k, 16, 32, 4),
        apply_mlp_classifier, fl, data, lr=0.1, batch_size=16,
        compression=CompressionConfig(kind="topk", topk_frac=0.1,
                                      error_feedback=True))
    with pytest.raises(AssertionError):
        sim.step_round_async(1, RT)


# ---------------------------------------------------------------------------
# multidevice lane: the sharded bank engine inherits the executor
# ---------------------------------------------------------------------------

NDEV = 8


@pytest.mark.multidevice
@pytest.mark.skipif(
    jax.device_count() < NDEV,
    reason=f"needs {NDEV} devices; run under XLA_FLAGS="
           f"--xla_force_host_platform_device_count={NDEV} "
           f"(the CI multidevice lane does)")
@pytest.mark.parametrize("staleness", [0, 2])
def test_sharded_async_parity(staleness):
    """The sharded bank engine's async rounds match the single-device
    flat bank event for event — at s=0 (barrier degeneracy) and at
    s=2 (staleness-masked operators force the dense-rotation path)."""
    from repro.core.sharded import ShardedBankCEFedAvg
    from repro.launch.mesh import make_replica_mesh
    fl = FLConfig(algorithm="ce_fedavg", num_clusters=4,
                  devices_per_cluster=2, tau=2, q=2, pi=4,
                  topology="ring")
    init = lambda k: init_mlp_classifier(k, 16, 32, 4)   # noqa: E731
    ref = _sim(fl)
    sb = ShardedBankCEFedAvg(init, apply_mlp_classifier, fl, _data(fl),
                             make_replica_mesh(NDEV), lr=0.1,
                             batch_size=16, seed=0)
    for _ in range(2):
        ref.step_round_async(staleness, RT)
        sb.step_round_async(staleness, RT)
        if staleness:
            _check_trace(sb, staleness)
    assert _maxdiff(ref.bank.params, sb.bank.params) < 2e-4
    assert _maxdiff(ref.bank.mom, sb.bank.mom) < 2e-4


# ---------------------------------------------------------------------------
# slow lane: CLI end to end (subprocess, 8 forced host devices)
# ---------------------------------------------------------------------------

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.mark.slow
def test_cli_async_staleness_end_to_end():
    """`train --engine bank --async-staleness 2` runs real async rounds
    on an 8-device host and reports per-round event counts/makespans."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--engine", "bank",
         "--data-parallel", "8", "--rounds", "2", "--async-staleness",
         "2", "--scenario", "lognormal"],
        capture_output=True, text=True, env=env, timeout=1200)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "async_staleness=2" in out.stdout
    assert "events=" in out.stdout and "makespan=" in out.stdout


# ---------------------------------------------------------------------------
# property sweep over the pure mask layer (hypothesis-fuzzed when the
# package is installed; a seeded deterministic sweep always runs)
# ---------------------------------------------------------------------------

def _mask_property(seed, staleness):
    """staleness_mask preserves row-stochasticity, never mixes a column
    whose phase gap exceeds the bound, and pins non-advancing rows to
    identity — for arbitrary phase vectors and advancing sets."""
    rng = np.random.default_rng(seed)
    m, dpc = int(rng.integers(2, 5)), int(rng.integers(1, 4))
    n = m * dpc
    labels = np.repeat(np.arange(m), dpc)
    W = rng.random((n, n)).astype(np.float32)
    W /= W.sum(1, keepdims=True)
    phases = rng.integers(0, 4, size=m)
    adv = rng.random(m) < 0.7
    if not adv.any():
        adv[int(rng.integers(m))] = True
    p = int(phases[adv][0])
    phases[adv] = p                          # advancing share one phase
    Wm = gsp.staleness_mask(W, labels, phases, staleness, adv)
    np.testing.assert_allclose(Wm.sum(1), 1.0, atol=1e-5)
    gap = np.abs(phases - p)[labels]
    row_adv = adv[labels]
    if (gap > staleness).any():
        # dropped columns belong to non-advancing (out-of-bound)
        # clusters, so no diagonal entry of an advancing row is in here
        assert (Wm[np.ix_(row_adv, gap > staleness)] == 0).all()
    assert (Wm[~row_adv] == np.eye(n, dtype=np.float32)[~row_adv]).all()


@pytest.mark.parametrize("staleness", [0, 1, 3])
@pytest.mark.parametrize("seed", range(8))
def test_staleness_mask_properties(seed, staleness):
    _mask_property(seed, staleness)


try:
    from hypothesis import given, settings, strategies as st
except ImportError:
    pass
else:
    @settings(max_examples=60, deadline=None)
    @given(st.integers(0, 2 ** 31 - 1), st.integers(0, 3))
    def test_hypothesis_staleness_mask(seed, staleness):
        _mask_property(seed, staleness)
