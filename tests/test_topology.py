"""Mixing-matrix / topology properties (Assumption 4) — incl. hypothesis."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import topology as topo


TOPOS = ["ring", "complete", "star", "torus"]


@pytest.mark.parametrize("name,m", [("ring", 8), ("complete", 8),
                                    ("star", 8), ("torus", 9),
                                    ("erdos_renyi", 8)])
def test_mixing_matrix_assumption4(name, m):
    from repro.config import FLConfig
    cfg = FLConfig(topology=name, er_prob=0.4)
    adj = topo.build_adjacency(name, m, cfg)
    H = topo.mixing_matrix(adj)
    # doubly stochastic + symmetric
    np.testing.assert_allclose(H.sum(0), 1.0, atol=1e-12)
    np.testing.assert_allclose(H.sum(1), 1.0, atol=1e-12)
    np.testing.assert_allclose(H, H.T, atol=1e-12)
    # supported on the graph
    off = ~np.eye(m, dtype=bool)
    assert np.all((H[off] > 0) <= adj[off])
    # spectral gap
    assert topo.zeta(H) < 1.0 - 1e-9


def test_complete_graph_zeta_zero():
    H = topo.mixing_matrix(topo.complete(6))
    assert topo.zeta(H) < 1e-10  # paper: complete graphs have zeta = 0


def test_ring_zeta_increases_with_size():
    zs = [topo.zeta(topo.mixing_matrix(topo.ring(m))) for m in (4, 8, 16)]
    assert zs[0] < zs[1] < zs[2]


def test_er_connectivity_vs_p():
    z_sparse = topo.zeta(topo.mixing_matrix(topo.erdos_renyi(16, 0.2, 1)))
    z_dense = topo.zeta(topo.mixing_matrix(topo.erdos_renyi(16, 0.9, 1)))
    assert z_dense < z_sparse  # better connectivity -> smaller zeta (Fig 6)


@given(st.integers(3, 12), st.floats(0.2, 0.9), st.integers(0, 100))
@settings(max_examples=25, deadline=None)
def test_er_mixing_hypothesis(m, p, seed):
    adj = topo.erdos_renyi(m, p, seed)
    H = topo.mixing_matrix(adj)
    np.testing.assert_allclose(H.sum(0), 1.0, atol=1e-10)
    np.testing.assert_allclose(H, H.T, atol=1e-12)
    assert topo.zeta(H) < 1.0


@given(st.lists(st.integers(1, 6), min_size=1, max_size=6))
@settings(max_examples=25, deadline=None)
def test_intra_operator_projection(sizes):
    """V = B^T diag(c) B is an averaging projection: V² = V, V1 = 1."""
    V = topo.intra_cluster_operator(sizes)
    n = V.shape[0]
    np.testing.assert_allclose(V @ V, V, atol=1e-10)
    np.testing.assert_allclose(V @ np.ones(n), np.ones(n), atol=1e-10)
    np.testing.assert_allclose(np.ones(n) @ V, np.ones(n), atol=1e-10)


@given(st.integers(2, 6), st.integers(1, 4), st.integers(1, 12))
@settings(max_examples=20, deadline=None)
def test_inter_operator_preserves_mean(m, dpc, pi):
    """1/n is a right eigenvector of B^T diag(c) H^pi B (paper eq. 12)."""
    sizes = [dpc] * m
    H = topo.mixing_matrix(topo.ring(m))
    W = topo.inter_cluster_operator(sizes, H, pi)
    n = m * dpc
    np.testing.assert_allclose(W @ np.ones(n), np.ones(n), atol=1e-9)
    np.testing.assert_allclose(np.ones(n) @ W, np.ones(n), atol=1e-9)


def test_gossip_converges_to_average():
    """H^pi -> 11^T/m as pi grows (Assumption 4 consequence)."""
    H = topo.mixing_matrix(topo.ring(8))
    Hp = np.linalg.matrix_power(H, 200)
    np.testing.assert_allclose(Hp, np.ones((8, 8)) / 8, atol=1e-6)


def test_omega_decreasing_in_pi():
    z = topo.zeta(topo.mixing_matrix(topo.ring(8)))
    o1 = [topo.omega1(z, pi) for pi in (1, 5, 10)]
    o2 = [topo.omega2(z, pi) for pi in (1, 5, 10)]
    assert o1[0] > o1[1] > o1[2]
    assert o2[0] > o2[1] > o2[2]
