"""Examples stay runnable (ISSUE 5 satellite): every ``examples/*.py``
imports cleanly against the current engine API, and each one dry-runs at
smoke scale — the two heavyweight drivers (LM trainer, serve path) in the
slow lane, the three simulator studies in the fast lane."""
import importlib
import os
import sys

import pytest

EXAMPLES = os.path.abspath(
    os.path.join(os.path.dirname(__file__), "..", "examples"))
MODULES = ("compressed_federated", "quickstart", "serve_batched",
           "topology_study", "train_lm_federated")


def _load(name):
    if EXAMPLES not in sys.path:
        sys.path.insert(0, EXAMPLES)
    return importlib.import_module(name)


@pytest.mark.parametrize("name", MODULES)
def test_example_imports(name):
    """Import must not execute the driver (main guarded) and must
    resolve every repro symbol the example uses."""
    mod = _load(name)
    assert hasattr(mod, "main")


def test_quickstart_dry_run(capsys):
    _load("quickstart").main(rounds=1, target=0.2)
    out = capsys.readouterr().out
    assert "CFEL quickstart" in out and "ce_fedavg" in out


def test_compressed_federated_dry_run(capsys):
    _load("compressed_federated").main(rounds=1)
    out = capsys.readouterr().out
    assert "topk 5%" in out and "local DP" in out


def test_topology_study_dry_run(capsys):
    _load("topology_study").main(rounds=1)
    out = capsys.readouterr().out
    assert "ring" in out and "complete" in out


@pytest.mark.slow
def test_train_lm_federated_smoke(capsys):
    _load("train_lm_federated").main(["--smoke"])
    out = capsys.readouterr().out
    assert "done" in out


@pytest.mark.slow
def test_serve_batched_smoke(capsys):
    _load("serve_batched").main(archs=("qwen2-0.5b",))
    out = capsys.readouterr().out
    assert "serving qwen2-0.5b" in out
