"""Optimizers, LR schedules, checkpointing."""

import os

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np
import pytest

from repro.checkpoint import (CheckpointStructureError, load_checkpoint,
                              save_checkpoint)
from repro.config import TrainConfig
from repro.optim import adamw, make_lr_schedule, make_optimizer, sgd
from repro.optim.optimizers import apply_updates


def _quad_losses(opt_init, opt_update, lr, steps=200):
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt_init(params)
    losses = []
    for _ in range(steps):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        upd, state = opt_update(g, state, params, lr)
        params = apply_updates(params, upd)
        losses.append(float(jnp.sum(params["w"] ** 2)))
    return losses


@pytest.mark.parametrize("maker,lr", [
    (lambda: sgd(0.9), 0.05), (lambda: sgd(0.0), 0.1),
    (lambda: adamw(), 0.1), (lambda: sgd(0.9, weight_decay=0.01), 0.05),
])
def test_optimizers_minimize_quadratic(maker, lr):
    init, update = maker()
    losses = _quad_losses(init, update, lr)
    assert losses[-1] < 1e-3 * losses[0]


def test_momentum_buffers_match_params_structure():
    init, _ = sgd(0.9)
    params = {"a": jnp.ones((3,)), "b": {"c": jnp.ones((2, 2))}}
    state = init(params)
    assert jax.tree.structure(state["mu"]) == jax.tree.structure(params)


def test_make_optimizer_dispatch():
    for name in ("sgd", "adamw"):
        init, update = make_optimizer(TrainConfig(optimizer=name))
        assert callable(init) and callable(update)


def test_lr_schedules():
    cfg = TrainConfig(lr_schedule="warmup_cosine", warmup_steps=10,
                      total_steps=100, learning_rate=1.0)
    sched = make_lr_schedule(cfg)
    assert float(sched(jnp.asarray(0))) < 0.2
    assert float(sched(jnp.asarray(10))) == pytest.approx(1.0, abs=0.01)
    assert float(sched(jnp.asarray(100))) < 0.01
    const = make_lr_schedule(TrainConfig(lr_schedule="constant",
                                         learning_rate=0.3))
    assert float(const(jnp.asarray(7))) == pytest.approx(0.3)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"layer": {"w": np.random.randn(4, 3).astype(np.float32),
                      "b": np.zeros(3, np.float32)},
            "step": np.asarray(7)}
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, tree, {"arch": "test"})
    loaded, meta = load_checkpoint(path, like=tree)
    assert meta["arch"] == "test"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_preserves_extension_dtypes(tmp_path):
    """bf16 (and friends) must round-trip as themselves, not the opaque
    void records a bare np.save/np.load produces; exact integer dtypes
    must survive too (regression: a step counter silently upcast to
    float corrupts resume arithmetic)."""
    tree = {"w_bf16": np.arange(12, dtype=ml_dtypes.bfloat16).reshape(3, 4),
            "w_f8": np.ones(5, dtype=ml_dtypes.float8_e4m3fn),
            "step": np.asarray(7, np.int32),
            "mask": np.array([1, 0, 1], np.int64)}
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, tree)
    loaded, _ = load_checkpoint(path, like=tree)
    for k, v in tree.items():
        assert loaded[k].dtype == v.dtype, k
        np.testing.assert_array_equal(
            np.asarray(loaded[k]).view(f"u{v.dtype.itemsize}"),
            np.asarray(v).view(f"u{v.dtype.itemsize}"))


def test_checkpoint_structure_error_names_keys(tmp_path):
    """A drifted tree raises CheckpointStructureError naming exactly the
    missing and unexpected paths (the former bare assert said nothing
    and vanished under python -O)."""
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, {"a": np.ones(3), "opt": {"mu": np.zeros(2)}})
    like = {"a": np.ones(3), "opt": {"nu": np.zeros(2)}}
    with pytest.raises(CheckpointStructureError) as ei:
        load_checkpoint(path, like=like)
    assert ei.value.missing == ("/opt/nu",)
    assert ei.value.extra == ("/opt/mu",)
    assert "/opt/nu" in str(ei.value) and "/opt/mu" in str(ei.value)
    assert isinstance(ei.value, ValueError)  # back-compat catch sites


def test_checkpoint_save_is_atomic(tmp_path, monkeypatch):
    """A save that dies mid-write must leave the previous checkpoint
    intact and no temp litter: the archive is written to a temp file
    and os.replace'd into place."""
    path = str(tmp_path / "ckpt.npz")
    old = {"w": np.full(4, 1.0, np.float32)}
    save_checkpoint(path, old, {"gen": 0})
    before = os.listdir(tmp_path)

    real_savez = np.savez

    def dying_savez(f, **kw):
        real_savez(f, **kw)   # bytes hit the temp file...
        raise OSError("disk died mid-save")

    monkeypatch.setattr(np, "savez", dying_savez)
    with pytest.raises(OSError, match="disk died"):
        save_checkpoint(path, {"w": np.full(4, 2.0, np.float32)},
                        {"gen": 1})
    monkeypatch.undo()
    assert sorted(os.listdir(tmp_path)) == sorted(before)  # no litter
    loaded, meta = load_checkpoint(path, like=old)
    assert meta["gen"] == 0
    np.testing.assert_array_equal(loaded["w"], old["w"])
