"""Optimizers, LR schedules, checkpointing."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.config import TrainConfig
from repro.optim import adamw, make_lr_schedule, make_optimizer, sgd
from repro.optim.optimizers import apply_updates


def _quad_losses(opt_init, opt_update, lr, steps=200):
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt_init(params)
    losses = []
    for _ in range(steps):
        g = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        upd, state = opt_update(g, state, params, lr)
        params = apply_updates(params, upd)
        losses.append(float(jnp.sum(params["w"] ** 2)))
    return losses


@pytest.mark.parametrize("maker,lr", [
    (lambda: sgd(0.9), 0.05), (lambda: sgd(0.0), 0.1),
    (lambda: adamw(), 0.1), (lambda: sgd(0.9, weight_decay=0.01), 0.05),
])
def test_optimizers_minimize_quadratic(maker, lr):
    init, update = maker()
    losses = _quad_losses(init, update, lr)
    assert losses[-1] < 1e-3 * losses[0]


def test_momentum_buffers_match_params_structure():
    init, _ = sgd(0.9)
    params = {"a": jnp.ones((3,)), "b": {"c": jnp.ones((2, 2))}}
    state = init(params)
    assert jax.tree.structure(state["mu"]) == jax.tree.structure(params)


def test_make_optimizer_dispatch():
    for name in ("sgd", "adamw"):
        init, update = make_optimizer(TrainConfig(optimizer=name))
        assert callable(init) and callable(update)


def test_lr_schedules():
    cfg = TrainConfig(lr_schedule="warmup_cosine", warmup_steps=10,
                      total_steps=100, learning_rate=1.0)
    sched = make_lr_schedule(cfg)
    assert float(sched(jnp.asarray(0))) < 0.2
    assert float(sched(jnp.asarray(10))) == pytest.approx(1.0, abs=0.01)
    assert float(sched(jnp.asarray(100))) < 0.01
    const = make_lr_schedule(TrainConfig(lr_schedule="constant",
                                         learning_rate=0.3))
    assert float(const(jnp.asarray(7))) == pytest.approx(0.3)


def test_checkpoint_roundtrip(tmp_path):
    tree = {"layer": {"w": np.random.randn(4, 3).astype(np.float32),
                      "b": np.zeros(3, np.float32)},
            "step": np.asarray(7)}
    path = str(tmp_path / "ckpt.npz")
    save_checkpoint(path, tree, {"arch": "test"})
    loaded, meta = load_checkpoint(path, like=tree)
    assert meta["arch"] == "test"
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(loaded)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
