"""Sharded ModelBank engine (ISSUE 4): device-parallel flat-bank CE-FedAvg.

These tests run IN-PROCESS on a multi-device host: they are marked
``multidevice`` and skip themselves unless jax sees >= 8 devices. The CI
``multidevice`` lane (and the slow subprocess wrapper in
``test_sharded.py``, which keeps tier-1 coverage on single-device hosts)
runs them under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``,
so bank-shard parity is checked on every PR without subprocess latency.

Covered: trajectory parity vs the single-device ModelBank engine (static
schedule, lognormal+mobility+sampling scenario, compression with error
feedback, every baseline algorithm, multi-pod meshes), the traffic
contract (the gossip boundary lowers to neighbor ``collective-permute``s,
never an all-gather of the bank), and the memory contract (per-device
state is the (1, T) row shard; round buffers are donated).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FLConfig, ScenarioConfig
from repro.core.cefedavg import FLSimulator
from repro.core.compress import CompressionConfig
from repro.core.sharded import ShardedBankCEFedAvg
from repro.data.federated import (build_fl_data, dirichlet_partition,
                                  make_synthetic_classification)
from repro.launch.mesh import make_replica_mesh
from repro.models.cnn import apply_mlp_classifier, init_mlp_classifier

NDEV = 8

pytestmark = [
    pytest.mark.multidevice,
    pytest.mark.skipif(
        jax.device_count() < NDEV,
        reason=f"needs {NDEV} devices; run under XLA_FLAGS="
               f"--xla_force_host_platform_device_count={NDEV} "
               f"(the CI multidevice lane does)"),
]

_FL = FLConfig(algorithm="ce_fedavg", num_clusters=4,
               devices_per_cluster=2, tau=2, q=2, pi=4, topology="ring")
ATOL = 2e-4


def _data(fl, seed=3):
    x, y = make_synthetic_classification(800, 16, 4, seed=seed)
    tx, ty = make_synthetic_classification(200, 16, 4, seed=seed + 1)
    parts = dirichlet_partition(y, fl.n, alpha=0.5, seed=5)
    d = build_fl_data(x, y, parts, tx, ty, samples_per_device=64)
    return {k: jnp.asarray(v) for k, v in d.items()}


def _pair(fl, mesh, **kw):
    """(single-device ModelBank sim, sharded-bank sim) — same seeds."""
    kw.setdefault("lr", 0.1)
    kw.setdefault("batch_size", 16)
    kw.setdefault("seed", 0)
    init = lambda k: init_mlp_classifier(k, 16, 32, 4)   # noqa: E731
    ref = FLSimulator(init, apply_mlp_classifier, fl, _data(fl), **kw)
    sb = ShardedBankCEFedAvg(init, apply_mlp_classifier, fl, _data(fl),
                             mesh, **kw)
    return ref, sb


def _maxdiff(a, b):
    return float(jnp.max(jnp.abs(a - b)))


@pytest.fixture(scope="module")
def mesh():
    return make_replica_mesh(NDEV)


# ---------------------------------------------------------------------------
# trajectory parity vs the single-device ModelBank engine
# ---------------------------------------------------------------------------

def test_static_trajectory_parity(mesh):
    """3 rounds of the static ce_fedavg schedule: the psum+ppermute
    boundaries reproduce the fused dense W_inter@W_intra pass."""
    ref, sb = _pair(_FL, mesh)
    for _ in range(3):
        ref.step_round()
        sb.step_round()
    assert _maxdiff(ref.bank.params, sb.bank.params) < ATOL
    assert _maxdiff(ref.bank.mom, sb.bank.mom) < ATOL
    acc_r, loss_r = ref.evaluate(128)
    acc_s, loss_s = sb.evaluate(128)
    assert acc_r == pytest.approx(acc_s, abs=1e-6)
    assert loss_r == pytest.approx(loss_s, abs=1e-4)


def test_scenario_trajectory_parity(mesh):
    """Lognormal speeds + mobility + client sampling: identical plans on
    both engines (same scenario seed), and the dense-rotation boundary
    reproduces the masked time-varying operators row for row."""
    # 0.5 of each 2-device cluster: the stratified keyed sampler draws
    # 1 per cluster, so every round has a partial cohort
    sc = ScenarioConfig(name="t", speed_dist="lognormal", speed_spread=0.6,
                        sample_fraction=0.5, move_prob=0.3, seed=7)
    ref, sb = _pair(_FL, mesh, scenario=sc)
    sampled = False
    for _ in range(4):
        p1 = ref.step_round()
        p2 = sb.step_round()
        assert np.array_equal(p1.mask, p2.mask)
        assert np.array_equal(p1.labels, p2.labels)
        sampled |= bool(p1.mask.sum() < _FL.n)
    assert sampled, "scenario never sampled a partial cohort"
    assert _maxdiff(ref.bank.params, sb.bank.params) < ATOL
    assert _maxdiff(ref.bank.mom, sb.bank.mom) < ATOL


def test_compression_error_feedback_parity(mesh):
    """Upload path: top-k compression with EF — the residual bank shard
    threads through the sharded round bit-compatibly."""
    comp = CompressionConfig(kind="topk", topk_frac=0.25,
                             error_feedback=True)
    ref, sb = _pair(_FL, mesh, compression=comp)
    for _ in range(2):
        ref.step_round()
        sb.step_round()
    assert _maxdiff(ref.bank.params, sb.bank.params) < ATOL
    assert _maxdiff(ref.bank.residual, sb.bank.residual) < ATOL


@pytest.mark.parametrize("algo,m,dpc", [
    ("fedavg", 1, 8), ("hier_favg", 4, 2),
    ("local_edge", 4, 2), ("dec_local_sgd", 8, 1)])
def test_baseline_algorithms_parity(mesh, algo, m, dpc):
    """Non-gossip baselines take the general dense-rotation path."""
    fl = FLConfig(algorithm=algo, num_clusters=m, devices_per_cluster=dpc,
                  tau=2, q=2, pi=2)
    ref, sb = _pair(fl, mesh)
    ref.step_round()
    sb.step_round()
    assert _maxdiff(ref.bank.params, sb.bank.params) < ATOL


def test_multipod_trajectory_parity():
    """pod x data mesh: flat replica ids cross the pod boundary."""
    mesh2 = make_replica_mesh(NDEV, pods=2)
    ref, sb = _pair(_FL, mesh2)
    for _ in range(2):
        ref.step_round()
        sb.step_round()
    assert _maxdiff(ref.bank.params, sb.bank.params) < ATOL


# ---------------------------------------------------------------------------
# traffic + memory contracts
# ---------------------------------------------------------------------------

def test_gossip_boundary_is_ppermute_not_allgather(mesh):
    """The static round's inter-cluster boundary must lower to neighbor
    collective-permutes (O(pi*deg*T) bytes); an all-gather would
    materialize the full (n, T) bank on every device."""
    _, sb = _pair(_FL, mesh)
    b = sb.bank
    args = sb._resolve_args(sb._canonical, None, fuse=True)
    hlo = sb._round_flat.lower(
        b.params, b.mom, None, sb.key, args,
        sb._full_mask).compile().as_text()
    assert "collective-permute" in hlo, "gossip boundary lost its ppermutes"
    assert "all-gather" not in hlo, \
        "round all-gathers the bank — sharding is broken"
    assert "all-to-all" not in hlo


def test_row_shards_and_donation(mesh):
    """Each device holds exactly its contiguous (1, T) bank row, and the
    jitted round donates the previous round's buffers (peak per-device
    memory ~1x the resident shards)."""
    _, sb = _pair(_FL, mesh)
    T = sb.bank.layout.total
    for buf in (sb.bank.params, sb.bank.mom):
        shards = buf.addressable_shards
        assert len(shards) == NDEV
        assert all(s.data.shape == (1, T) for s in shards)
        assert all(s.data.nbytes == sb.bank.layout.row_nbytes
                   for s in shards)
    y0, m0 = sb.bank.params, sb.bank.mom
    sb.step_round()
    assert y0.is_deleted() and m0.is_deleted(), \
        "round did not donate the bank shards"
    # state stays row-sharded across rounds (no silent re-layout)
    assert sb.bank.params.sharding == sb._row_sharding


def test_mesh_guards():
    """Row-per-device and no-tensor-parallel preconditions are enforced."""
    mesh = make_replica_mesh(NDEV)
    fl = FLConfig(num_clusters=2, devices_per_cluster=2)  # n=4 != 8
    init = lambda k: init_mlp_classifier(k, 16, 32, 4)    # noqa: E731
    with pytest.raises(AssertionError, match="one bank row per replica"):
        ShardedBankCEFedAvg(init, apply_mlp_classifier, fl, _data(fl),
                            mesh)
    # model axis > 1: rows are not tensor-parallel
    import numpy as _np
    mesh_mp = jax.sharding.Mesh(
        _np.asarray(jax.devices()[:NDEV]).reshape(4, 2),
        ("data", "model"))
    with pytest.raises(AssertionError, match="not tensor-parallel"):
        ShardedBankCEFedAvg(init, apply_mlp_classifier, fl, _data(fl),
                            mesh_mp)


# ---------------------------------------------------------------------------
# RoundProgram lowering parity (ISSUE 5): arbitrary programs, sharded
# ---------------------------------------------------------------------------

def _random_program(seed, n):
    from test_program import random_program
    return random_program(np.random.default_rng(seed), n)


@pytest.mark.parametrize("seed", [0, 1])
def test_program_fuzz_parity_static(mesh, seed):
    """Randomized-schedule fuzz: the sharded lowering (psum + per-π
    ppermute matchings, cluster-mean dedupe at fused boundaries) must
    reproduce the single-device flat lowering on the same program."""
    prog = _random_program(seed, _FL.n)
    ref, sb = _pair(_FL, mesh, schedule=prog)
    for _ in range(2):
        ref.step_round()
        sb.step_round()
    assert _maxdiff(ref.bank.params, sb.bank.params) < ATOL
    assert _maxdiff(ref.bank.mom, sb.bank.mom) < ATOL


def test_program_fuzz_parity_scenario(mesh):
    """Masked/mobility rounds of a random program take the dense-rotation
    path; trajectories still match the single-device engine."""
    prog = _random_program(7, _FL.n)
    sc = ScenarioConfig(name="t", speed_dist="lognormal", speed_spread=0.6,
                        sample_fraction=0.5, move_prob=0.3, seed=5)
    ref, sb = _pair(_FL, mesh, scenario=sc, schedule=prog)
    for _ in range(3):
        p1 = ref.step_round()
        p2 = sb.step_round()
        assert np.array_equal(p1.mask, p2.mask)
    assert _maxdiff(ref.bank.params, sb.bank.params) < ATOL


def test_adaptive_tau_schedule_parity(mesh):
    """The adaptive-τ_k schedule (per-device tau_dev cutoffs threaded as
    a replicated operand into the shard_map body) matches the
    single-device engine under a heterogeneous scenario."""
    sc = ScenarioConfig(name="t", speed_dist="lognormal", speed_spread=0.6,
                        seed=9)
    fl = FLConfig(algorithm="ce_fedavg", num_clusters=4,
                  devices_per_cluster=2, tau=4, q=2, pi=4, topology="ring")
    ref, sb = _pair(fl, mesh, scenario=sc, schedule="adaptive_tau")
    for _ in range(2):
        ref.step_round()
        sb.step_round()
    assert ref.last_program.adaptive
    assert np.array_equal(ref.last_program.tau_dev,
                          sb.last_program.tau_dev)
    assert _maxdiff(ref.bank.params, sb.bank.params) < ATOL


def test_pi_decay_schedule_parity_and_recompile_bound(mesh):
    """π_t decay: the sharded lowering rebuilds its GossipSchedule per
    distinct π (structured path) — exactly two compiled variants."""
    ref, sb = _pair(_FL, mesh, schedule="pi_decay")
    for _ in range(3):
        ref.step_round()
        sb.step_round()
    assert _maxdiff(ref.bank.params, sb.bank.params) < ATOL
    # decay_round=5 default: only the early program compiled so far
    assert len(sb._lowered) == 1


# ---------------------------------------------------------------------------
# GroupRegistry tiers + per-shard init (ISSUE 6)
# ---------------------------------------------------------------------------

_FL3 = FLConfig(algorithm="ce_fedavg", num_clusters=4,
                devices_per_cluster=2, tau=2, q=2, pi=2, topology="ring",
                hierarchy=(2, 2, 2))


def test_depth3_trajectory_parity(mesh):
    """Depth-3 (device→edge→region) TierMix program: the registry-tier
    lowering (per-tier psums + block-diagonal gossip matchings) matches
    the dense single-device engine."""
    ref, sb = _pair(_FL3, mesh)
    for _ in range(3):
        ref.step_round()
        sb.step_round()
    assert _maxdiff(ref.bank.params, sb.bank.params) < ATOL
    assert _maxdiff(ref.bank.mom, sb.bank.mom) < ATOL


def test_depth3_scenario_trajectory_parity(mesh):
    """Masked/mobility depth-3 rounds take the dense-rotation path with
    per-tier masked operators; parity must hold."""
    sc = ScenarioConfig(name="t", speed_dist="lognormal", speed_spread=0.6,
                        sample_fraction=0.5, move_prob=0.3, seed=7)
    ref, sb = _pair(_FL3, mesh, scenario=sc)
    for _ in range(3):
        p1 = ref.step_round()
        p2 = sb.step_round()
        assert np.array_equal(p1.mask, p2.mask)
    assert _maxdiff(ref.bank.params, sb.bank.params) < ATOL


def test_depth3_round_has_no_allgather(mesh):
    """A depth-3 TierMix round must still lower to grouped psums +
    collective-permutes only — the region tier adds a wider psum and its
    own matchings, never an all-gather of the bank."""
    _, sb = _pair(_FL3, mesh)
    assert sb._canonical.ops[-1].level == 2
    b = sb.bank
    args = sb._resolve_args(sb._canonical, None, fuse=True)
    hlo = sb._round_flat.lower(
        b.params, b.mom, None, sb.key, args,
        sb._full_mask).compile().as_text()
    assert "collective-permute" in hlo
    assert "all-gather" not in hlo
    assert "all-to-all" not in hlo


def test_sharded_init_parity_and_no_full_bank(mesh, monkeypatch):
    """Per-shard init (``ModelBank.from_model_sharded``) is bit-identical
    to the old build-then-place path, and the sharded engine never calls
    the full-bank constructor — init never materializes (n, T) on one
    device (each addressable shard is the device's own (1, T) row)."""
    from repro.core.modelbank import ModelBank
    from repro.models.cnn import init_mlp_classifier
    fl = _FL
    one = init_mlp_classifier(jax.random.PRNGKey(0), 16, 32, 4)
    old = ModelBank.from_model(one, fl.n)
    old.place(jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("data", None)))

    def _forbidden(*a, **kw):
        raise AssertionError(
            "sharded init must not build the full bank on one device")
    monkeypatch.setattr(ModelBank, "from_model", _forbidden)
    init = lambda k: init_mlp_classifier(k, 16, 32, 4)   # noqa: E731
    sb = ShardedBankCEFedAvg(init, apply_mlp_classifier, fl, _data(fl),
                             mesh, lr=0.1, batch_size=16, seed=0)
    assert np.array_equal(np.asarray(old.params), np.asarray(sb.bank.params))
    assert np.array_equal(np.asarray(old.mom), np.asarray(sb.bank.mom))
    T = sb.bank.layout.total
    for buf in (sb.bank.params, sb.bank.mom):
        assert all(s.data.shape == (1, T) for s in buf.addressable_shards)
        assert buf.sharding == sb._row_sharding
