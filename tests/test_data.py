"""Federated partitioner invariants (hypothesis property tests)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.data.federated import (build_fl_data, cluster_partition,
                                  dirichlet_partition,
                                  make_synthetic_classification,
                                  shard_by_label)
from repro.data.lm import TokenStream, synthetic_lm_batch


@given(st.integers(2, 16), st.floats(0.1, 10.0), st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_dirichlet_partition_is_a_partition(n_dev, alpha, seed):
    _, y = make_synthetic_classification(500, 4, 7, seed=seed)
    parts = dirichlet_partition(y, n_dev, alpha, seed)
    allidx = np.concatenate(parts)
    assert len(allidx) == len(y)
    assert len(np.unique(allidx)) == len(y)  # disjoint union


def test_dirichlet_alpha_controls_skew():
    _, y = make_synthetic_classification(4000, 4, 10, seed=0)

    def skew(alpha):
        parts = dirichlet_partition(y, 8, alpha, seed=1)
        props = []
        for p in parts:
            c = np.bincount(y[p], minlength=10) / max(len(p), 1)
            props.append(c)
        return np.std(np.stack(props), axis=0).mean()
    assert skew(0.1) > skew(100.0)  # small alpha -> more heterogeneity


@given(st.integers(2, 8), st.integers(1, 4))
@settings(max_examples=15, deadline=None)
def test_cluster_partition_covers_everything(m, dpc):
    _, y = make_synthetic_classification(800, 4, 10, seed=2)
    for iid in (True, False):
        parts = cluster_partition(y, m, dpc, cluster_iid=iid, seed=3)
        assert len(parts) == m * dpc
        allidx = np.concatenate(parts)
        assert len(np.unique(allidx)) == len(y)


def test_cluster_noniid_reduces_labels_per_cluster():
    _, y = make_synthetic_classification(4000, 4, 10, seed=4)
    parts = cluster_partition(y, 8, 2, cluster_iid=False,
                              labels_per_cluster=2, seed=5)
    for c in range(8):
        cl = np.concatenate(parts[2 * c:2 * c + 2])
        labels = np.unique(y[cl])
        assert len(labels) <= 4  # ~C=2 labels (boundary shards add a few)


def test_shard_by_label_pathological():
    _, y = make_synthetic_classification(1000, 4, 10, seed=6)
    parts = shard_by_label(y, 10, shards_per_device=2, seed=7)
    n_labels = [len(np.unique(y[p])) for p in parts]
    assert np.mean(n_labels) <= 4


def test_build_fl_data_stacks_equal_shapes():
    x, y = make_synthetic_classification(300, 6, 4, seed=8)
    parts = dirichlet_partition(y, 6, 0.5, 9)
    data = build_fl_data(x, y, parts, x[:50], y[:50],
                         samples_per_device=32)
    assert data["xs"].shape == (6, 32, 6)
    assert data["ys"].shape == (6, 32)


def test_token_stream_cluster_skew():
    ts = TokenStream(1000, 8, lambda r: r // 2, seed=0)
    b = ts.next_batch((4, 16))
    assert b["tokens"].shape == (8, 4, 16)
    assert b["tokens"].max() < 1000
    # same-cluster replicas share distributional shift; labels = next token
    np.testing.assert_array_equal(b["labels"][:, :, :-1],
                                  b["tokens"][:, :, 1:])


def test_synthetic_lm_batch_labels_shifted():
    b = synthetic_lm_batch((2, 8), 100, seed=1)
    np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
