"""Beyond-paper optimizations: exactness + semantics tests.

- ringweight gossip backend == the paper's dense W_inter operator
- zero-masked head padding == original architecture (bit-level fwd)
- MoE batch-local dispatch == global dispatch (capacity non-binding)
- attn_seq_shard flag is a no-op numerically
"""
import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_model_config
from repro.data.lm import synthetic_lm_batch
from repro.models import model as mdl
from repro.models.moe import apply_moe, init_moe

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def test_head_padding_exact_forward():
    cfg = get_model_config("qwen2.5-14b").reduced(
        num_heads=4, num_kv_heads=2, head_dim=32, d_model=128)
    cfgp = dataclasses.replace(cfg, head_pad_to=8)
    params, _ = mdl.init_model(jax.random.PRNGKey(0), cfg)
    paramsp, _ = mdl.init_model(jax.random.PRNGKey(0), cfgp)
    # graft real-head weights into padded slots (interleaved per kv group)
    rep_o, rep_n = 4 // 2, 8 // 2
    sel = [g * rep_n + r for g in range(2) for r in range(rep_o)]
    pp = jax.tree.map(np.array, paramsp)
    pn = jax.tree.map(np.array, params)
    at = pp["layers"]["attn"]
    at["wq"][:, :, sel, :] = pn["layers"]["attn"]["wq"]
    at["wo"][:, sel] = pn["layers"]["attn"]["wo"]
    if "bq" in at:
        at["bq"][:, sel] = pn["layers"]["attn"]["bq"]
    for k in ("wk", "wv", "bk", "bv"):
        if k in pn["layers"]["attn"]:
            at[k] = pn["layers"]["attn"][k]
    for k in ("mlp", "norm1", "norm2"):
        pp["layers"][k] = pn["layers"][k]
    for k in ("tok_embed", "final_norm", "lm_head"):
        if k in pn:
            pp[k] = pn[k]
    pp = jax.tree.map(jnp.asarray, pp)
    batch = {k: jnp.asarray(v) for k, v in
             synthetic_lm_batch((2, 32), cfg.vocab_size).items()}
    l1, _ = mdl.forward(cfg, params, batch)
    l2, _ = mdl.forward(cfgp, pp, batch)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32), atol=1e-5)


def test_padded_heads_gradients_stay_inert():
    """Padded head weights receive exactly zero gradient."""
    cfg = get_model_config("qwen2.5-14b").reduced(
        num_heads=4, num_kv_heads=2, head_dim=32, d_model=128,
        head_pad_to=8)
    params, _ = mdl.init_model(jax.random.PRNGKey(0), cfg)
    batch = {k: jnp.asarray(v) for k, v in
             synthetic_lm_batch((2, 16), cfg.vocab_size).items()}
    g = jax.grad(lambda p: mdl.lm_loss(cfg, p, batch))(params)
    rep_o, rep_n = 2, 4
    padded = [i for i in range(8) if (i % rep_n) >= rep_o]
    gq = np.asarray(g["layers"]["attn"]["wq"], np.float32)
    go = np.asarray(g["layers"]["attn"]["wo"], np.float32)
    assert np.abs(gq[:, :, padded, :]).max() == 0.0
    assert np.abs(go[:, padded]).max() == 0.0
    real = [i for i in range(8) if (i % rep_n) < rep_o]
    assert np.abs(gq[:, :, real, :]).max() > 0.0


@pytest.mark.parametrize("shared", [False, True])
def test_moe_local_dispatch_matches_global(shared):
    cfg = get_model_config("mixtral-8x7b").reduced(
        num_experts=4, experts_per_token=2, capacity_factor=8.0)
    cfg = dataclasses.replace(cfg, moe_shared_expert=shared)
    cfgl = dataclasses.replace(cfg, moe_local_dispatch=True)
    p, _ = init_moe(jax.random.PRNGKey(0), cfg, cfg.d_model, cfg.d_ff)
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 32, cfg.d_model))
    y1, _ = apply_moe(cfg, p, x)
    y2, _ = apply_moe(cfgl, p, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


def test_moe_capacity_drops_tokens_when_binding():
    cfg = get_model_config("mixtral-8x7b").reduced(
        num_experts=4, experts_per_token=1, capacity_factor=0.1)
    cfgl = dataclasses.replace(cfg, moe_local_dispatch=True)
    p, _ = init_moe(jax.random.PRNGKey(0), cfg, cfg.d_model, cfg.d_ff)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model))
    for c in (cfg, cfgl):
        y, _ = apply_moe(c, p, x)
        # some token outputs must be exactly zero (dropped)
        tok_norms = np.asarray(jnp.linalg.norm(y, axis=-1))
        assert (tok_norms < 1e-7).any()
        assert (tok_norms > 1e-3).any()


def test_attn_seq_shard_numerically_noop():
    """The CP constraint changes layout, never values (1-device host)."""
    cfg = get_model_config("qwen2.5-14b").reduced()
    cfgs = dataclasses.replace(cfg, attn_seq_shard=True)
    params, _ = mdl.init_model(jax.random.PRNGKey(0), cfg)
    batch = {k: jnp.asarray(v) for k, v in
             synthetic_lm_batch((2, 64), cfg.vocab_size).items()}
    l1, _ = mdl.forward(cfg, params, batch)
    l2, _ = mdl.forward(cfgs, params, batch)
    np.testing.assert_allclose(np.asarray(l1, np.float32),
                               np.asarray(l2, np.float32), atol=1e-5)


@pytest.mark.slow
def test_ringweight_equals_dense_operator():
    code = """
import jax, jax.numpy as jnp, numpy as np, dataclasses
from jax.sharding import Mesh
from repro.config import ExperimentConfig, FLConfig
from repro.configs import get_model_config
from repro.core.sharded import ShardedCEFedAvg
from repro.data.lm import synthetic_lm_batch
mesh = Mesh(np.asarray(jax.devices()).reshape(8, 1), ("data", "model"))
cfg = get_model_config("qwen2-0.5b").reduced(
    d_model=128, num_layers=2, d_ff=256, vocab_size=256)
base = ExperimentConfig(model=cfg, fl=FLConfig(
    num_clusters=4, devices_per_cluster=2, tau=1, q=2, pi=3,
    topology="ring"))
res = {}
for impl in ("dense", "ringweight"):
    e = dataclasses.replace(base, fl=dataclasses.replace(
        base.fl, gossip_impl=impl))
    tr = ShardedCEFedAvg(e, mesh)
    batch = {k: jnp.asarray(v) for k, v in synthetic_lm_batch(
        (2, 1, 8, 2, 32), cfg.vocab_size).items()}
    with mesh:
        params, opt = jax.jit(tr.init_fn())(jax.random.PRNGKey(0))
        p2, _, _, _ = jax.jit(tr.make_global_round())(
            params, opt, batch, jnp.zeros((), jnp.int32))
    res[impl] = jax.tree.map(np.asarray, p2)
mx = max(jax.tree.leaves(jax.tree.map(
    lambda a, b: float(np.max(np.abs(a.astype(np.float32) -
                                     b.astype(np.float32)))),
    res["dense"], res["ringweight"])))
print("MAXDIFF", mx)
assert mx < 1e-4, mx
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=1200)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "MAXDIFF" in out.stdout
