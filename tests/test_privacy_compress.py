"""Secure aggregation, DP, and uplink compression (paper §4.1 / §2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.config import FLConfig
from repro.core.compress import (CompressionConfig, compress_tree,
                                 compression_ratio)
from repro.core.privacy import (DPConfig, clip_by_global_norm,
                                gaussian_epsilon, global_norm, mask_update,
                                masked_cluster_sum, privatize_update)
from repro.kernels.quantize import (dequantize_int8_blocked,
                                    quantize_int8_blocked,
                                    quantize_int8_ref)


# ---------------------------------------------------------------------------
# secure aggregation
# ---------------------------------------------------------------------------

def _tree(seed, shape=(7, 3)):
    k = jax.random.PRNGKey(seed)
    return {"w": jax.random.normal(k, shape),
            "b": jax.random.normal(jax.random.fold_in(k, 1), (shape[1],))}


def test_secure_agg_masks_cancel_in_sum():
    cluster = [0, 1, 2, 3]
    trees = [_tree(i) for i in cluster]
    true_sum = jax.tree.map(lambda *ls: sum(ls), *trees)
    sec_sum = masked_cluster_sum(trees, cluster, seed=5, scale=10.0)
    for a, b in zip(jax.tree.leaves(true_sum), jax.tree.leaves(sec_sum)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-3)


def test_secure_agg_individual_updates_are_hidden():
    cluster = [0, 1]
    t = _tree(0)
    masked = mask_update(t, 0, cluster, seed=5, scale=10.0)
    diff = float(jnp.abs(masked["w"] - t["w"]).max())
    assert diff > 1.0  # the mask actually obscures the values


# ---------------------------------------------------------------------------
# differential privacy
# ---------------------------------------------------------------------------

def test_clip_by_global_norm():
    t = _tree(1)
    c = clip_by_global_norm(t, 0.5)
    assert float(global_norm(c)) <= 0.5 + 1e-5
    # short vectors are untouched
    small = jax.tree.map(lambda l: l * 1e-4, t)
    c2 = clip_by_global_norm(small, 0.5)
    for a, b in zip(jax.tree.leaves(small), jax.tree.leaves(c2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-8)


def test_privatize_adds_calibrated_noise():
    dp = DPConfig(clip_norm=1.0, noise_multiplier=1.0)
    t = {"w": jnp.zeros((2000,))}
    noisy = privatize_update(t, dp, jax.random.PRNGKey(0))
    std = float(jnp.std(noisy["w"]))
    assert 0.9 < std < 1.1  # sigma = 1.0


def test_gaussian_epsilon_monotone():
    assert gaussian_epsilon(0.5) > gaussian_epsilon(1.0) > \
        gaussian_epsilon(4.0)
    assert gaussian_epsilon(0.0) == float("inf")


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------

def test_topk_keeps_largest_and_error_feedback_accumulates():
    cfg = CompressionConfig(kind="topk", topk_frac=0.25)
    t = {"w": jnp.asarray([1.0, -8.0, 0.1, 3.0, 0.2, -0.3, 6.0, 0.05])}
    sent, res = compress_tree(cfg, t)
    w = np.asarray(sent["w"])
    assert (w != 0).sum() == 2  # 25% of 8
    assert w[1] == -8.0 and w[6] == 6.0
    # residual holds exactly what was not sent
    np.testing.assert_allclose(np.asarray(res["w"]) + w,
                               np.asarray(t["w"]), atol=1e-6)


def test_int8_roundtrip_accuracy():
    cfg = CompressionConfig(kind="int8", stochastic=False)
    t = {"w": jax.random.normal(jax.random.PRNGKey(2), (4096,))}
    sent, _ = compress_tree(cfg, t)
    err = float(jnp.abs(sent["w"] - t["w"]).max())
    amax = float(jnp.abs(t["w"]).max())
    assert err <= amax / 127.0 + 1e-6


@given(st.integers(1, 4000), st.integers(0, 10))
@settings(max_examples=20, deadline=None)
def test_quantize_kernel_matches_ref(T, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), (T,))
    q1, s1 = quantize_int8_blocked(x, block=256, interpret=True)
    q2, s2 = quantize_int8_ref(x, block=256)
    np.testing.assert_array_equal(np.asarray(q1), np.asarray(q2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=1e-6)
    deq = dequantize_int8_blocked(q1, s1, block=256)
    assert float(jnp.abs(deq - x).max()) <= float(
        jnp.abs(x).max()) / 127.0 + 1e-6


def test_compression_ratio():
    assert compression_ratio(CompressionConfig("none")) == 1.0
    assert compression_ratio(CompressionConfig("int8")) == 0.25
    assert compression_ratio(
        CompressionConfig("topk", topk_frac=0.05)) == pytest.approx(0.1)


# ---------------------------------------------------------------------------
# end-to-end: compressed / privatized CE-FedAvg still learns
# ---------------------------------------------------------------------------

def _sim(compression=None, dp=None):
    from repro.core.cefedavg import FLSimulator
    from repro.data.federated import (build_fl_data, dirichlet_partition,
                                      make_synthetic_classification)
    from repro.models.cnn import apply_mlp_classifier, init_mlp_classifier
    fl = FLConfig(num_clusters=4, devices_per_cluster=2, tau=2, q=2, pi=4,
                  topology="ring")
    x, y = make_synthetic_classification(800, 16, 4, seed=3)
    tx, ty = make_synthetic_classification(400, 16, 4, seed=4)
    parts = dirichlet_partition(y, fl.n, 0.5, 5)
    data = {k: jnp.asarray(v) for k, v in
            build_fl_data(x, y, parts, tx, ty, 64).items()}
    return FLSimulator(lambda k: init_mlp_classifier(k, 16, 32, 4),
                       apply_mlp_classifier, fl, data, lr=0.1,
                       batch_size=16, compression=compression, dp=dp)


def test_exact_equivalence_when_disabled():
    s1 = _sim()
    s2 = _sim(compression=CompressionConfig("none"))
    s1.run(2)
    s2.run(2)
    for a, b in zip(jax.tree.leaves(s1.params), jax.tree.leaves(s2.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_int8_compressed_training_learns():
    s = _sim(compression=CompressionConfig("int8"))
    hist = s.run(6)
    assert hist["acc"][-1] > 0.8, hist["acc"]


def test_topk_with_error_feedback_learns():
    s = _sim(compression=CompressionConfig("topk", topk_frac=0.25))
    hist = s.run(8)
    assert hist["acc"][-1] > 0.7, hist["acc"]


def test_dp_training_runs_and_degrades_gracefully():
    s = _sim(dp=DPConfig(clip_norm=1.0, noise_multiplier=0.3))
    hist = s.run(6)
    assert np.isfinite(hist["loss"][-1])
    assert hist["acc"][-1] > 0.4, hist["acc"]