"""Sharded production trainer: multi-device semantics tests.

These spawn subprocesses with XLA_FLAGS=--xla_force_host_platform_device_count
because device count is fixed at first jax init (and the rest of the suite
must see the single real CPU device).
"""
import os
import subprocess
import sys
import textwrap

import pytest

# every test here spawns a subprocess that jit-compiles full training rounds
# on 8 fake devices — minutes, not seconds
pytestmark = pytest.mark.slow

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run(code: str, ndev: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=1200)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


COMMON = """
import jax, jax.numpy as jnp, numpy as np, dataclasses
from jax.sharding import Mesh
from repro.config import ExperimentConfig, FLConfig, TrainConfig
from repro.configs import get_model_config
from repro.core.sharded import ShardedCEFedAvg
from repro.data.lm import synthetic_lm_batch

def build(impl, mesh, algo="ce_fedavg", m=4, dpc=2, tau=2, q=2, pi=2,
          topology="ring"):
    cfg = get_model_config("qwen2-0.5b").reduced(
        d_model=128, num_layers=2, d_ff=256, vocab_size=256)
    exp = ExperimentConfig(model=cfg,
        fl=FLConfig(algorithm=algo, num_clusters=m, devices_per_cluster=dpc,
                    tau=tau, q=q, pi=pi, topology=topology,
                    gossip_impl=impl),
        train=TrainConfig(learning_rate=0.01))
    tr = ShardedCEFedAvg(exp, mesh)
    R = tr.geo.num_replicas
    batch = {k: jnp.asarray(v) for k, v in synthetic_lm_batch(
        (q, tau, R, 2, 32), cfg.vocab_size).items()}
    return tr, batch

def run_round(tr, batch, mesh):
    with mesh:
        params, opt = jax.jit(tr.init_fn())(jax.random.PRNGKey(0))
        p2, o2, m, s = jax.jit(tr.make_global_round())(
            params, opt, batch, jnp.zeros((), jnp.int32))
    return jax.tree.map(np.asarray, p2), float(m["loss"])
"""


def test_sparse_equals_dense_singlepod():
    out = _run(COMMON + """
mesh = Mesh(np.asarray(jax.devices()).reshape(8, 1), ("data", "model"))
pd, ld = run_round(*build("dense", mesh)[:2], mesh)
ps, ls = run_round(*build("sparse", mesh)[:2], mesh)
mx = max(jax.tree.leaves(jax.tree.map(
    lambda a, b: float(np.max(np.abs(a.astype(np.float32) -
                                     b.astype(np.float32)))), pd, ps)))
print("MAXDIFF", mx)
assert mx < 1e-4, mx
""")
    assert "MAXDIFF" in out


def test_sparse_equals_dense_multipod():
    out = _run(COMMON + """
mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4, 1),
            ("pod", "data", "model"))
pd, _ = run_round(*build("dense", mesh)[:2], mesh)
ps, _ = run_round(*build("sparse", mesh)[:2], mesh)
mx = max(jax.tree.leaves(jax.tree.map(
    lambda a, b: float(np.max(np.abs(a.astype(np.float32) -
                                     b.astype(np.float32)))), pd, ps)))
print("MAXDIFF", mx)
assert mx < 1e-4, mx
""")
    assert "MAXDIFF" in out


def test_sparse_equals_dense_star_multipod():
    """Non-ring backhaul through the full trainer, pods crossed."""
    out = _run(COMMON + """
mesh = Mesh(np.asarray(jax.devices()).reshape(2, 4, 1),
            ("pod", "data", "model"))
pd, _ = run_round(*build("dense", mesh, topology="star")[:2], mesh)
for impl in ("sparse", "ringweight"):
    ps, _ = run_round(*build(impl, mesh, topology="star")[:2], mesh)
    mx = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(np.max(np.abs(a.astype(np.float32) -
                                         b.astype(np.float32)))), pd, ps)))
    print("MAXDIFF", impl, mx)
    assert mx < 1e-4, (impl, mx)
""")
    assert out.count("MAXDIFF") == 2


def test_sharded_matches_simulator():
    """The production trainer reproduces the paper-faithful matrix-form
    simulator exactly (same data, same seeds, SGD no momentum)."""
    out = _run(COMMON + """
from repro.core.cefedavg import make_w_schedule, mix
from repro.models import model as mdl
from repro.optim.optimizers import apply_updates

mesh = Mesh(np.asarray(jax.devices()).reshape(8, 1), ("data", "model"))
cfg = get_model_config("qwen2-0.5b").reduced(
    d_model=64, num_layers=2, d_ff=128, vocab_size=128)
fl = FLConfig(num_clusters=4, devices_per_cluster=2, tau=2, q=2, pi=2,
              topology="ring")
exp = ExperimentConfig(model=cfg, fl=fl,
                       train=TrainConfig(learning_rate=0.02, momentum=0.0))
tr = ShardedCEFedAvg(exp, mesh)
R = 8
batch = {k: jnp.asarray(v) for k, v in synthetic_lm_batch(
    (2, 2, R, 2, 16), cfg.vocab_size).items()}
with mesh:
    params, opt = jax.jit(tr.init_fn())(jax.random.PRNGKey(0))
    p_sh, _, _, _ = jax.jit(tr.make_global_round())(
        params, opt, batch, jnp.zeros((), jnp.int32))
p_sh = jax.tree.map(np.asarray, p_sh)

# reference: literal eq. (10) loop on host
sched = make_w_schedule(fl)
p_ref = jax.tree.map(np.asarray, params)
p_ref = jax.tree.map(jnp.asarray, p_ref)
loss_fn = lambda p, b: mdl.lm_loss(cfg, p, b)
grad_fn = jax.grad(loss_fn)
t = 0
for qi in range(2):
    for ti in range(2):
        mb = {k: v[qi, ti] for k, v in batch.items()}
        grads = jax.vmap(grad_fn)(p_ref, mb)
        p_ref = jax.tree.map(lambda p, g: p - 0.02 * g.astype(p.dtype),
                             p_ref, grads)
    p_ref = mix(sched.W_intra, p_ref)
p_ref = mix(sched.W_inter, p_ref)
mx = max(jax.tree.leaves(jax.tree.map(
    lambda a, b: float(np.max(np.abs(np.asarray(a, np.float32) -
                                     np.asarray(b, np.float32)))),
    p_sh, p_ref)))
print("MAXDIFF", mx)
assert mx < 5e-3, mx
""")
    assert "MAXDIFF" in out


def test_sharded_bank_multidevice_lane():
    """Single-device fallback for the in-process ``multidevice`` tests
    (tests/test_sharded_bank.py): run them exactly as the CI multidevice
    lane does — one pytest subprocess with 8 forced host devices — so
    tier-1 on a single-device host still exercises bank-shard parity."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    test_file = os.path.join(os.path.dirname(__file__),
                             "test_sharded_bank.py")
    out = subprocess.run(
        [sys.executable, "-m", "pytest", "-x", "-q", "-m", "multidevice",
         test_file],
        capture_output=True, text=True, env=env, timeout=1200)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "skipped" not in out.stdout.splitlines()[-1], out.stdout


def test_baseline_algorithms_lower():
    out = _run(COMMON + """
mesh = Mesh(np.asarray(jax.devices()).reshape(8, 1), ("data", "model"))
for algo, m, dpc in [("fedavg", 1, 8), ("hier_favg", 4, 2),
                     ("local_edge", 4, 2), ("dec_local_sgd", 8, 1)]:
    tr, batch = build("dense", mesh, algo=algo, m=m, dpc=dpc)
    _, loss = run_round(tr, batch, mesh)
    assert np.isfinite(loss)
    print(algo, "OK", loss)
""")
    assert out.count("OK") == 4
