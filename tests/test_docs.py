"""Docs health: public-API docstrings, intra-repo markdown links, and the
fenced doctest examples under docs/ (the CI docs lane runs this file)."""
import doctest
import inspect
import os
import re

import pytest

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

# -- every public symbol in the paper-core modules cites its math ------------

DOC_MODULES = ("repro.core.cefedavg", "repro.core.gossip",
               "repro.core.topology", "repro.core.scenario",
               "repro.core.clock", "repro.core.runtime",
               "repro.core.modelbank", "repro.core.program",
               "repro.core.groups", "repro.kernels.gossip_mix",
               "repro.checkpoint.ckpt", "repro.checkpoint.runckpt")


@pytest.mark.parametrize("modname", DOC_MODULES)
def test_public_symbols_have_docstrings(modname):
    mod = __import__(modname, fromlist=["_"])
    assert (mod.__doc__ or "").strip(), f"{modname} has no module docstring"
    missing = []
    for name, obj in vars(mod).items():
        if name.startswith("_"):
            continue
        if not (inspect.isfunction(obj) or inspect.isclass(obj)):
            continue
        if getattr(obj, "__module__", None) != modname:
            continue   # re-exports are documented at their home
        if not (inspect.getdoc(obj) or "").strip():
            missing.append(name)
    assert not missing, f"{modname}: undocumented public symbols {missing}"


# -- intra-repo markdown links resolve ---------------------------------------

def _markdown_files():
    files = [os.path.join(REPO, f)
             for f in ("README.md", "ROADMAP.md", "CHANGES.md")]
    docs = os.path.join(REPO, "docs")
    files += [os.path.join(docs, f) for f in sorted(os.listdir(docs))
              if f.endswith(".md")]
    return files

_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


@pytest.mark.parametrize("md", _markdown_files(),
                         ids=lambda p: os.path.relpath(p, REPO))
def test_markdown_links_resolve(md):
    text = open(md, encoding="utf-8").read()
    bad = []
    for target in _LINK.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        path = target.split("#", 1)[0]
        resolved = os.path.normpath(os.path.join(os.path.dirname(md), path))
        if not os.path.exists(resolved):
            bad.append(target)
    assert not bad, f"{os.path.relpath(md, REPO)}: broken links {bad}"


# -- fenced doctest examples in docs/ actually run ---------------------------

@pytest.mark.parametrize("md", [p for p in _markdown_files()
                                if os.sep + "docs" + os.sep in p],
                         ids=lambda p: os.path.relpath(p, REPO))
def test_docs_doctests_pass(md):
    res = doctest.testfile(md, module_relative=False, verbose=False)
    assert res.failed == 0, \
        f"{os.path.relpath(md, REPO)}: {res.failed} doctest failures"
