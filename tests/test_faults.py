"""Fault injection: keyed FaultModel draws, graceful degradation of every
engine (identity rows for dark clusters, per-component gossip under link
loss, straggler retry ladders), and the row-stochasticity of every mixing
operator under faults (ISSUE 8 acceptance)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FLConfig, FaultConfig, ScenarioConfig
from repro.core import gossip as gsp
from repro.core import topology as topo
from repro.core.cefedavg import FLSimulator
from repro.core.clock import fault_compute_penalty, run_wall_clock
from repro.core.groups import GroupRegistry
from repro.core.runtime import paper_runtime_model
from repro.core.scenario import (FAULTS, FaultModel, ScenarioEngine,
                                 get_faults, make_masked_w)
from repro.data.federated import (build_fl_data, dirichlet_partition,
                                  make_synthetic_classification)
from repro.models.cnn import apply_mlp_classifier, init_mlp_classifier

CHAOS = FaultConfig(outage_prob=0.25, outage_len=2, link_drop_prob=0.2,
                    timeout_factor=1.2, max_retries=2, retry_backoff=1.5,
                    seed=11)


def _fl(**kw):
    base = dict(num_clusters=4, devices_per_cluster=3, tau=2, q=1, pi=2,
                topology="ring")
    base.update(kw)
    return FLConfig(**base)


def _sim(fl, *, scenario=None, seed=0, bank=True, schedule=None):
    x, y = make_synthetic_classification(800, 16, 4, seed=3)
    tx, ty = make_synthetic_classification(400, 16, 4, seed=4)
    parts = dirichlet_partition(y, fl.n, alpha=0.5, seed=5)
    data = build_fl_data(x, y, parts, tx, ty, samples_per_device=64)
    data = {k: jnp.asarray(v) for k, v in data.items()}
    return FLSimulator(
        lambda k: init_mlp_classifier(k, 16, 32, 4),
        apply_mlp_classifier, fl, data, lr=0.1, batch_size=16, seed=seed,
        scenario=scenario, bank=bank, schedule=schedule)


def _stochastic(W, atol=1e-6):
    W = np.asarray(W)
    np.testing.assert_allclose(W.sum(1), 1.0, atol=atol)
    assert (W >= -atol).all()


# ---------------------------------------------------------------------------
# fault_gate: the one degradation primitive
# ---------------------------------------------------------------------------

def test_fault_gate_identity_rows_and_dropped_columns():
    labels = np.repeat(np.arange(4), 3)
    W = np.full((12, 12), 1 / 12.0)
    down = np.array([True, False, False, True])
    G = gsp.fault_gate(W, labels, down)
    _stochastic(G)
    dark = down[labels]
    np.testing.assert_allclose(G[dark], np.eye(12)[dark])   # dark: identity
    assert np.allclose(G[~dark][:, dark], 0.0)              # dark cols gone
    # surviving rows fold the dropped mass onto their diagonal
    assert (np.diag(G)[~dark] > np.diag(W)[~dark]).all()


def test_fault_gate_no_fault_is_bitwise_identity():
    labels = np.repeat(np.arange(3), 2)
    W = topo.mixing_matrix(topo.build_adjacency("ring", 6), "metropolis")
    G = gsp.fault_gate(W, labels, np.zeros(3, bool))
    assert (G == np.float32(W)).all()


def test_fault_gate_all_down_is_identity():
    labels = np.repeat(np.arange(3), 2)
    G = gsp.fault_gate(np.full((6, 6), 1 / 6.0), labels, np.ones(3, bool))
    np.testing.assert_allclose(G, np.eye(6))


def test_tier_operator_fault_gates_row_stochastic():
    """Dense TierMix operators degraded for an outage — the tiered form
    GroupRegistry.faulted_operator wraps — stay row-stochastic with
    identity rows for the dark clusters."""
    fl = _fl()
    hier = topo.Hierarchy.from_config(fl)
    W = hier.tier_operator(1, 2, fl.topology, fl.mixing, fl)
    labels = np.repeat(np.arange(4), 3)
    down = np.array([False, True, False, False])
    G = gsp.fault_gate(W, labels, down)
    _stochastic(G)
    dark = down[labels]
    np.testing.assert_allclose(G[dark], np.eye(fl.n)[dark])


@pytest.mark.multidevice
def test_registry_faulted_operator_row_stochastic():
    if jax.device_count() < 8:
        pytest.skip("needs 8 devices (CI multidevice lane)")
    from repro.launch.mesh import make_replica_mesh
    fl = _fl(num_clusters=4, devices_per_cluster=2)
    reg = GroupRegistry(fl, make_replica_mesh(8))
    down = np.array([False, True, False, False])
    G = reg.faulted_operator(1, 2, down)
    _stochastic(G)
    dark = down[np.repeat(np.arange(4), 2)]
    np.testing.assert_allclose(G[dark], np.eye(fl.n)[dark])
    # nothing down degenerates to the plain operator, bitwise
    assert (reg.faulted_operator(1, 2, np.zeros(4, bool))
            == np.float32(reg.operator(1, 2))).all()


# ---------------------------------------------------------------------------
# FaultModel: keyed draws, stateless outage windows, timeout ladder
# ---------------------------------------------------------------------------

def test_fault_model_draws_are_keyed_and_order_free():
    fl = _fl()
    a = FaultModel(CHAOS, fl)
    b = FaultModel(CHAOS, fl)
    mask = np.ones(fl.n)
    speeds = np.linspace(0.3, 2.0, fl.n)
    labels = np.repeat(np.arange(4), 3)
    # query b out of order and twice — the draws only key on the round
    for r in (5, 1, 5, 3):
        b.realize(r, mask, speeds, labels)
    for r in range(8):
        assert (a.realize(r, mask, speeds, labels).trace()
                == b.realize(r, mask, speeds, labels).trace())


def test_outage_windows_are_stateless_and_span_rounds():
    """cluster_down is a pure function of (config, round): membership
    matches a brute-force replay of the keyed window draws, so resume
    needs no fault state in the checkpoint; multi-round windows occur."""
    fl = _fl(num_clusters=6, devices_per_cluster=1)
    fc = FaultConfig(outage_prob=0.3, outage_len=3, seed=2)
    fm = FaultModel(fc, fl)
    R = 40
    down = np.array([fm.cluster_down(r) for r in range(R)])
    assert down.any() and not down.all()
    # brute-force: window starts at s w.p. outage_prob with keyed
    # length 1..outage_len; dark at t iff some window covers t
    expect = np.zeros((R, 6), bool)
    for c in range(6):
        for s in range(R):
            if fm._rng(s, fm._STREAM_OUTAGE, c).random() < fc.outage_prob:
                length = int(fm._rng(s, fm._STREAM_OUTAGE_LEN, c)
                             .integers(1, fc.outage_len + 1))
                expect[s:s + length, c] = True
    np.testing.assert_array_equal(down, expect)
    streaks = (down[1:] & down[:-1]).any()
    assert streaks, "outage_len=3 never produced a multi-round window"


def test_timeout_ladder_prices_stragglers():
    fl = _fl()
    fc = FaultConfig(timeout_factor=1.2, max_retries=2, retry_backoff=1.5,
                     seed=0)
    fm = FaultModel(fc, fl)
    speeds = np.ones(fl.n)
    speeds[0] = 0.01          # hopeless straggler: exhausts the ladder
    speeds[1] = 0.7           # needs one retry: 1/(1.2*0.7) > 1.5**0
    speeds[2] = 0.9           # survives the first budget: 1/(1.2*0.9) <= 1
    mask = np.ones(fl.n)
    attempts, timed_out, ref = fm.timeouts(mask, speeds)
    assert timed_out[0] and attempts[0] == fc.max_retries + 1
    assert not timed_out[1] and attempts[1] == 1
    assert not timed_out[2] and attempts[2] == 0
    assert not timed_out[3:].any() and (attempts[3:] == 0).all()
    # the exhausted ladder is priced as extra wall-clock
    labels = np.repeat(np.arange(4), 3)
    fp = fm.realize(0, mask, speeds, labels)
    survivors = mask * (~fp.timed_out)
    rt = paper_runtime_model()
    from repro.core import program as prg
    pen = fault_compute_penalty(rt, prg.canonical_program(fl), fc, fp,
                                mask=survivors)
    assert pen > 0.0
    # no aborted attempt -> zero penalty (the fault-free anchor)
    calm = fm.realize(0, mask, np.ones(fl.n), labels)
    assert fault_compute_penalty(rt, prg.canonical_program(fl), fc, calm,
                                 mask=mask) == 0.0


def test_link_loss_partitions_gossip_per_component():
    fl = _fl(num_clusters=4, devices_per_cluster=1, topology="ring")
    fc = FaultConfig(link_drop_prob=0.9, seed=3)
    sc = ScenarioConfig(name="links", faults=fc)
    eng = ScenarioEngine(sc, fl)
    saw_partition = False
    for _ in range(10):
        plan = eng.step()
        if plan.fault is None or plan.H_eff is None:
            continue
        _stochastic(plan.H_eff)
        up = eng.adj & plan.fault.link_up
        comps = topo.connected_components(up)
        assert plan.fault.n_components == comps.max() + 1
        if plan.fault.n_components > 1:
            saw_partition = True
            # no mixing weight across components
            cross = comps[:, None] != comps[None, :]
            assert np.allclose(plan.H_eff[cross], 0.0)
    assert saw_partition, "p=0.9 on a 4-ring never partitioned in 10 rounds"


# ---------------------------------------------------------------------------
# every engine degrades instead of crashing; operators stay row-stochastic
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ("ce_fedavg", "hier_favg", "fedavg",
                                  "local_edge"))
def test_scenario_operators_row_stochastic_under_faults(algo):
    fl = _fl(algorithm=algo)
    eng = ScenarioEngine(ScenarioConfig(name="chaos", faults=CHAOS), fl)
    saw_fault = False
    for _ in range(8):
        plan = eng.step()
        _stochastic(plan.W_intra)
        _stochastic(plan.W_inter)
        if plan.fault is not None and plan.fault.any:
            saw_fault = True
            # dark clusters contribute nothing to the cohort
            assert (plan.mask[plan.fault.cluster_down[plan.labels]]
                    == 0).all()
    assert saw_fault


@pytest.mark.parametrize("mode", ("bank", "legacy", "async"))
def test_engines_survive_fault_sweep(mode):
    fl = _fl()
    sc = ScenarioConfig(name="chaos", speed_dist="lognormal",
                        speed_spread=0.5, faults=CHAOS)
    sim = _sim(fl, scenario=sc, bank=(mode != "legacy"))
    rt = paper_runtime_model()
    labels = np.repeat(np.arange(4), 3)
    saw_fault = False
    for _ in range(6):
        if mode == "async":
            plan = sim.step_round_async(2, rt)
        else:
            plan = sim.step_round()
        fault = plan.fault
        if fault is not None and fault.any:
            saw_fault = True
            # the exact degraded operators the engine multiplied:
            # masked W's built from the (possibly link-degraded) H, then
            # gated for the outage — all row-stochastic
            H = plan.H_eff if plan.H_eff is not None else sim.engine.H
            Wi, We = make_masked_w(fl, plan.labels, plan.mask, H)
            for W in (Wi, We):
                _stochastic(gsp.fault_gate(W, plan.labels,
                                           fault.cluster_down))
    assert saw_fault
    acc, _ = sim.evaluate(256)
    assert np.isfinite(acc)


def test_fault_presets_resolve_and_validate():
    for name in FAULTS:
        fc = get_faults(name)
        fc.validate()
        assert not fc.trivial
    with pytest.raises(ValueError, match="unknown fault preset"):
        get_faults("nope")
    with pytest.raises(AssertionError):
        FaultConfig(outage_prob=1.5).validate()
    # trivial faults don't instantiate a FaultModel
    sc = ScenarioConfig(name="t", faults=FaultConfig())
    assert sc.trivial
    assert ScenarioEngine(sc, _fl()).faults is None


def test_faulted_accuracy_within_bound_of_fault_free():
    """Graceful degradation, quantified: chaos-level faults may slow
    CE-FedAvg down but must not wreck it — final accuracy at matched
    rounds stays within 0.15 of the fault-free run."""
    fl = _fl()
    rt = paper_runtime_model()
    base = ScenarioConfig(name="b", speed_dist="lognormal",
                          speed_spread=0.5)
    clean = _sim(fl, scenario=base, seed=2)
    hc = run_wall_clock(clean, rt, 8, eval_every=8)
    faulted = _sim(fl, scenario=dataclasses.replace(base, faults=CHAOS),
                   seed=2)
    hf = run_wall_clock(faulted, rt, 8, eval_every=8)
    assert hf["acc"][-1] >= hc["acc"][-1] - 0.15, (hc["acc"], hf["acc"])
    # the injected retries/outages can only cost wall-clock, not save it
    assert hf["wall_time"][-1] >= hc["wall_time"][-1] * 0.99


# ---------------------------------------------------------------------------
# pi_feedback: closed-loop gossip depth from observed edge disagreement
# ---------------------------------------------------------------------------

def test_pi_feedback_converges_and_decays_depth():
    fl = _fl(num_clusters=4, devices_per_cluster=3, pi=4)
    sim = _sim(fl, schedule="pi_feedback")
    for _ in range(8):
        sim.step_round()
    acc, _ = sim.evaluate(256)
    assert acc > 0.8
    trace = sim._schedule_fn.pi_trace
    assert trace, "schedule never observed disagreement"
    assert all(1 <= p <= fl.pi for p in trace)
    # the EMA state is live (checkpointed by RunCheckpoint)
    assert np.isfinite(sim._schedule_fn.state["ema"])
