"""Flat ModelBank engine (ISSUE 3): parity vs the legacy pytree engine,
cohort compaction across bucket boundaries, buffer donation / retracing,
FlatLayout caching, and the flat-domain upload transforms."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FLConfig, ScenarioConfig
from repro.core.cefedavg import FLSimulator, mix
from repro.core.compress import (CompressionConfig, compress_flat,
                                 compress_tree)
from repro.core.modelbank import (ModelBank, bucket_for, cohort_buckets,
                                  compact_plan)
from repro.core.privacy import (DPConfig, clip_by_global_norm,
                                privatize_update_flat)
from repro.data.federated import (build_fl_data, dirichlet_partition,
                                  make_synthetic_classification)
from repro.kernels.gossip_mix import (FlatLayout, gossip_mix_rows,
                                      gossip_mix_tree)
from repro.models.cnn import apply_mlp_classifier, init_mlp_classifier


def _sim(fl, *, scenario=None, seed=0, lr=0.1, bank=True, compression=None,
         dp=None):
    x, y = make_synthetic_classification(800, 16, 4, seed=3)
    tx, ty = make_synthetic_classification(400, 16, 4, seed=4)
    parts = dirichlet_partition(y, fl.n, alpha=0.5, seed=5)
    data = build_fl_data(x, y, parts, tx, ty, samples_per_device=64)
    data = {k: jnp.asarray(v) for k, v in data.items()}
    return FLSimulator(
        lambda k: init_mlp_classifier(k, 16, 32, 4),
        apply_mlp_classifier, fl, data, lr=lr, batch_size=16, seed=seed,
        scenario=scenario, compression=compression, dp=dp, bank=bank)


def _params_close(a, b, atol=1e-5):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=atol)


_FL = FLConfig(algorithm="ce_fedavg", num_clusters=4,
               devices_per_cluster=2, tau=2, q=2, pi=4, topology="ring")


# ---------------------------------------------------------------------------
# FlatLayout: roundtrip + the cached concat/split plan
# ---------------------------------------------------------------------------

def _tree(seed=0, n=None):
    k = jax.random.PRNGKey(seed)
    shape = lambda s: ((n,) + s if n else s)          # noqa: E731
    return {"a": jax.random.normal(k, shape((5, 3))),
            "b": jax.random.normal(jax.random.fold_in(k, 1), shape((7,))),
            "c": {"d": jax.random.normal(jax.random.fold_in(k, 2),
                                         shape((2, 2, 2)))}}


def test_flat_layout_roundtrip_one_and_stack():
    t = _tree()
    lay = FlatLayout.for_tree(t)
    assert lay.total == 5 * 3 + 7 + 8
    _params_close(lay.unflatten_one(lay.flatten_one(t)), t, atol=0)
    ts = _tree(n=6)
    lay2 = FlatLayout.for_stacked(ts)
    assert lay2 is lay  # same trailing structure -> same cached plan
    _params_close(lay2.unflatten_stack(lay2.flatten_stack(ts)), ts, atol=0)


def test_flat_layout_cached_per_structure():
    a = FlatLayout.for_tree(_tree(0))
    b = FlatLayout.for_tree(_tree(9))      # same structure, other values
    assert a is b
    c = FlatLayout.for_tree({"x": jnp.zeros((3,))})
    assert c is not a and c.total == 3


def test_flat_layout_segments_match_offsets():
    lay = FlatLayout.for_tree(_tree())
    assert lay.segments == tuple(zip(lay.offsets, lay.sizes))
    assert lay.offsets[0] == 0
    assert lay.offsets[-1] + lay.sizes[-1] == lay.total


# ---------------------------------------------------------------------------
# fused row-apply kernel path
# ---------------------------------------------------------------------------

def test_gossip_mix_tree_matches_mix_for_asymmetric_w():
    """Row-application semantics: must agree with mix() for the
    row-stochastic (asymmetric) masked operators, not just symmetric W."""
    from repro.core import topology as topo
    B = topo.assignment_matrix([0, 0, 0, 1, 2, 2], 3)
    H = topo.mixing_matrix(topo.ring(3))
    W = topo.masked_inter_operator(B, H, 2, np.array([1, 0, 1, 1, 1, 1.0]))
    assert not np.allclose(W, W.T)   # genuinely asymmetric
    params = _tree(seed=1, n=6)
    got = gossip_mix_tree(W, params, interpret=True)
    _params_close(got, mix(W, params), atol=1e-5)


@pytest.mark.parametrize("n,T", [(8, 100), (16, 1 << 18),
                                 (16, (1 << 18) + 37), (4, 3 * (1 << 18))])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_mix_rows_blocked_matches_gemm(n, T, dtype):
    """The in-place CPU streaming pass (tile loop) is exact vs the gemm
    oracle across tile-divisibility edge cases and dtypes."""
    from repro.kernels.gossip_mix import _mix_rows_blocked
    from repro.kernels.ref import gossip_mix_rows_ref
    ks = jax.random.split(jax.random.PRNGKey(8), 2)
    W = jax.random.uniform(ks[0], (n, n))
    W = W / W.sum(1, keepdims=True)
    Y = jax.random.normal(ks[1], (n, T)).astype(dtype)
    got = jax.jit(_mix_rows_blocked)(W, Y)
    exp = gossip_mix_rows_ref(W, Y)
    assert got.dtype == Y.dtype
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(exp, np.float32), atol=tol)


def test_gossip_mix_rows_matches_ref_and_rectangular():
    k = jax.random.PRNGKey(0)
    Y = jax.random.normal(k, (6, 301))
    W = jax.random.uniform(jax.random.fold_in(k, 1), (6, 6))
    W = W / W.sum(1, keepdims=True)
    np.testing.assert_allclose(
        np.asarray(gossip_mix_rows(W, Y, interpret=True)),
        np.asarray(W @ Y), atol=1e-5)
    P = jax.random.uniform(jax.random.fold_in(k, 2), (2, 6))  # edge proj
    np.testing.assert_allclose(
        np.asarray(gossip_mix_rows(P, Y, interpret=True)),
        np.asarray(P @ Y), atol=1e-5)


# ---------------------------------------------------------------------------
# parity: ModelBank engine vs legacy pytree engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ["ce_fedavg", "hier_favg", "fedavg",
                                  "local_edge"])
def test_bank_matches_legacy_full_participation(algo):
    """Acceptance: full-mask equivalence with the legacy engine (static
    schedule) before any benchmark numbers are trusted."""
    fl = dataclasses.replace(_FL, algorithm=algo)
    sb, sl = _sim(fl), _sim(fl, bank=False)
    sb.run(3)
    sl.run(3)
    _params_close(sb.params, sl.params)
    _params_close(sb.mom, sl.mom)
    np.testing.assert_allclose(sb.evaluate(), sl.evaluate(), atol=1e-5)


def test_bank_matches_legacy_under_lognormal_mobility_sampling():
    """Trajectory equivalence under a non-trivial scenario: lognormal
    speeds + mobility + sampling with dropout (compacted cohorts)."""
    # 0.5 of each 2-device cluster: the stratified keyed sampler draws
    # 1 per cluster, so the compacted cohort path engages every round
    sc = ScenarioConfig(speed_dist="lognormal", speed_spread=0.6,
                        sample_fraction=0.5, dropout_prob=0.2,
                        move_prob=0.3, seed=3)
    sb, sl = _sim(_FL, scenario=sc), _sim(_FL, scenario=sc, bank=False)
    buckets = []
    for _ in range(5):
        sb.step_round()
        buckets.append(sb.last_bucket)
        sl.step_round()
    assert min(buckets) < sb.bank.n   # compaction actually engaged
    _params_close(sb.params, sl.params)


def test_bank_compaction_across_bucket_boundaries():
    """Cohort sizes that wander across bucket boundaries round-to-round
    stay correct (each bucket is a separate trace of the same round)."""
    n = _FL.n
    buckets_seen = set()
    sc = ScenarioConfig(sample_fraction=1.0, dropout_prob=0.55, seed=7)
    sb, sl = _sim(_FL, scenario=sc), _sim(_FL, scenario=sc, bank=False)
    for _ in range(8):
        sb.step_round()
        buckets_seen.add(sb.last_bucket)
        sl.step_round()
    assert len(buckets_seen) >= 2, buckets_seen   # crossed a boundary
    assert all(b in cohort_buckets(n) for b in buckets_seen)
    _params_close(sb.params, sl.params)


def test_bank_learns_and_syncs_clusters():
    fl = dataclasses.replace(_FL, tau=1, q=1, pi=2)
    s = _sim(fl)
    s.run(1)
    w = np.asarray(jax.tree.leaves(s.params)[0])
    for c in range(4):
        np.testing.assert_allclose(w[2 * c], w[2 * c + 1], atol=1e-6)


# ---------------------------------------------------------------------------
# donation + retracing + eval jit cache
# ---------------------------------------------------------------------------

def test_round_donates_bank_buffers():
    """donate_argnums on the jitted round: the previous round's buffers
    are invalidated, so peak memory stays ~1x the bank."""
    s = _sim(_FL)
    y0, m0 = s.bank.params, s.bank.mom
    s.step_round()
    assert y0.is_deleted() and m0.is_deleted()


def test_no_per_round_retracing_across_scenario_rounds():
    """jit cache-miss counter: after every bucket has been seen once, more
    scenario rounds add no new traces."""
    sc = ScenarioConfig(sample_fraction=0.6, dropout_prob=0.3,
                        move_prob=0.3, seed=1)
    s = _sim(_FL, scenario=sc)
    n_buckets = len(cohort_buckets(s.bank.n))
    for _ in range(6):
        s.step_round()
    sizes = (s._round_flat._cache_size(), s._round_compact._cache_size())
    assert sizes[0] <= 1 and sizes[1] <= n_buckets
    for _ in range(6):
        s.step_round()
    after = (s._round_flat._cache_size(), s._round_compact._cache_size())
    assert after[0] <= 1 and after[1] <= n_buckets
    # every incremental trace must correspond to a new bucket, never a
    # re-trace of a shape that was already compiled
    assert after[1] - sizes[1] <= n_buckets - sizes[1]


def test_evaluate_traces_once_per_eval_batch_shape():
    s = _sim(_FL)
    s.evaluate(128)
    s.evaluate(128)
    s.evaluate(128)
    assert s._eval_fn._cache_size() == 1
    s.evaluate(256)
    assert s._eval_fn._cache_size() == 2


# ---------------------------------------------------------------------------
# cohort bucket helpers
# ---------------------------------------------------------------------------

def test_cohort_buckets_and_bucket_for():
    assert cohort_buckets(16) == (1, 2, 4, 8, 16)
    assert cohort_buckets(12) == (1, 2, 4, 8, 12)
    assert cohort_buckets(1) == (1,)
    bks = cohort_buckets(12)
    assert bucket_for(1, bks) == 1
    assert bucket_for(5, bks) == 8
    assert bucket_for(12, bks) == 12
    with pytest.raises(ValueError):
        bucket_for(13, bks)


def test_compact_plan_distinct_rows_and_lanes():
    mask = np.array([1, 0, 0, 1, 1, 0, 0, 0.0])
    cp = compact_plan(mask)
    assert cp.k == 3 and cp.k_pad == 4
    assert len(set(cp.idx.tolist())) == cp.k_pad     # scatter-safe
    assert cp.lane.sum() == cp.k
    assert set(cp.idx[cp.lane].tolist()) == {0, 3, 4}
    assert all(mask[i] == 0 for i in cp.idx[~cp.lane])  # inert padding


# ---------------------------------------------------------------------------
# flat-domain upload transforms
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cfg", [
    CompressionConfig("topk", topk_frac=0.3),
    CompressionConfig("topk", topk_frac=0.3, error_feedback=False),
    CompressionConfig("int8", stochastic=False),
    CompressionConfig("int8", stochastic=True),
])
def test_compress_flat_matches_compress_tree(cfg):
    tree = _tree(seed=2)
    lay = FlatLayout.for_tree(tree)
    res_tree = jax.tree.map(lambda l: 0.1 * l, _tree(seed=5))
    key = jax.random.PRNGKey(0)
    sent_t, newres_t = compress_tree(cfg, tree, res_tree, key)
    sent_f, newres_f = compress_flat(cfg, lay.flatten_one(tree),
                                     lay.flatten_one(res_tree), key,
                                     lay.segments)
    _params_close(lay.unflatten_one(sent_f), sent_t, atol=1e-6)
    if cfg.error_feedback:
        _params_close(lay.unflatten_one(newres_f), newres_t, atol=1e-6)


def test_privatize_flat_clips_like_tree():
    tree = _tree(seed=3)
    lay = FlatLayout.for_tree(tree)
    dp = DPConfig(clip_norm=0.5, noise_multiplier=0.0)
    flat = privatize_update_flat(lay.flatten_one(tree), dp,
                                 jax.random.PRNGKey(0))
    _params_close(lay.unflatten_one(flat),
                  clip_by_global_norm(tree, 0.5), atol=1e-6)


def test_privatize_flat_noise_calibration():
    dp = DPConfig(clip_norm=1.0, noise_multiplier=1.0)
    vec = jnp.zeros((4000,))
    noisy = privatize_update_flat(vec, dp, jax.random.PRNGKey(0))
    assert 0.9 < float(jnp.std(noisy)) < 1.1


@pytest.mark.parametrize("cfg", [CompressionConfig("topk", topk_frac=0.25),
                                 CompressionConfig("int8")])
def test_bank_matches_legacy_with_compression(cfg):
    """The flat-domain upload path reproduces the pytree path (same
    per-device / per-leaf key schedule)."""
    sb = _sim(_FL, compression=cfg)
    sl = _sim(_FL, compression=cfg, bank=False)
    sb.run(2)
    sl.run(2)
    _params_close(sb.params, sl.params)
    if cfg.error_feedback:
        _params_close(sb.residual, sl.residual)


def test_bank_dp_training_learns():
    """DP noise is one flat draw (different stream than the per-leaf
    pytree path — same mechanism), so assert convergence, not parity."""
    s = _sim(_FL, dp=DPConfig(clip_norm=1.0, noise_multiplier=0.3))
    hist = s.run(5)
    assert np.isfinite(hist["loss"][-1])
    assert hist["acc"][-1] > 0.4, hist["acc"]


# ---------------------------------------------------------------------------
# bank state API (checkpoint/eval edges)
# ---------------------------------------------------------------------------

def test_bank_state_roundtrip_through_pytree_setters():
    s = _sim(_FL)
    s.run(1)
    p = s.params
    s.params = p          # e.g. checkpoint restore
    _params_close(s.params, p, atol=0)
    gm = s.global_model()
    em = jax.tree.leaves(s.edge_models())[0]
    assert em.shape[0] == s.fl.num_clusters
    assert jax.tree.leaves(gm)[0].shape == em.shape[1:]


def test_modelbank_from_model_broadcasts_shared_init():
    one = init_mlp_classifier(jax.random.PRNGKey(0), 16, 32, 4)
    bank = ModelBank.from_model(one, 6)
    assert bank.params.shape == (6, bank.layout.total)
    _params_close(bank.layout.unflatten_one(bank.params[3]), one, atol=0)
    assert bank.residual is None
    assert float(jnp.abs(bank.mom).max()) == 0.0
