"""Streaming client-state store (ISSUE 9): O(cohort) memory at large n.

Covers the full paging stack: cold-codec round-trip error bounds
(``compress.encode_cold_rows``), the :class:`ClientStore` host store
(lazy momentum, shard partitioning, encoded snapshots), the keyed
determinism of :class:`PopulationEngine` cohort/mobility draws,
streamed-vs-resident trajectory parity at enumerated n=16, bit-identical
kill-and-resume through the cold store (``RunCheckpoint``), and an
n=10⁴ population smoke asserting the resident slab tracks the cohort
bucket — never the population. The sharded variant
(``ShardedStreamedBank``) is parity-checked in the multidevice lane.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import RunCheckpoint
from repro.config import FLConfig, PopulationConfig, ScenarioConfig
from repro.core.cefedavg import FLSimulator
from repro.core.clientstore import (ClientStore, cold_row_nbytes,
                                    resident_slab_nbytes)
from repro.core.compress import decode_cold_rows, encode_cold_rows
from repro.core.scenario import PopulationEngine
from repro.data.federated import (build_fl_data, dirichlet_partition,
                                  make_synthetic_classification)
from repro.kernels.gossip_mix import FlatLayout
from repro.models.cnn import apply_mlp_classifier, init_mlp_classifier

FL = FLConfig(algorithm="ce_fedavg", num_clusters=4,
              devices_per_cluster=4, tau=2, q=2, pi=2, topology="ring")
# enumerated-device scenario exercising every redraw the pager must
# survive: sampling, straggler dropout, and mobility re-association
MOBILE = ScenarioConfig(name="mobile", sample_fraction=0.5,
                        dropout_prob=0.1, move_prob=0.25, seed=7)


def _data(fl=FL):
    x, y = make_synthetic_classification(800, 16, 4, seed=3)
    tx, ty = make_synthetic_classification(400, 16, 4, seed=4)
    parts = dirichlet_partition(y, fl.n, alpha=0.5, seed=5)
    d = build_fl_data(x, y, parts, tx, ty, samples_per_device=64)
    return {k: jnp.asarray(v) for k, v in d.items()}


def _sim(*, scenario, streaming=False, codec="f32", seed=1,
         pipeline=False):
    return FLSimulator(
        lambda k: init_mlp_classifier(k, 16, 32, 4),
        apply_mlp_classifier, FL, _data(), lr=0.1, batch_size=16,
        seed=seed, scenario=scenario, streaming=streaming, codec=codec,
        pipeline=pipeline)


def _pop_sc(n=400, codec="f32", **kw):
    return dataclasses.replace(
        MOBILE, population=PopulationConfig(
            clients_per_cluster=n // FL.num_clusters,
            cohort_per_cluster=3, codec=codec, **kw))


def _layout():
    return FlatLayout.for_tree(
        init_mlp_classifier(jax.random.PRNGKey(0), 16, 32, 4))


def _leaves(tree):
    return [np.asarray(jax.device_get(l)) for l in jax.tree.leaves(tree)]


# -- cold codecs --------------------------------------------------------------

def test_cold_codec_roundtrip_error_bounds():
    layout = _layout()
    rng = np.random.default_rng(0)
    rows = (rng.standard_normal((5, layout.total)) * 3).astype(np.float32)
    # f32 is the lossless default: bit-exact (what makes resume through
    # the cold store bit-identical)
    got = decode_cold_rows(encode_cold_rows(rows, "f32", layout.segments),
                           "f32", layout.segments)
    np.testing.assert_array_equal(got, rows)
    # f16: half-precision rounding, relative error <= 2^-11 per entry
    got = decode_cold_rows(encode_cold_rows(rows, "f16", layout.segments),
                           "f16", layout.segments)
    assert np.max(np.abs(got - rows) / np.maximum(np.abs(rows), 1e-6)) \
        <= 2.0 ** -10
    # int8: per-segment affine, |err| <= scale/2 = max|seg| / 254
    got = decode_cold_rows(encode_cold_rows(rows, "int8", layout.segments),
                           "int8", layout.segments)
    for lo, size in layout.segments:
        seg, seg_got = rows[:, lo:lo + size], got[:, lo:lo + size]
        bound = np.abs(seg).max(axis=1) / 254.0 + 1e-7
        assert (np.abs(seg_got - seg).max(axis=1) <= bound).all()


@pytest.mark.parametrize("codec", ["f32", "f16", "int8"])
def test_store_lazy_momentum_sharding_and_snapshot(codec):
    layout = _layout()
    rng = np.random.default_rng(1)
    init = rng.standard_normal(layout.total).astype(np.float32)
    st = ClientStore(layout, 4, init, codec=codec, num_shards=3)
    # never-sampled momentum is exactly zero — no bytes stored
    assert st.num_stored == 0
    np.testing.assert_array_equal(st.fetch(np.array([7, 123])), 0.0)
    assert st.nbytes == st.cluster_params.nbytes
    ids = np.array([2, 5, 9, 3000])
    rows = rng.standard_normal((4, layout.total)).astype(np.float32)
    st.commit(ids, rows)
    assert st.num_stored == 4
    per = cold_row_nbytes(layout.total, codec, len(layout.segments))
    assert sum(st.shard_nbytes()) == 4 * per
    got = st.fetch(ids)
    if codec == "f32":
        np.testing.assert_array_equal(got, rows)
    # fetch is decode-of-what-was-stored: committing the decoded rows
    # again must reproduce them exactly (idempotent re-quantization)
    st.commit(ids, got)
    np.testing.assert_array_equal(st.fetch(ids), got)
    # encoded snapshot round-trips bit-exactly under every codec
    snap = st.snapshot()
    st2 = ClientStore(layout, 4, init, codec=codec, num_shards=3)
    st2.load(snap)
    np.testing.assert_array_equal(st2.fetch(ids), got)
    np.testing.assert_array_equal(st2.cluster_params, st.cluster_params)


# -- keyed population draws ---------------------------------------------------

def test_population_engine_keyed_determinism():
    sc = _pop_sc(n=500, size_dist="uniform", size_spread=0.5)
    a, b = PopulationEngine(sc, FL), PopulationEngine(sc, FL)
    assert a.population == b.population and a.cohort_cap == b.cohort_cap
    for _ in range(5):
        pa, pb = a.step(), b.step()
        np.testing.assert_array_equal(pa.clients, pb.clients)
        np.testing.assert_array_equal(pa.labels, pb.labels)
        np.testing.assert_array_equal(pa.speeds, pb.speeds)
        assert pa.clients.shape[0] <= a.cohort_cap
        assert np.unique(pa.clients).shape[0] == pa.clients.shape[0]
        assert pa.labels.min() >= 0 and pa.labels.max() < FL.num_clusters
        assert pa.clients.min() >= 0 and pa.clients.max() < a.population


# -- streamed engine ----------------------------------------------------------

def test_streamed_matches_resident_at_n16():
    """Mode A parity: the streamed pager over the enumerated n=16 fleet
    must reproduce the resident bank engine's trajectory (same seeds,
    same sampling/dropout/mobility redraws) to float tolerance."""
    res = _sim(scenario=MOBILE, streaming=False)
    stm = _sim(scenario=MOBILE, streaming=True)
    for _ in range(4):
        res.step_round()
        stm.step_round()
    for a, b in zip(_leaves(res.edge_models()), _leaves(stm.edge_models())):
        np.testing.assert_allclose(a, b, atol=1e-4)
    for a, b in zip(_leaves(res.global_model()),
                    _leaves(stm.global_model())):
        np.testing.assert_allclose(a, b, atol=1e-4)
    acc_r, _ = res.evaluate(128)
    acc_s, _ = stm.evaluate(128)
    assert abs(acc_r - acc_s) <= 0.05


@pytest.mark.parametrize("codec", ["f32", "int8"])
def test_streamed_kill_and_resume_bit_identical(tmp_path, codec):
    """A streamed run killed at round 3 and resumed from RunCheckpoint
    replays rounds 3..6 bit-identically — the cold store snapshots its
    *encoded* rows, so this holds under lossy codecs too."""
    ref = _sim(scenario=_pop_sc(codec=codec), codec=codec)
    for _ in range(6):
        ref.step_round()
    rc = RunCheckpoint(str(tmp_path))
    killed = _sim(scenario=_pop_sc(codec=codec), codec=codec)
    for _ in range(3):
        killed.step_round()
    rc.save(killed, round_idx=3)
    fresh = _sim(scenario=_pop_sc(codec=codec), codec=codec)
    meta = rc.restore(fresh)
    assert meta["round"] == 3 and meta["engine"] == "streamed"
    for _ in range(3, 6):
        fresh.step_round()
    for a, b in zip(_leaves(ref.global_model()),
                    _leaves(fresh.global_model())):
        np.testing.assert_array_equal(a, b)
    sa, sb = ref.store.snapshot(), fresh.store.snapshot()
    for k in sa:
        np.testing.assert_array_equal(sa[k], sb[k])
    np.testing.assert_array_equal(ref._page_labels, fresh._page_labels)


def test_population_smoke_memory_is_o_cohort():
    """n=10⁴ virtual clients: the resident slab stays at the cohort
    bucket and the cold store holds only ever-sampled rows."""
    rounds = 3
    sim = _sim(scenario=_pop_sc(n=10_000))
    plans = [sim.step_round() for _ in range(rounds)]
    cap = max(sim._buckets)
    assert sim.peak_slab_bytes <= resident_slab_nbytes(
        cap, sim._layout.total)
    # never O(n): the full bank would be 10^4 rows
    assert cap < 100
    k_total = sum(p.clients.shape[0] for p in plans)
    assert sim.store.num_stored <= k_total
    full_bank = resident_slab_nbytes(sim.engine.population,
                                     sim._layout.total)
    assert sim.store.nbytes < full_bank / 100
    # paging is priced: the last round reported its d2e row traffic
    assert sim.last_paging is not None
    assert sim.last_paging["bits_per_row"] == sim.store.bits_per_row
    acc, loss = sim.evaluate(128)
    assert np.isfinite(loss) and 0.0 <= acc <= 1.0


# -- arena store fast paths (ISSUE 10 satellites) -----------------------------

def test_snapshot_incremental_dirty_patch_bit_identical():
    """After the first full snapshot, later snapshots re-gather only
    rows dirtied since — and must be bit-identical to a from-scratch
    snapshot of the same logical contents, under every shard split."""
    layout = _layout()
    rng = np.random.default_rng(2)
    init = rng.standard_normal(layout.total).astype(np.float32)
    for shards in (1, 3):
        st = ClientStore(layout, 4, init, codec="int8",
                         num_shards=shards)
        ids = np.array([2, 5, 9, 3000, 17])
        rows = rng.standard_normal((5, layout.total)).astype(np.float32)
        st.commit(ids, rows)
        st.snapshot()                       # full rebuild, clears dirty
        sub = np.array([5, 3000])           # dirty-patch path
        rows2 = rng.standard_normal((2, layout.total)).astype(np.float32)
        st.commit(sub, rows2)
        snap = st.snapshot()
        # oracle: a fresh store committed to the same final state takes
        # the stale full-rebuild path unconditionally
        oracle = ClientStore(layout, 4, init, codec="int8",
                             num_shards=shards)
        oracle.commit(ids, rows)
        oracle.commit(sub, rows2)
        ref = oracle.snapshot()
        for k in ref:
            np.testing.assert_array_equal(snap[k], ref[k])
        # no commits since -> nothing re-gathered, identical arrays
        again = st.snapshot()
        for k in ref:
            np.testing.assert_array_equal(again[k], snap[k])


def test_fetch_warm_cohort_fast_path_parity():
    """The all-hit fetch fast path (no zero-fill, single gather) must
    return the same rows as a mixed warm/cold fetch that routes through
    the memset path — for one shard and several."""
    layout = _layout()
    rng = np.random.default_rng(3)
    init = rng.standard_normal(layout.total).astype(np.float32)
    for shards in (1, 3):
        st = ClientStore(layout, 4, init, codec="f16",
                         num_shards=shards)
        ids = np.arange(0, 60, 4)
        rows = rng.standard_normal(
            (ids.size, layout.total)).astype(np.float32)
        st.commit(ids, rows)
        warm = st.fetch(ids)                        # all-hit fast path
        mixed = st.fetch(np.concatenate([ids, np.array([9991, 9993])]))
        np.testing.assert_array_equal(mixed[:ids.size], warm)
        np.testing.assert_array_equal(mixed[ids.size:], 0.0)
        # the fast path returns freshly decoded rows, not views into
        # the arena: mutating the result must not corrupt the store
        warm[:] = np.nan
        np.testing.assert_array_equal(st.fetch(ids), mixed[:ids.size])


# -- pipelined driver (ISSUE 10 tentpole) -------------------------------------

def test_pipelined_matches_serial_bit_identical_f32():
    """The double-buffered driver — device-side codec, cross-round
    momentum forwarding, one-round-late commits — reuses the serial
    driver's compiled round executable, so at f32 the two trajectories
    are bit-identical: global model, cold store bytes, page labels."""
    ser = _sim(scenario=_pop_sc())
    pip = _sim(scenario=_pop_sc(), pipeline=True)
    for _ in range(6):
        ser.step_round()
        pip.step_round()
    for a, b in zip(_leaves(ser.global_model()),
                    _leaves(pip.global_model())):
        np.testing.assert_array_equal(a, b)
    sa, sb = ser.store.snapshot(), pip.store.snapshot()
    for k in sa:
        np.testing.assert_array_equal(sa[k], sb[k])
    np.testing.assert_array_equal(ser._page_labels, pip._page_labels)
    assert pip._page_seconds > 0.0


def test_pipelined_matches_serial_int8_close():
    """Under the lossy int8 codec the device kernels round-trip through
    the same fixed points as the host codec; tiny divergence can still
    accumulate through requantized momentum, so: close, not equal."""
    ser = _sim(scenario=_pop_sc(codec="int8"), codec="int8")
    pip = _sim(scenario=_pop_sc(codec="int8"), codec="int8",
               pipeline=True)
    for _ in range(5):
        ser.step_round()
        pip.step_round()
    for a, b in zip(_leaves(ser.global_model()),
                    _leaves(pip.global_model())):
        np.testing.assert_allclose(a, b, atol=5e-3)


def test_pipelined_streamed_matches_resident_at_n16():
    """Mode A parity, pipelined: the overlapped pager over the
    enumerated n=16 fleet reproduces the serial streamed driver
    bit-identically (f32) and the resident engine to float tolerance."""
    res = _sim(scenario=MOBILE, streaming=False)
    ser = _sim(scenario=MOBILE, streaming=True)
    pip = _sim(scenario=MOBILE, streaming=True, pipeline=True)
    for _ in range(4):
        res.step_round()
        ser.step_round()
        pip.step_round()
    for a, b in zip(_leaves(ser.global_model()),
                    _leaves(pip.global_model())):
        np.testing.assert_array_equal(a, b)
    for a, b in zip(_leaves(res.global_model()),
                    _leaves(pip.global_model())):
        np.testing.assert_allclose(a, b, atol=1e-4)
    np.testing.assert_array_equal(ser._page_labels, pip._page_labels)


def test_pipelined_kill_and_resume_bit_identical(tmp_path):
    """RunCheckpoint drains the in-flight page-out before capturing, so
    a pipelined run killed at round 3 resumes bit-identically — and
    matches the serial trajectory end to end."""
    ref = _sim(scenario=_pop_sc())
    for _ in range(6):
        ref.step_round()
    rc = RunCheckpoint(str(tmp_path))
    killed = _sim(scenario=_pop_sc(), pipeline=True)
    for _ in range(3):
        killed.step_round()
    rc.save(killed, round_idx=3)
    fresh = _sim(scenario=_pop_sc(), pipeline=True)
    meta = rc.restore(fresh)
    assert meta["round"] == 3 and meta["engine"] == "streamed"
    for _ in range(3, 6):
        fresh.step_round()
    for a, b in zip(_leaves(ref.global_model()),
                    _leaves(fresh.global_model())):
        np.testing.assert_array_equal(a, b)
    sa, sb = ref.store.snapshot(), fresh.store.snapshot()
    for k in sa:
        np.testing.assert_array_equal(sa[k], sb[k])


NDEV = 8


@pytest.mark.multidevice
@pytest.mark.skipif(
    jax.device_count() < NDEV,
    reason=f"needs {NDEV} devices; run under XLA_FLAGS="
           f"--xla_force_host_platform_device_count={NDEV} "
           f"(the CI multidevice lane does)")
def test_sharded_streamed_bank_matches_single_process():
    """ShardedStreamedBank (hot slab row-sharded over an 8-replica
    mesh, one cold shard per bank shard) must match the single-process
    streamed engine's trajectory on the same virtual population."""
    from repro.core.sharded import ShardedStreamedBank
    from repro.launch.mesh import make_replica_mesh
    sc = _pop_sc(n=400)
    ref = _sim(scenario=sc)
    mesh = make_replica_mesh(NDEV)
    shd = ShardedStreamedBank(
        lambda k: init_mlp_classifier(k, 16, 32, 4),
        apply_mlp_classifier, FL, _data(), mesh, lr=0.1, batch_size=16,
        seed=1, scenario=sc)
    assert shd.store.num_shards == NDEV
    for _ in range(3):
        ref.step_round()
        shd.step_round()
    for a, b in zip(_leaves(ref.global_model()),
                    _leaves(shd.global_model())):
        np.testing.assert_allclose(a, b, atol=2e-4)
    # slab buckets stay divisible by the replica count (even row shards)
    assert all(b % NDEV == 0 for b in shd._buckets)
    assert shd.peak_slab_bytes <= resident_slab_nbytes(
        max(shd._buckets), shd._layout.total)


@pytest.mark.multidevice
@pytest.mark.skipif(
    jax.device_count() < NDEV,
    reason=f"needs {NDEV} devices; run under XLA_FLAGS="
           f"--xla_force_host_platform_device_count={NDEV} "
           f"(the CI multidevice lane does)")
def test_sharded_streamed_pipelined_matches_serial():
    """Pipelined ShardedStreamedBank: prefetched cohorts land
    row-sharded via device_put and the codec kernels run per shard —
    the trajectory must stay bit-identical (f32) to the serial sharded
    driver, which shares the same compiled round executable."""
    from repro.core.sharded import ShardedStreamedBank
    from repro.launch.mesh import make_replica_mesh
    sc = _pop_sc(n=400)

    def mk(pipeline):
        return ShardedStreamedBank(
            lambda k: init_mlp_classifier(k, 16, 32, 4),
            apply_mlp_classifier, FL, _data(), make_replica_mesh(NDEV),
            lr=0.1, batch_size=16, seed=1, scenario=sc,
            pipeline=pipeline)

    ser, pip = mk(False), mk(True)
    for _ in range(4):
        ser.step_round()
        pip.step_round()
    for a, b in zip(_leaves(ser.global_model()),
                    _leaves(pip.global_model())):
        np.testing.assert_array_equal(a, b)
    sa, sb = ser.store.snapshot(), pip.store.snapshot()
    for k in sa:
        np.testing.assert_array_equal(sa[k], sb[k])
