import os
import sys

# NOTE: deliberately NOT forcing a multi-device host here — unit/smoke tests
# run on the single real CPU device. Multi-device trainer tests spawn
# subprocesses with XLA_FLAGS set (see test_sharded.py).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
