"""Communicator-group registry (ISSUE 6): tiered collectives + TierMix.

Single-device coverage: the :class:`repro.core.topology.Hierarchy` math
(tier partitions, block-diagonal mixing, dense TierMix operators, exact
depth-2 reduction to the paper's two-tier schedule), the ``TierMix`` IR
op and its IntraMix/InterGossip sugar, depth-3 dense-engine parity
(legacy pytree vs flat ModelBank), per-tier clock pricing, the online
adaptive-τ schedule's estimator loop, and the ``--multihost`` env-var
plumbing. The ``multidevice``-marked tests exercise the
:class:`repro.core.groups.GroupRegistry` proper — member lists, cached
gossip schedules, mean/gossip collectives — on 8 forced host devices
(the CI multidevice lane).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FLConfig, ScenarioConfig
from repro.core import program as prg
from repro.core import topology as topo
from repro.core.cefedavg import FLSimulator, make_w_schedule

NDEV = 8

_FL3 = FLConfig(algorithm="ce_fedavg", num_clusters=4,
                devices_per_cluster=2, tau=2, q=2, pi=2, topology="ring",
                hierarchy=(2, 2, 2))
_FL2 = FLConfig(algorithm="ce_fedavg", num_clusters=4,
                devices_per_cluster=2, tau=2, q=2, pi=4, topology="ring")

multidevice = pytest.mark.multidevice
needs_devices = pytest.mark.skipif(
    jax.device_count() < NDEV,
    reason=f"needs {NDEV} devices; run under XLA_FLAGS="
           f"--xla_force_host_platform_device_count={NDEV}")


# ---------------------------------------------------------------------------
# Hierarchy math (host-side numpy; tier-1)
# ---------------------------------------------------------------------------

def test_hierarchy_tier_table():
    h = topo.Hierarchy((2, 2, 2))
    assert h.depth == 3 and h.n == 8 and h.num_edges == 4
    table = [(lv, h.tier_name(lv), h.num_groups(lv), h.group_size(lv))
             for lv in range(h.depth)]
    assert table == [(0, "device", 4, 2), (1, "edge", 4, 2),
                     (2, "region", 2, 4)]
    # tier 1 gossips pairs of edges under each region; tier 2 the regions
    assert h.num_siblings(1) == 2 and h.num_parents(1) == 2
    assert h.num_siblings(2) == 2 and h.num_parents(2) == 1
    assert list(h.node_of_edge(2)) == [0, 0, 1, 1]


def test_hierarchy_blockdiag_mixing():
    """H_1 at depth 3 is kron(I_parents, H_block): gossip never crosses
    a parent boundary."""
    h = topo.Hierarchy((2, 2, 2))
    H1 = h.mixing(1, "ring")
    blk = topo.mixing_matrix(topo.build_adjacency("ring", 2), "metropolis")
    assert np.allclose(H1, np.kron(np.eye(2), blk))
    # off-diagonal parent blocks are exactly zero
    assert np.allclose(H1[:2, 2:], 0) and np.allclose(H1[2:, :2], 0)


def test_hierarchy_depth2_reduces_to_schedule():
    """Depth 2 (the paper) reproduces make_w_schedule's H, W_intra and
    W_inter exactly — the hierarchy generalizes, never changes, the
    two-tier path."""
    h = topo.Hierarchy.from_config(_FL2)
    sched = make_w_schedule(_FL2)
    assert np.allclose(h.mixing(1, _FL2.topology, _FL2.mixing, _FL2),
                       sched.H)
    assert np.allclose(h.tier_operator(0), sched.W_intra)
    assert np.allclose(
        h.tier_operator(1, _FL2.pi, _FL2.topology, _FL2.mixing, _FL2),
        sched.W_inter)


def test_tier_operators_are_stochastic():
    h = topo.Hierarchy((2, 2, 2))
    for lv, pi in [(0, 1), (1, 3), (2, 2)]:
        W = h.tier_operator(lv, pi)
        assert W.shape == (8, 8)
        assert np.allclose(W.sum(1), 1.0)
        assert (W >= -1e-12).all()


def test_config_hierarchy_validation():
    with pytest.raises(AssertionError):
        FLConfig(algorithm="ce_fedavg", num_clusters=4,
                 devices_per_cluster=2, hierarchy=(2, 3, 2)).validate()
    with pytest.raises(AssertionError, match="ce_fedavg only"):
        FLConfig(algorithm="hier_favg", num_clusters=4,
                 devices_per_cluster=2, hierarchy=(2, 2, 2)).validate()
    assert _FL3.tiers == (2, 2, 2) and _FL3.depth == 3
    assert _FL2.tiers == (4, 2) and _FL2.depth == 2


# ---------------------------------------------------------------------------
# TierMix IR op + sugar (tier-1)
# ---------------------------------------------------------------------------

def test_tiermix_sugar_value_semantics():
    """IntraMix/InterGossip are TierMix(0)/TierMix(1) sugar: equal by
    value, interchangeable as dict keys, isinstance-compatible."""
    assert prg.IntraMix() == prg.TierMix(0, 1)
    assert prg.InterGossip(4) == prg.TierMix(1, 4)
    assert prg.InterGossip(4) != prg.TierMix(1, 3)
    assert hash(prg.IntraMix()) == hash(prg.TierMix(0, 1))
    assert isinstance(prg.InterGossip(2), prg.TierMix)
    assert isinstance(prg.IntraMix(), prg.TierMix)
    assert "InterGossip" in repr(prg.InterGossip(2))


def test_tiermix_validation():
    with pytest.raises(ValueError, match="level must be >= 0"):
        prg.RoundProgram((prg.MaskRenorm(), prg.LocalSteps(1),
                          prg.TierMix(-1, 1))).validate()
    with pytest.raises(ValueError, match="pi must be"):
        prg.RoundProgram((prg.MaskRenorm(), prg.LocalSteps(1),
                          prg.TierMix(2, 0))).validate()


def test_hierarchical_program_shapes():
    """Depth 2 delegation is exactly the old canonical program; depth 3
    appends one TierMix per deeper tier at the outermost boundary."""
    p2 = prg.canonical_program(_FL2)
    assert p2.ops[-1] == prg.InterGossip(_FL2.pi)
    assert sum(isinstance(o, prg.TierMix) and o.level == 0
               for o in p2.ops) == _FL2.q
    p3 = prg.canonical_program(_FL3)
    assert p3.ops[-1] == prg.TierMix(2, _FL3.pi)
    assert p3.ops[-2] == prg.InterGossip(_FL3.pi)
    custom = prg.hierarchical_program(_FL3, qs=(2, 3), pis=(4, 1))
    levels = [o.level for o in custom.ops if isinstance(o, prg.TierMix)]
    assert levels.count(1) == 3 and levels.count(2) == 1
    custom.validate()


def test_resolve_matrices_tier_dispatch():
    """Level>=2 mixes route through tier_of; omitting it raises."""
    prog = prg.canonical_program(_FL3)
    plans = prg.lowering_plan(prog, fuse=True)
    sched = make_w_schedule(_FL3)
    h = topo.Hierarchy.from_config(_FL3)
    W2 = h.tier_operator(2, _FL3.pi, _FL3.topology, _FL3.mixing, _FL3)
    mats = prg.resolve_matrices(
        plans, sched.W_intra, lambda pi: sched.W_inter,
        tier_of=lambda op: W2)
    # last group fuses V, W_inter and the region mix right-to-left
    assert np.allclose(mats[-1], W2 @ sched.W_inter @ sched.W_intra,
                       atol=1e-6)
    with pytest.raises(ValueError, match="tier_of"):
        prg.resolve_matrices(plans, sched.W_intra,
                             lambda pi: sched.W_inter)


# ---------------------------------------------------------------------------
# depth-3 dense engines: legacy pytree vs flat ModelBank (tier-1)
# ---------------------------------------------------------------------------

def _sim_pair(fl, **kw):
    from repro.data.federated import (build_fl_data, dirichlet_partition,
                                      make_synthetic_classification)
    from repro.models.cnn import apply_mlp_classifier, init_mlp_classifier
    x, y = make_synthetic_classification(800, 16, 4, seed=3, noise=2.5)
    tx, ty = make_synthetic_classification(200, 16, 4, seed=4, noise=2.5)
    parts = dirichlet_partition(y, fl.n, alpha=0.3, seed=3)
    data = {k: jnp.asarray(v) for k, v in build_fl_data(
        x, y, parts, tx, ty, samples_per_device=64).items()}
    init = lambda k: init_mlp_classifier(k, 16, 32, 4)   # noqa: E731
    kw.setdefault("lr", 0.1)
    kw.setdefault("batch_size", 16)
    kw.setdefault("seed", 0)
    flat = FLSimulator(init, apply_mlp_classifier, fl, data, bank=True,
                       **kw)
    leg = FLSimulator(init, apply_mlp_classifier, fl, data, bank=False,
                      **kw)
    return flat, leg


def _tree_maxdiff(a, b):
    return max(float(jnp.max(jnp.abs(jnp.asarray(x, jnp.float32)
                                     - jnp.asarray(y, jnp.float32))))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def test_depth3_dense_engine_parity():
    """A depth-3 TierMix round (device→edge→region) runs identically on
    the legacy pytree and flat ModelBank lowerings."""
    flat, leg = _sim_pair(_FL3)
    assert flat.last_program is None
    for _ in range(2):
        flat.step_round()
        leg.step_round()
    assert isinstance(flat.last_program.ops[-1], prg.TierMix)
    assert flat.last_program.ops[-1].level == 2
    assert _tree_maxdiff(flat.params, leg.params) < 2e-4


def test_depth3_scenario_parity():
    """Masked depth-3 operators (mobility re-labels devices; tier-2 node
    labels lift through node_of_edge) stay in parity across engines."""
    sc = ScenarioConfig(name="t", speed_dist="lognormal",
                        speed_spread=0.6, sample_fraction=0.75,
                        move_prob=0.3, seed=7)
    flat, leg = _sim_pair(_FL3, scenario=sc)
    for _ in range(3):
        p1 = flat.step_round()
        p2 = leg.step_round()
        assert np.array_equal(p1.mask, p2.mask)
        assert np.array_equal(p1.labels, p2.labels)
    assert _tree_maxdiff(flat.params, leg.params) < 2e-4


def test_tier_operator_level_guard():
    flat, _ = _sim_pair(_FL2)
    with pytest.raises(ValueError, match="depth"):
        flat._tier_operator(prg.TierMix(2, 1), None, True)


# ---------------------------------------------------------------------------
# per-tier clock pricing (tier-1)
# ---------------------------------------------------------------------------

def test_tier_bandwidth_pricing():
    from repro.core import clock
    from repro.core.runtime import (HardwareProfile, RuntimeModel,
                                    WorkloadProfile)
    hw = HardwareProfile(b_tiers=(5e6,))
    rt = RuntimeModel(hw, WorkloadProfile(1000, 1e6))
    assert hw.tier_bandwidth(1) == hw.b_e2e
    assert hw.tier_bandwidth(2) == 5e6
    assert hw.tier_bandwidth(3) == hw.b_e2e   # no entry -> backhaul
    W = rt.wl.model_bits(hw)
    prog = prg.canonical_program(_FL3)
    t = clock.program_comm_time(rt, "ce_fedavg", prog)
    expect = (_FL3.q * W / hw.b_d2e + _FL3.pi * W / hw.b_e2e
              + _FL3.pi * W / 5e6)
    assert t == pytest.approx(expect)
    # depth 2 still reduces to the closed-form eq. (8) comm term
    t2 = clock.program_comm_time(rt, "ce_fedavg",
                                 prg.canonical_program(_FL2))
    assert t2 == pytest.approx(rt.comm_time("ce_fedavg", _FL2.q, _FL2.pi))


# ---------------------------------------------------------------------------
# online adaptive-τ schedule (tier-1)
# ---------------------------------------------------------------------------

def test_online_estimator_converges_to_oracle():
    fl = FLConfig(algorithm="ce_fedavg", num_clusters=2,
                  devices_per_cluster=2, tau=4, q=2, pi=2)
    oracle = np.array([1.0, 1.0, 0.25, 0.25])
    sched = prg.make_schedule("adaptive_tau_online", fl)
    # round 0: nothing observed yet -> full tau everywhere
    assert np.array_equal(sched(0, None).tau_dev, np.full(4, fl.tau))
    steps = np.full(4, fl.q * fl.tau)
    times = steps / oracle
    for _ in range(5):
        sched.estimator.observe(steps, times)
    assert np.allclose(
        sched.estimator.multipliers, oracle / oracle.mean(), atol=1e-6)
    want = prg.make_schedule("adaptive_tau", fl, speeds=oracle)(1, None)
    assert np.array_equal(sched(1, None).tau_dev, want.tau_dev)


def test_online_estimator_partial_cohorts():
    """Masked devices keep their last estimate; raw-rate EMA keeps
    cross-round partial observations comparable."""
    est = prg.OnlineSpeedEstimator(4, beta=0.5)
    est.observe(np.array([4, 4, 0, 0]), np.array([1.0, 2.0, 0, 0]),
                mask=np.array([1, 1, 0, 0]))
    m1 = est.multipliers.copy()
    assert m1[2] == 1.0 and m1[3] == 1.0       # unseen -> neutral
    est.observe(np.array([0, 0, 4, 4]), np.array([0, 0, 1.0, 4.0]),
                mask=np.array([0, 0, 1, 1]))
    m2 = est.multipliers
    # device 0 is 2x device 1 and 4x device 3, straight from raw rates
    assert m2[0] == pytest.approx(2 * m2[1])
    assert m2[0] == pytest.approx(4 * m2[3])


def test_online_schedule_wall_clock_loop():
    """run_wall_clock feeds realized compute times back into the online
    schedule: after one round the estimator is live and slow clusters
    get shorter τ_k, tracking the oracle adaptive_tau schedule."""
    from repro.core.clock import run_wall_clock
    from repro.core.runtime import compute_bound_runtime_model
    fl = FLConfig(algorithm="ce_fedavg", num_clusters=2,
                  devices_per_cluster=2, tau=4, q=2, pi=2)
    sc = ScenarioConfig(name="t", speed_dist="lognormal",
                        speed_spread=0.8, seed=11)
    flat, _ = _sim_pair(fl, scenario=sc, schedule="adaptive_tau_online")
    est = flat._schedule_fn.estimator
    assert not est.ready
    rt = compute_bound_runtime_model()
    run_wall_clock(flat, rt, 3, eval_every=3, eval_batch=64)
    assert est.ready
    oracle = np.asarray(flat.engine.speed_multipliers, float)
    assert np.allclose(est.multipliers, oracle / oracle.mean(), atol=1e-6)
    want = prg.adaptive_tau_map(fl.tau, flat.labels, np.ones(fl.n),
                                oracle, fl.num_clusters)
    assert np.array_equal(flat.last_program.tau_dev, want)


# ---------------------------------------------------------------------------
# --multihost env-var plumbing (tier-1)
# ---------------------------------------------------------------------------

def test_initialize_multihost_env_plumbing(monkeypatch):
    from repro.launch import mesh as lm
    calls = []
    monkeypatch.setattr(jax.distributed, "initialize",
                        lambda **kw: calls.append(kw))
    monkeypatch.setenv("JAX_COORDINATOR_ADDRESS", "10.0.0.1:1234")
    monkeypatch.setenv("JAX_NUM_PROCESSES", "4")
    monkeypatch.setenv("JAX_PROCESS_ID", "2")
    lm.initialize_multihost()
    assert calls == [{"coordinator_address": "10.0.0.1:1234",
                      "num_processes": 4, "process_id": 2}]
    # explicit arguments win over the environment
    lm.initialize_multihost("10.0.0.9:99", 8, 5)
    assert calls[-1] == {"coordinator_address": "10.0.0.9:99",
                         "num_processes": 8, "process_id": 5}
    # no env, no args: auto-detect (Cloud TPU) — no kwargs passed
    for var in ("JAX_COORDINATOR_ADDRESS", "JAX_NUM_PROCESSES",
                "JAX_PROCESS_ID"):
        monkeypatch.delenv(var)
    lm.initialize_multihost()
    assert calls[-1] == {}


def test_train_cli_multihost_wiring(monkeypatch):
    """--multihost routes the coordinator trio into
    initialize_multihost before any training work."""
    from repro.launch import mesh as lm
    from repro.launch import train
    calls = []
    monkeypatch.setattr(
        lm, "initialize_multihost",
        lambda **kw: calls.append(kw))
    train.main(["--engine", "bank", "--data-parallel", "1", "--rounds",
                "0", "--multihost", "--coordinator", "h:1",
                "--num-processes", "2", "--process-id", "1"])
    assert calls == [{"coordinator_address": "h:1", "num_processes": 2,
                      "process_id": 1}]


def test_make_tier_mesh():
    from repro.launch.mesh import make_tier_mesh
    mesh = make_tier_mesh((2, 2, 2)) if jax.device_count() >= 8 else None
    if mesh is not None:
        from repro.core import collectives as col
        assert col.flat_axis_size(mesh) == 8


# ---------------------------------------------------------------------------
# GroupRegistry proper (multidevice: 8 forced host devices)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def registry():
    from repro.core.groups import get_registry
    from repro.launch.mesh import make_tier_mesh
    return get_registry(_FL3, make_tier_mesh(_FL3.hierarchy))


@multidevice
@needs_devices
def test_registry_members_and_cache(registry):
    from repro.core.groups import get_registry
    assert registry is get_registry(registry.fl, registry.mesh)
    dev = registry.tier("device")
    edge = registry.tier("edge")
    region = registry.tier("region")
    assert dev.members == edge.members == (
        (0, 1), (2, 3), (4, 5), (6, 7))
    assert region.members == ((0, 1, 2, 3), (4, 5, 6, 7))
    assert registry.tier(2) is region
    assert "region" in registry.describe()


@multidevice
@needs_devices
def test_registry_gossip_schedule_matches_mixing(registry):
    """Each tier's edge-colored schedule applies exactly H_ℓ (rounds
    mode), with per-parent matchings that never cross parents."""
    for lvl in (1, 2):
        sched = registry.gossip_schedule(lvl, _FL3.pi)
        assert np.allclose(sched.dense_equivalent(),
                           registry.mixing(lvl), atol=1e-12)
    s1 = registry.gossip_schedule(1, _FL3.pi)
    assert s1 is registry.gossip_schedule(1, _FL3.pi)   # cached
    node = registry.hier.node_size(1)
    for perm in s1.perms:
        for src, dst in perm:
            # gossip at tier 1 stays within the parent region
            assert (src // 4) == (dst // 4)
            assert src // node != dst // node


@multidevice
@needs_devices
def test_registry_mean_matches_dense_operator(registry):
    """registry.mean at each tier equals the dense block-average."""
    from repro.sharding import replica_axes
    from jax.sharding import PartitionSpec as P
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    raxes = replica_axes(registry.mesh)
    spec = P(tuple(raxes) if len(raxes) > 1 else raxes[0], None)
    for lvl in range(3):
        got = registry.mean(x, spec, lvl)
        t = registry.tier(lvl)
        want = np.asarray(x).copy()
        for g in t.members:
            want[list(g)] = want[list(g)].mean(0)
        assert np.allclose(np.asarray(got), want, atol=1e-6)


@multidevice
@needs_devices
def test_registry_gossip_matches_dense_operator(registry):
    """registry.gossip at tier ℓ equals rows mixed by the (n, n)
    TierMix operator (mean ∘ gossip = the full tier_operator)."""
    from repro.sharding import replica_axes
    from jax.sharding import PartitionSpec as P
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.normal(size=(8, 16)), jnp.float32)
    raxes = replica_axes(registry.mesh)
    spec = P(tuple(raxes) if len(raxes) > 1 else raxes[0], None)
    for lvl in (1, 2):
        y = registry.mean(x, spec, lvl)
        y = registry.gossip(y, spec, lvl, _FL3.pi)
        W = registry.operator(lvl, _FL3.pi)
        assert np.allclose(np.asarray(y), W @ np.asarray(x), atol=1e-5)


@multidevice
@needs_devices
def test_registry_rejects_mismatched_mesh():
    from repro.core.groups import GroupRegistry
    from repro.launch.mesh import make_replica_mesh
    fl = FLConfig(algorithm="ce_fedavg", num_clusters=2,
                  devices_per_cluster=2)   # n=4 != 8
    with pytest.raises(AssertionError, match="flat replica axis"):
        GroupRegistry(fl, make_replica_mesh(NDEV))


if __name__ == "__main__":
    os.environ.setdefault("XLA_FLAGS",
                          "--xla_force_host_platform_device_count=8")
    raise SystemExit(pytest.main([__file__, "-x", "-q"]))
