"""End-to-end behaviour tests for the CFEL/CE-FedAvg system."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FLConfig
from repro.core.cefedavg import FLSimulator
from repro.core.runtime import (HardwareProfile, RuntimeModel,
                                WorkloadProfile)
from repro.data.federated import (build_fl_data, cluster_partition,
                                  dirichlet_partition,
                                  make_synthetic_classification)
from repro.models.cnn import (MODEL_REGISTRY, apply_femnist_cnn,
                              apply_mlp_classifier, init_femnist_cnn,
                              init_mlp_classifier, init_vgg11, apply_vgg11)


def _mlp_data(fl, cluster_iid=None, seed=0):
    x, y = make_synthetic_classification(1600, 16, 8, seed=seed)
    tx, ty = make_synthetic_classification(400, 16, 8, seed=seed + 1)
    if cluster_iid is None:
        parts = dirichlet_partition(y, fl.n, 0.5, seed)
    else:
        parts = cluster_partition(y, fl.num_clusters,
                                  fl.devices_per_cluster,
                                  cluster_iid=cluster_iid, seed=seed)
    data = build_fl_data(x, y, parts, tx, ty, samples_per_device=64)
    return {k: jnp.asarray(v) for k, v in data.items()}


def test_paper_models_param_counts():
    """The paper's model sizes: CNN 6,603,710; VGG-11 9,750,922."""
    p = init_femnist_cnn(jax.random.PRNGKey(0))
    n_cnn = sum(x.size for x in jax.tree.leaves(p))
    assert n_cnn == 6_603_710, n_cnn
    p = init_vgg11(jax.random.PRNGKey(0))
    n_vgg = sum(x.size for x in jax.tree.leaves(p))
    assert n_vgg == 9_750_922, n_vgg


def test_femnist_cnn_trains_on_synthetic_images():
    from repro.data.federated import make_synthetic_images
    x, y = make_synthetic_images(256, 28, 1, 62, seed=0)
    x, y = jnp.asarray(x), jnp.asarray(y)
    p = init_femnist_cnn(jax.random.PRNGKey(0))

    @jax.jit
    def step(p):
        def loss(p):
            lg = apply_femnist_cnn(p, x[:64])
            lse = jax.nn.logsumexp(lg, -1)
            pick = jnp.take_along_axis(lg, y[:64, None], -1)[:, 0]
            return jnp.mean(lse - pick)
        l, g = jax.value_and_grad(loss)(p)
        return l, jax.tree.map(lambda a, b: a - 0.05 * b, p, g)
    l0, p = step(p)
    for _ in range(5):
        l1, p = step(p)
    assert float(l1) < float(l0)


def test_cfel_end_to_end_time_to_accuracy():
    """The paper's headline: CE-FedAvg reaches a target accuracy in less
    wall time than FedAvg/Hier-FAvg under the §6.1 network model."""
    target = 0.60
    hw = HardwareProfile()
    # network-bound regime (FEMNIST-CNN-sized payload, paper §6.1)
    wl = WorkloadProfile(model_params=6_603_710, flops_per_step=2e9)
    results = {}
    for algo, m, dpc in [("ce_fedavg", 4, 2), ("hier_favg", 4, 2),
                         ("fedavg", 1, 8)]:
        fl = FLConfig(algorithm=algo, num_clusters=m,
                      devices_per_cluster=dpc, tau=2, q=4, pi=10,
                      topology="ring")
        sim = FLSimulator(lambda k: init_mlp_classifier(k, 16, 32, 8),
                          apply_mlp_classifier, fl, _mlp_data(fl),
                          lr=0.1, batch_size=16)
        rt = RuntimeModel(hw, wl)
        hist = sim.run(10)
        t_round = rt.round_time(algo, 2, 4, 10)
        reach = next((i + 1 for i, a in enumerate(hist["acc"])
                      if a >= target), None)
        results[algo] = (reach, t_round,
                         None if reach is None else reach * t_round)
    ce = results["ce_fedavg"][2]
    assert ce is not None, results
    for other in ("hier_favg", "fedavg"):
        t = results[other][2]
        assert t is None or ce < t, results


def test_cluster_iid_beats_cluster_noniid():
    """Paper Fig. 5 direction: cluster-IID grouping converges faster."""
    fl = FLConfig(algorithm="ce_fedavg", num_clusters=4,
                  devices_per_cluster=2, tau=2, q=2, pi=10, topology="ring")
    accs = {}
    for iid in (True, False):
        sim = FLSimulator(lambda k: init_mlp_classifier(k, 16, 32, 8),
                          apply_mlp_classifier, fl,
                          _mlp_data(fl, cluster_iid=iid), lr=0.1,
                          batch_size=16)
        accs[iid] = sim.run(8)["acc"][-1]
    assert accs[True] >= accs[False] - 0.02, accs


def test_model_registry_complete():
    assert set(MODEL_REGISTRY) == {"femnist_cnn", "vgg11", "mlp"}


def test_configs_registry_and_shapes():
    from repro.config import INPUT_SHAPES
    from repro.configs import ARCHS, applicable_shapes, get_model_config
    assert len(ARCHS) == 10
    fams = {get_model_config(a).family for a in ARCHS}
    assert fams == {"dense", "moe", "ssm", "hybrid", "encdec", "vlm"}
    total = sum(len(applicable_shapes(a)) for a in ARCHS)
    # 10 archs x 4 shapes - 6 long_500k skips (full-attention archs)
    assert total == 34
    assert set(INPUT_SHAPES) == {"train_4k", "prefill_32k", "decode_32k",
                                 "long_500k"}


def test_vgg11_forward_backward_smoke():
    from repro.data.federated import make_synthetic_images
    x, y = make_synthetic_images(32, 32, 3, 10, seed=1)
    x, y = jnp.asarray(x), jnp.asarray(y)
    p = init_vgg11(jax.random.PRNGKey(0))

    def loss(p):
        lg = apply_vgg11(p, x)
        lse = jax.nn.logsumexp(lg, -1)
        pick = jnp.take_along_axis(lg, y[:, None], -1)[:, 0]
        return jnp.mean(lse - pick)
    l, g = jax.jit(jax.value_and_grad(loss))(p)
    assert np.isfinite(float(l))
    gn = sum(float(jnp.sum(jnp.abs(leaf))) for leaf in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
