"""Event clock + wall-clock time-to-accuracy harness (paper §6 accounting)."""
import os
import subprocess
import sys

import pytest

from repro.config import FLConfig, ScenarioConfig
from repro.core.clock import (EventClock, run_wall_clock, summarize,
                              time_to_accuracy)
from repro.core.runtime import (HardwareProfile, RuntimeModel,
                                WorkloadProfile)

REPO = os.path.join(os.path.dirname(__file__), "..")


def _rt(flops_per_step=1e9):
    return RuntimeModel(HardwareProfile(),
                        WorkloadProfile(1_000_000, flops_per_step))


def test_charge_round_is_compute_plus_comm():
    fl = FLConfig(algorithm="ce_fedavg", tau=2, q=4, pi=10)
    rt = _rt()
    clock = EventClock(rt, fl)
    t = clock.charge_round()
    assert t == pytest.approx(rt.compute_time(8) +
                              rt.comm_time("ce_fedavg", 4, 10))
    assert clock.charge_round() == pytest.approx(2 * t)  # accumulates


def test_charge_round_paced_by_slowest_participant():
    fl = FLConfig(algorithm="ce_fedavg", tau=2, q=2, pi=2)
    rt = _rt(flops_per_step=1e12)          # compute-dominant regime
    fast = EventClock(rt, fl).charge_round(speeds=[1e12, 1e12])
    slow = EventClock(rt, fl).charge_round(speeds=[1e12, 1e10])
    assert slow > fast
    # the straggler sets the compute term exactly (max_k rule, eq. 8)
    assert slow - fast == pytest.approx(4 * 1e12 / 1e10 - 4 * 1e12 / 1e12)


def test_dropping_the_straggler_speeds_the_round():
    """Client sampling can shorten rounds: when the slow device sits out,
    the cohort min-speed rises."""
    fl = FLConfig(algorithm="ce_fedavg", tau=2, q=2, pi=2)
    rt = _rt(flops_per_step=1e12)
    with_straggler = EventClock(rt, fl).charge_round(speeds=[1e12, 1e10])
    without = EventClock(rt, fl).charge_round(speeds=[1e12])
    assert without < with_straggler


def test_time_to_accuracy_lookup():
    hist = {"wall_time": [10.0, 20.0, 30.0], "acc": [0.2, 0.6, 0.9],
            "round": [1, 2, 3], "loss": [1, 1, 1], "participants": [4] * 3}
    assert time_to_accuracy(hist, 0.5) == 20.0
    assert time_to_accuracy(hist, 0.95) is None
    assert "never" in summarize(hist, 0.95)
    assert "20" in summarize(hist, 0.5)


def _tiny_sim(scenario=None, algo="ce_fedavg"):
    import jax.numpy as jnp

    from repro.core.cefedavg import FLSimulator
    from repro.data.federated import (build_fl_data, dirichlet_partition,
                                      make_synthetic_classification)
    from repro.models.cnn import apply_mlp_classifier, init_mlp_classifier
    fl = FLConfig(algorithm=algo, num_clusters=2, devices_per_cluster=2,
                  tau=1, q=2, pi=2, topology="ring")
    x, y = make_synthetic_classification(400, 8, 4, seed=0)
    tx, ty = make_synthetic_classification(200, 8, 4, seed=1)
    parts = dirichlet_partition(y, fl.n, 0.5, seed=2)
    data = {k: jnp.asarray(v) for k, v in
            build_fl_data(x, y, parts, tx, ty, 32).items()}
    return FLSimulator(lambda k: init_mlp_classifier(k, 8, 16, 4),
                       apply_mlp_classifier, fl, data, lr=0.1,
                       batch_size=8, scenario=scenario)


def test_run_wall_clock_curves():
    sim = _tiny_sim()
    hist = run_wall_clock(sim, _rt(), 3)
    assert len(hist["wall_time"]) == len(hist["acc"]) == 3
    assert hist["wall_time"] == sorted(hist["wall_time"])  # monotone
    assert hist["participants"] == [4, 4, 4]               # full cohort


def test_run_wall_clock_heterogeneous_scenario_is_slower():
    """Same rounds, same comm — a lognormal fleet's straggler stretches
    the compute term, so heterogeneous wall time > homogeneous."""
    rt = _rt(flops_per_step=1e12)  # compute-dominant so speeds matter
    t_hom = run_wall_clock(_tiny_sim(ScenarioConfig()), rt,
                           3)["wall_time"][-1]
    sc = ScenarioConfig(speed_dist="lognormal", speed_spread=0.8, seed=0)
    t_het = run_wall_clock(_tiny_sim(sc), rt, 3)["wall_time"][-1]
    assert t_het > t_hom


def test_run_wall_clock_counts_participants():
    sc = ScenarioConfig(sample_fraction=0.5, seed=0)
    hist = run_wall_clock(_tiny_sim(sc), _rt(), 3)
    assert all(p == 2 for p in hist["participants"])  # ceil(0.5 * 4)


@pytest.mark.slow
def test_benchmark_reproduces_paper_ordering():
    """Acceptance: CE-FedAvg reaches the target in less simulated wall
    time than FedAvg AND Hier-FAvg in homogeneous, lognormal-heterogeneous
    and heterogeneous+mobility scenarios (benchmarks/time_to_accuracy.py
    asserts this internally; exit 0 == all orderings held)."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + os.pathsep + \
        env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable,
         os.path.join(REPO, "benchmarks", "time_to_accuracy.py"),
         "--quick"],
        capture_output=True, text=True, env=env, timeout=1200)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "OK: CE-FedAvg reaches the target" in out.stdout


# ---------------------------------------------------------------------------
# async bounded-staleness accounting (ISSUE 7)
# ---------------------------------------------------------------------------

def _async_fixture():
    import dataclasses

    import numpy as np

    from repro.core.program import canonical_program
    from repro.core.runtime import compute_bound_runtime_model
    from repro.core.scenario import ScenarioEngine, get_scenario
    fl = FLConfig(algorithm="ce_fedavg", num_clusters=4,
                  devices_per_cluster=2, tau=2, q=3, pi=4,
                  topology="ring")
    return (fl, canonical_program(fl), compute_bound_runtime_model(),
            np, dataclasses, ScenarioEngine, get_scenario)


def _realize(fl, rt, ScenarioEngine, get_scenario, dataclasses, np,
             name, rounds=4):
    """Realize one preset's rounds ONCE: (speeds, mask, labels) per
    round, so barrier and async clocks charge identical scenarios."""
    eng = ScenarioEngine(dataclasses.replace(get_scenario(name), seed=0),
                         fl)
    out = []
    for _ in range(rounds):
        plan = eng.step()
        speeds = np.asarray(eng.speed_multipliers,
                            float) * rt.hw.device_flops
        out.append((speeds, np.asarray(plan.mask, float),
                    np.asarray(plan.labels)))
    return out


def test_async_makespan_never_exceeds_barrier_on_every_preset():
    """Cumulative async wall clock <= cumulative barrier wall clock on
    EVERY named scenario preset, for every small staleness bound: the
    wait rule only ever relaxes barrier edges, never adds one."""
    (fl, prog, rt, np, dataclasses, ScenarioEngine,
     get_scenario) = _async_fixture()
    from repro.core.scenario import SCENARIOS
    for name in sorted(SCENARIOS):
        rows = _realize(fl, rt, ScenarioEngine, get_scenario,
                        dataclasses, np, name)
        for s in (1, 2, 3):
            cb, ca = EventClock(rt, fl), EventClock(rt, fl)
            for speeds, mask, labels in rows:
                cb.charge_program(prog, speeds, mask)
                ca.charge_program_async(prog, speeds, mask, staleness=s,
                                        labels=labels)
            assert ca.now <= cb.now + 1e-6, \
                f"async s={s} {ca.now:.3f} > barrier {cb.now:.3f} " \
                f"on preset {name!r}"


def test_charge_program_async_equals_barrier_at_s0():
    """s=0 is the barrier, EXACTLY (float-equal, not approx) — and it
    clears any staggered carry a previous async round left behind."""
    (fl, prog, rt, np, dataclasses, ScenarioEngine,
     get_scenario) = _async_fixture()
    rows = _realize(fl, rt, ScenarioEngine, get_scenario, dataclasses,
                    np, "lognormal")
    cb, ca = EventClock(rt, fl), EventClock(rt, fl)
    ca.charge_program_async(prog, *rows[0][:2], staleness=2,
                            labels=rows[0][2])   # leaves a carry
    ca.now = cb.now = 0.0
    for speeds, mask, labels in rows:
        tb = cb.charge_program(prog, speeds, mask)
        ta = ca.charge_program_async(prog, speeds, mask, staleness=0,
                                     labels=labels)
        assert ta == tb
    assert ca._async_carry is None


def test_async_compute_intervals_never_overlap():
    """On one cluster's timeline, block intervals are disjoint and
    ordered — within a round and across the carried round boundary."""
    (fl, prog, rt, np, dataclasses, ScenarioEngine,
     get_scenario) = _async_fixture()
    from repro.core.clock import async_program_timeline
    rows = _realize(fl, rt, ScenarioEngine, get_scenario, dataclasses,
                    np, "lognormal", rounds=2)
    carry, prev_end = None, None
    for speeds, mask, labels in rows:
        tl = async_program_timeline(rt, fl, prog, speeds, mask, labels,
                                    staleness=2, carry=carry)
        T, start = tl["T"], tl["start"]
        assert (T >= start - 1e-9).all()              # nonneg duration
        assert (start[:, 1:] >= T[:, :-1] - 1e-9).all()   # in-round order
        if prev_end is not None:                      # across rounds
            assert (start[:, 0] >= prev_end - 1e-9).all()
        carry, prev_end = tl["carry_out"], T[:, -1]
    # event times in the merged stream are the recorded end times
    assert tl["makespan"] == float(T[:, -1].max())
