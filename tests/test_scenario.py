"""Scenario engine: masked/unequal-cluster operators, mobility, sampling,
and parity with the static equal-cluster schedule (ISSUE 2 acceptance)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FLConfig, ScenarioConfig
from repro.core import topology as topo
from repro.core.cefedavg import FLSimulator, make_w_schedule
from repro.core.scenario import (SCENARIOS, ScenarioEngine, get_scenario,
                                 make_masked_w, sample_speed_multipliers)
from repro.data.federated import (build_fl_data, dirichlet_partition,
                                  make_synthetic_classification)
from repro.models.cnn import apply_mlp_classifier, init_mlp_classifier

ALGOS = ("ce_fedavg", "hier_favg", "fedavg", "local_edge")


def _sim(fl, *, scenario=None, seed=0, lr=0.1):
    x, y = make_synthetic_classification(800, 16, 4, seed=3)
    tx, ty = make_synthetic_classification(400, 16, 4, seed=4)
    parts = dirichlet_partition(y, fl.n, alpha=0.5, seed=5)
    data = build_fl_data(x, y, parts, tx, ty, samples_per_device=64)
    data = {k: jnp.asarray(v) for k, v in data.items()}
    return FLSimulator(
        lambda k: init_mlp_classifier(k, 16, 32, 4),
        apply_mlp_classifier, fl, data, lr=lr, batch_size=16, seed=seed,
        scenario=scenario)


# ---------------------------------------------------------------------------
# operator parity: full participation + equal contiguous clusters must
# reduce to the static make_w_schedule operators (acceptance criterion)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", ALGOS)
def test_masked_w_reduces_to_static_schedule(algo):
    fl = FLConfig(algorithm=algo, num_clusters=4, devices_per_cluster=3,
                  topology="ring", pi=3)
    s = make_w_schedule(fl)
    labels = np.repeat(np.arange(4), 3)
    Wi, We = make_masked_w(fl, labels, np.ones(fl.n), s.H)
    np.testing.assert_allclose(Wi, s.W_intra, atol=1e-12)
    np.testing.assert_allclose(We, s.W_inter, atol=1e-12)


def test_masked_w_reduces_to_static_dec_local_sgd():
    fl = FLConfig(algorithm="dec_local_sgd", num_clusters=6,
                  devices_per_cluster=1, topology="ring", pi=2)
    s = make_w_schedule(fl)
    Wi, We = make_masked_w(fl, np.arange(6), np.ones(6), s.H)
    np.testing.assert_allclose(Wi, np.eye(6), atol=1e-12)
    np.testing.assert_allclose(We, s.W_inter, atol=1e-12)


@pytest.mark.parametrize("algo", ALGOS + ("dec_local_sgd",))
def test_masked_w_row_stochastic_under_mask_and_unequal_clusters(algo):
    if algo == "dec_local_sgd":
        fl = FLConfig(algorithm=algo, num_clusters=6,
                      devices_per_cluster=1, topology="ring", pi=2)
        labels = np.arange(6)
        mask = np.array([1, 0, 1, 1, 0, 1.0])
    else:
        fl = FLConfig(algorithm=algo, num_clusters=3,
                      devices_per_cluster=2, topology="ring", pi=4)
        labels = np.array([0, 0, 0, 1, 2, 2])   # unequal: sizes 3,1,2
        mask = np.array([1, 0, 1, 1, 0, 1.0])
    H = topo.mixing_matrix(topo.build_adjacency(fl.topology,
                                                fl.num_clusters, fl))
    for W in make_masked_w(fl, labels, mask, H):
        np.testing.assert_allclose(W.sum(1), 1.0, atol=1e-9)
        assert (W >= -1e-12).all()


def test_masked_intra_averages_over_participants_only():
    """Cluster {0,1} with device 1 offline: everyone syncs to device 0."""
    B = topo.assignment_matrix([0, 0, 1, 1], 2)
    V = topo.masked_intra_operator(B, np.array([1, 0, 1, 1.0]))
    np.testing.assert_allclose(V[0], [1, 0, 0, 0], atol=1e-12)
    np.testing.assert_allclose(V[1], [1, 0, 0, 0], atol=1e-12)
    np.testing.assert_allclose(V[2], [0, 0, .5, .5], atol=1e-12)


def test_masked_intra_empty_cohort_falls_back_to_member_average():
    """A cluster whose devices all sat out keeps its plain edge average."""
    B = topo.assignment_matrix([0, 0, 1, 1], 2)
    V = topo.masked_intra_operator(B, np.array([0, 0, 1, 1.0]))
    np.testing.assert_allclose(V[0], [.5, .5, 0, 0], atol=1e-12)


def test_renormalize_rows_keeps_offline_devices_fixed():
    H = topo.mixing_matrix(topo.ring(4))
    W = topo.renormalize_rows(np.linalg.matrix_power(H, 3),
                              np.array([1, 0, 1, 1.0]))
    np.testing.assert_allclose(W[1], [0, 1, 0, 0], atol=1e-12)  # offline
    np.testing.assert_allclose(W.sum(1), 1.0, atol=1e-9)
    assert W[0, 1] == 0  # nobody receives from the offline device


def test_unequal_inter_operator_is_stochastic_where_papers_isnt():
    """For unequal clusters B^T diag(c) H^π B (eq. 11 verbatim) loses row
    sums; the generalized B^T H^π P keeps them (docs/SCENARIOS.md)."""
    H = topo.mixing_matrix(topo.ring(3))
    B = topo.assignment_matrix([0, 0, 0, 1, 2, 2], 3)
    sizes = np.array([3, 1, 2])
    paper = B.T @ np.diag(1 / sizes) @ np.linalg.matrix_power(H, 2) @ B
    ours = topo.masked_inter_operator(B, H, 2)
    assert not np.allclose(paper.sum(1), 1.0)
    np.testing.assert_allclose(ours.sum(1), 1.0, atol=1e-9)


# ---------------------------------------------------------------------------
# engine: mobility, sampling, heterogeneity draws
# ---------------------------------------------------------------------------

def test_mobility_keeps_clusters_nonempty_and_moves_devices():
    fl = FLConfig(num_clusters=4, devices_per_cluster=4, topology="ring")
    eng = ScenarioEngine(ScenarioConfig(move_prob=0.5, seed=3), fl)
    moved = False
    prev = eng.labels.copy()
    for _ in range(20):
        plan = eng.step()
        assert (plan.cluster_sizes > 0).all()
        assert plan.cluster_sizes.sum() == fl.n
        moved = moved or (plan.labels != prev).any()
        prev = plan.labels.copy()
    assert moved, "move_prob=0.5 over 20 rounds must move someone"


def test_engine_deterministic_across_instances():
    fl = FLConfig(num_clusters=4, devices_per_cluster=4, topology="ring")
    sc = SCENARIOS["mobile_sampled"]
    a, b = ScenarioEngine(sc, fl), ScenarioEngine(sc, fl)
    np.testing.assert_allclose(a.speed_multipliers, b.speed_multipliers)
    for _ in range(5):
        pa, pb = a.step(), b.step()
        np.testing.assert_array_equal(pa.labels, pb.labels)
        np.testing.assert_array_equal(pa.mask, pb.mask)


def test_engine_keyed_draws_survive_interleaved_rng_use():
    """Regression for the keyed (round, cluster) draw streams: per-round
    randomness must be a pure function of (seed, round_idx, stream,
    cluster). Burning arbitrary extra draws on the engine's instance
    generator between rounds — which the old shared-sequential-stream
    implementation would have consumed from — must not change a single
    plan, so barrier and async drivers (which interleave draws very
    differently) realize identical scenarios."""
    fl = FLConfig(num_clusters=4, devices_per_cluster=4, topology="ring")
    sc = SCENARIOS["mobile_sampled"]
    a, b = ScenarioEngine(sc, fl), ScenarioEngine(sc, fl)
    for r in range(6):
        b.rng.random(17 * (r + 1))            # would desync a shared stream
        pa, pb = a.step(), b.step()
        np.testing.assert_array_equal(pa.labels, pb.labels)
        np.testing.assert_array_equal(pa.mask, pb.mask)
    # round r's draws are replayable from (seed, r) + the B_t state
    # alone — no need to have realized rounds < r on the same generator
    ref = ScenarioEngine(sc, fl)
    for _ in range(3):
        ref.step()                            # rounds 0..2
    state_labels = ref.labels.copy()          # B_t entering round 3
    p3 = ref.step()                           # round 3
    c = ScenarioEngine(sc, fl)                # fresh generator state
    c.round_index = 3
    c.labels = state_labels
    np.testing.assert_array_equal(c.step().mask, p3.mask)
    np.testing.assert_array_equal(c.labels, ref.labels)


def test_sampling_cardinality_and_dropout():
    fl = FLConfig(num_clusters=4, devices_per_cluster=4, topology="ring")
    eng = ScenarioEngine(ScenarioConfig(sample_fraction=0.5, seed=0), fl)
    for _ in range(10):
        plan = eng.step()
        assert plan.mask.sum() == 8   # ceil(0.5 * 16), no dropout
    eng = ScenarioEngine(ScenarioConfig(sample_fraction=0.5,
                                        dropout_prob=0.4, seed=0), fl)
    sums = [eng.step().mask.sum() for _ in range(20)]
    assert min(sums) >= 1 and max(sums) <= 8
    assert any(s < 8 for s in sums), "dropout must thin some cohort"


@pytest.mark.parametrize("dist,kw", [
    ("uniform", dict(speed_spread=0.5)),
    ("lognormal", dict(speed_spread=0.6)),
    ("bimodal", dict(slow_fraction=0.5, slow_factor=0.1)),
])
def test_speed_distributions_positive_mean_near_one(dist, kw):
    sc = ScenarioConfig(speed_dist=dist, **kw)
    mult = sample_speed_multipliers(sc, 4096, np.random.default_rng(0))
    assert (mult > 0).all()
    assert 0.4 < mult.mean() < 1.2, mult.mean()


def test_speed_homogeneous_is_ones():
    mult = sample_speed_multipliers(ScenarioConfig(), 8,
                                    np.random.default_rng(0))
    np.testing.assert_allclose(mult, 1.0)


def test_get_scenario():
    assert get_scenario("mobility").move_prob > 0
    with pytest.raises(ValueError):
        get_scenario("nope")
    for name, sc in SCENARIOS.items():
        sc.validate()
        assert sc.name == name


def test_trivial_property():
    assert ScenarioConfig().trivial
    assert ScenarioConfig(speed_dist="lognormal", speed_spread=1.0).trivial
    assert not ScenarioConfig(sample_fraction=0.5).trivial
    assert not ScenarioConfig(move_prob=0.1).trivial


# ---------------------------------------------------------------------------
# end-to-end parity + learning under scenarios
# ---------------------------------------------------------------------------

def test_trivial_scenario_matches_no_scenario_exactly():
    """sampling=1.0 + mobility off must reproduce the static-schedule
    trajectory bit-for-bit (acceptance criterion)."""
    fl = FLConfig(algorithm="ce_fedavg", num_clusters=4,
                  devices_per_cluster=2, tau=2, q=2, pi=4, topology="ring")
    s0 = _sim(fl)
    s1 = _sim(fl, scenario=ScenarioConfig(speed_dist="lognormal",
                                          speed_spread=0.6))
    s0.run(3)
    s1.run(3)
    # identical jitted round + full mask; the only slack is the last-ulp
    # matmul-association difference between the static and masked W builds
    for a, b in zip(jax.tree.leaves(s0.params), jax.tree.leaves(s1.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_simulator_learns_under_sampling_and_mobility():
    fl = FLConfig(algorithm="ce_fedavg", num_clusters=4,
                  devices_per_cluster=2, tau=2, q=2, pi=4, topology="ring")
    sc = ScenarioConfig(sample_fraction=0.75, dropout_prob=0.1,
                        move_prob=0.3, seed=1)
    s = _sim(fl, scenario=sc)
    acc0, _ = s.evaluate()
    hist = s.run(8)
    assert hist["acc"][-1] > max(acc0 + 0.15, 0.5), (acc0, hist["acc"])


def test_cluster_models_synced_after_round_under_mobility():
    """Algorithm 1 line 12 still holds per-round under mobility: devices
    sharing a cluster at round end share the edge model."""
    fl = FLConfig(algorithm="ce_fedavg", num_clusters=4,
                  devices_per_cluster=2, tau=1, q=1, pi=2, topology="ring")
    s = _sim(fl, scenario=ScenarioConfig(move_prob=0.5, seed=2))
    for _ in range(3):
        s.step_round()
    w = np.asarray(jax.tree.leaves(s.params)[0])
    labels = s.labels
    for c in np.unique(labels):
        members = np.nonzero(labels == c)[0]
        for k in members[1:]:
            np.testing.assert_allclose(w[members[0]], w[k], atol=1e-5)


def test_masked_operators_apply_rowwise_consensus_fixed_point():
    """Row-stochastic masked operators must leave a consensus state
    invariant. With lr=0 nothing trains, so every round is pure mixing:
    params must stay at the shared init — under sampling AND mobility.
    (Catches transposed application: column-applying the asymmetric
    masked operators zeroes non-participants and rescales cohorts.)"""
    for algo in ("ce_fedavg", "hier_favg", "fedavg", "local_edge"):
        fl = FLConfig(algorithm=algo, num_clusters=4,
                      devices_per_cluster=2, tau=1, q=2, pi=3,
                      topology="ring")
        sc = ScenarioConfig(sample_fraction=0.5, dropout_prob=0.2,
                            move_prob=0.4, seed=3)
        s = _sim(fl, scenario=sc, lr=0.0)
        p0 = [np.asarray(x).copy() for x in jax.tree.leaves(s.params)]
        for _ in range(4):
            s.step_round()
        for a, b in zip(jax.tree.leaves(s.params), p0):
            np.testing.assert_allclose(np.asarray(a), b, atol=1e-5)


def test_nonparticipants_receive_cohort_average():
    """After a fedavg round with a partial cohort, EVERY device (sampled
    or not) holds the cohort average — the masked A's rows are identical,
    so all device models must coincide post-round."""
    fl = FLConfig(algorithm="fedavg", num_clusters=2,
                  devices_per_cluster=2, tau=1, q=1, topology="ring")
    s = _sim(fl, scenario=ScenarioConfig(sample_fraction=0.5, seed=0))
    s.step_round()
    w = np.asarray(jax.tree.leaves(s.params)[0])
    assert np.abs(w).max() > 0, "params must not be zeroed"
    for k in range(1, fl.n):
        np.testing.assert_allclose(w[0], w[k], atol=1e-5)


def test_scenario_seed_controls_trajectory():
    fl = FLConfig(algorithm="ce_fedavg", num_clusters=2,
                  devices_per_cluster=2, tau=1, q=1, pi=2, topology="ring")
    sc = dataclasses.replace(SCENARIOS["sampled"], seed=0)
    h0 = _sim(fl, scenario=sc).run(2)
    h1 = _sim(fl, scenario=dataclasses.replace(sc, seed=7)).run(2)
    assert h0["acc"] != h1["acc"] or h0["loss"] != h1["loss"]


# ---------------------------------------------------------------------------
# fault replay determinism (ISSUE 8): the realized fault trace is a pure
# function of (config, round) — a killed-and-resumed engine sees exactly
# the faults the uninterrupted engine would have
# ---------------------------------------------------------------------------

def test_fault_trace_identical_straight_vs_resumed():
    from repro.config import FaultConfig

    fl = FLConfig(algorithm="ce_fedavg", num_clusters=4,
                  devices_per_cluster=3, tau=1, q=1, pi=2, topology="ring")
    sc = ScenarioConfig(
        name="chaos", speed_dist="lognormal", speed_spread=0.5,
        sample_fraction=0.8, move_prob=0.2, seed=4,
        faults=FaultConfig(outage_prob=0.25, outage_len=2,
                           link_drop_prob=0.2, timeout_factor=1.3,
                           max_retries=2, seed=9))
    R, kill_at = 10, 4

    def traces(eng, rounds):
        out = []
        for _ in range(rounds):
            plan = eng.step()
            assert plan.fault is not None
            out.append(plan.fault.trace())
        return out

    straight = traces(ScenarioEngine(sc, fl), R)
    assert any(t[1] or t[2] or t[4] for t in straight), \
        "chaos config produced no faults in 10 rounds"

    # kill at round 4; "resume" restores exactly what RunCheckpoint
    # saves of an engine: the mobility labels and the round cursor
    a = ScenarioEngine(sc, fl)
    traces(a, kill_at)
    b = ScenarioEngine(sc, fl)
    b.labels = a.labels.copy()
    b.round_index = a.round_index
    resumed = traces(ScenarioEngine(sc, fl), kill_at) + traces(b, R - kill_at)
    assert resumed == straight


def test_faulted_engine_parity_across_duplicate_engines():
    """Two engines with the same faulted config realize identical plans
    round by round (cohort, operators, H_eff) — the property different
    algorithms rely on to be compared under identical fault conditions."""
    from repro.config import FaultConfig

    fl = FLConfig(algorithm="ce_fedavg", num_clusters=3,
                  devices_per_cluster=2, tau=1, q=1, pi=2, topology="ring")
    sc = ScenarioConfig(name="f", sample_fraction=0.8, seed=1,
                        faults=FaultConfig(outage_prob=0.3, outage_len=2,
                                           link_drop_prob=0.25, seed=2))
    e1, e2 = ScenarioEngine(sc, fl), ScenarioEngine(sc, fl)
    for _ in range(8):
        p1, p2 = e1.step(), e2.step()
        np.testing.assert_array_equal(p1.mask, p2.mask)
        np.testing.assert_array_equal(p1.W_intra, p2.W_intra)
        np.testing.assert_array_equal(p1.W_inter, p2.W_inter)
        assert (p1.fault is None) == (p2.fault is None)
        if p1.fault is not None:
            assert p1.fault.trace() == p2.fault.trace()
        if p1.H_eff is not None:
            np.testing.assert_array_equal(p1.H_eff, p2.H_eff)
