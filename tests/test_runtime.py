"""Runtime model (eq. 8) + Theorem-1 bound sanity checks."""
import pytest

from repro.core.runtime import (HardwareProfile, RuntimeModel,
                                WorkloadProfile, convergence_bound)


def _rt():
    hw = HardwareProfile()  # paper §6.1 constants
    wl = WorkloadProfile(model_params=6_603_710,
                         flops_per_step=13.30e6 * 50 * 3)  # FEMNIST CNN
    return RuntimeModel(hw, wl)


def test_ce_faster_than_cloud_baselines():
    """Paper Fig. 2: per-round wall time CE < Hier < FedAvg is not the
    claim; the claim is runtime-to-accuracy. But with the paper's
    bandwidths, CE's round avoids the 1 Mb/s cloud hop entirely."""
    rt = _rt()
    t_ce = rt.round_time("ce_fedavg", tau=2, q=4, pi=10)
    t_hier = rt.round_time("hier_favg", tau=2, q=4, pi=10)
    t_fa = rt.round_time("fedavg", tau=2, q=4, pi=10)
    t_le = rt.round_time("local_edge", tau=2, q=4, pi=10)
    # the 1 Mb/s cloud hop dominates both cloud-touching baselines
    assert t_ce < t_fa < t_hier
    assert t_le < t_ce  # local-edge communicates least (but can't converge)


def test_round_time_monotone_in_q_pi():
    rt = _rt()
    assert rt.round_time("ce_fedavg", 2, 8, 10) > \
        rt.round_time("ce_fedavg", 2, 4, 10)
    assert rt.round_time("ce_fedavg", 2, 8, 10) > \
        rt.round_time("ce_fedavg", 2, 8, 5)


def test_smaller_tau_costs_more_time_at_fixed_qtau():
    """Paper Fig. 3: at fixed q·tau, smaller tau => more uplink rounds."""
    rt = _rt()
    t2 = rt.round_time("ce_fedavg", tau=2, q=8, pi=10)   # qtau = 16
    t4 = rt.round_time("ce_fedavg", tau=4, q=4, pi=10)
    t8 = rt.round_time("ce_fedavg", tau=8, q=2, pi=10)
    assert t2 > t4 > t8


def test_straggler_max_rule():
    hw = HardwareProfile()
    wl = WorkloadProfile(1_000_000, 1e9)
    fast = RuntimeModel(hw, wl, device_speeds=[1e12] * 8)
    slow = RuntimeModel(hw, wl, device_speeds=[1e12] * 7 + [1e10])
    assert slow.round_time("ce_fedavg", 2, 2, 2) > \
        fast.round_time("ce_fedavg", 2, 2, 2)


ALGOS = ("ce_fedavg", "hier_favg", "fedavg", "local_edge", "dec_local_sgd")


@pytest.mark.parametrize("algo", ALGOS)
def test_round_time_monotone_in_tau_q_pi(algo):
    """Eq. (8) per algorithm: more local steps, more edge rounds or more
    gossip steps never make a round faster."""
    rt = _rt()
    base = rt.round_time(algo, tau=2, q=4, pi=5)
    assert rt.round_time(algo, tau=4, q=4, pi=5) > base       # tau: compute
    assert rt.round_time(algo, tau=2, q=8, pi=5) > base       # q: compute+up
    more_pi = rt.round_time(algo, tau=2, q=4, pi=10)
    if algo in ("ce_fedavg", "dec_local_sgd"):                # pi: backhaul
        assert more_pi > base
    else:
        assert more_pi == base  # pi only prices gossip algorithms


@pytest.mark.parametrize("algo", ALGOS)
def test_per_device_speeds_straggler_dominates(algo):
    """The slowest device's compute term is exactly the max_k rule, for
    every algorithm's comm structure."""
    hw = HardwareProfile()
    wl = WorkloadProfile(1_000_000, 1e9)
    speeds = [1e12] * 7 + [1e10]
    rt = RuntimeModel(hw, wl, device_speeds=speeds)
    tau, q, pi = 2, 4, 3
    expected = q * tau * wl.flops_per_step / min(speeds) \
        + rt.comm_time(algo, q, pi)
    assert rt.round_time(algo, tau, q, pi) == pytest.approx(expected)
    # a per-call cohort that excludes the straggler is faster
    assert rt.round_time(algo, tau, q, pi, speeds=[1e12] * 7) < \
        rt.round_time(algo, tau, q, pi)


def test_model_bits_follows_hardware_precision():
    """Satellite fix: the payload W always reflects hw.bytes_per_param
    (the old property hardcoded 8 bits and was silently ignored)."""
    wl = WorkloadProfile(1_000_000, 1e9)
    assert wl.model_bits(HardwareProfile()) == 1_000_000 * 4 * 8
    assert wl.model_bits(HardwareProfile.tpu_v5e()) == 1_000_000 * 2 * 8
    hw4, hw2 = HardwareProfile(), HardwareProfile.tpu_v5e()
    t4 = RuntimeModel(hw4, wl).comm_time("fedavg", 1, 1)
    assert t4 == pytest.approx(wl.model_bits(hw4) / hw4.b_d2c)
    t2 = RuntimeModel(hw2, wl).comm_time("fedavg", 1, 1)
    assert t2 == pytest.approx(wl.model_bits(hw2) / hw2.b_d2c)


def test_convergence_bound_decreases_in_n():
    base = dict(T=10000, eta=0.01, L=1.0, sigma2=1.0, eps2=1.0,
                eps_i2=1.0, m=8, tau=2, q=8, z=0.8, pi=10)
    bounds = [convergence_bound(n=n, **base) for n in (16, 64, 256, 1024)]
    assert all(a > b for a, b in zip(bounds, bounds[1:])), bounds


def test_theorem1_bound_effects():
    base = dict(T=10000, eta=0.01, L=1.0, sigma2=1.0, eps2=1.0,
                eps_i2=1.0, n=64, m=8, tau=2, q=8, z=0.8, pi=10)
    b0 = convergence_bound(**base)
    # Remark 1: smaller tau at fixed q*tau converges better
    b_tau = convergence_bound(**{**base, "tau": 1, "q": 16})
    assert b_tau < b0
    # Theorem 1: better-connected graph (smaller zeta) converges better
    b_zeta = convergence_bound(**{**base, "z": 0.2})
    assert b_zeta < b0
    # Remark 3: moving divergence from inter- to intra-cluster helps
    b_shift = convergence_bound(**{**base, "eps2": 0.0, "eps_i2": 2.0})
    assert b_shift < b0


def test_tpu_profile_round_trip():
    hw = HardwareProfile.tpu_v5e(16)
    wl = WorkloadProfile(494_000_000, 6 * 494e6 * 65536)
    rt = RuntimeModel(hw, wl)
    t = rt.round_time("ce_fedavg", 2, 8, 10)
    assert 0 < t < 3600
