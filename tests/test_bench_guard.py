"""The CI perf regression guard (benchmarks/check_regression.py): the
guarded derived ratios exist in the committed baseline, and the
floor/ceiling semantics catch regressions without flagging the
overhead-dominated smoke shapes."""
import json
import os
import sys

import pytest

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.check_regression import (CHECKS, check, derived_field,
                                         main, newest_baseline)

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
# the guard compares against the NEWEST committed trajectory point —
# the same default resolution CI uses
BASELINE = newest_baseline(REPO)


def _rec(name, derived):
    return {"name": name, "us_per_call": 1.0, "derived": derived}


def _smoke(speedup, ratio, async_ratio=0.97, fault_ratio=0.98,
           resident_ratio=1.0, pipelined_ratio=0.7):
    return [
        _rec("kern_boundary_fused_femnist_cnn_n16",
             f"bank qt-boundary;speedup_vs_perleaf={speedup}x"),
        _rec("kern_compaction_ratio_mlp_smoke",
             f"half/full_round_time={ratio};blurb"),
        _rec("clock_async_s2_lognormal",
             f"async/barrier_makespan={async_ratio};rounds=8"),
        _rec("faults_chaos_cefedavg",
             f"faulted/clean_final_acc={fault_ratio};rounds=6"),
        _rec("scale_resident_ratio",
             f"resident_n10k/n1k={resident_ratio};blurb"),
        _rec("scale_pipelined_n10000",
             f"pipelined/serial_round_us={pipelined_ratio};blurb"),
    ]


@pytest.fixture(scope="module")
def baseline():
    with open(BASELINE) as f:
        return json.load(f)


def test_baseline_has_all_guarded_fields(baseline):
    for field, base_name, _, _ in CHECKS:
        assert derived_field(baseline, base_name, field) > 0


def test_healthy_smoke_passes(baseline):
    failures, _ = check(_smoke(1.85, 1.39), baseline, 2.5)
    assert failures == []


def test_lost_fusion_speedup_fails(baseline):
    """Fused boundary degrading to the per-leaf baseline (speedup ~1x
    while the committed baseline is >3x) must fail the floor check."""
    failures, _ = check(_smoke(0.9, 1.39), baseline, 2.5)
    assert failures == ["speedup_vs_perleaf"]


def test_compaction_blowup_fails(baseline):
    """A half-cohort round costing >2.5x the full round (per-round
    recompiles, duplicated gradient work) must fail the ceiling check."""
    failures, _ = check(_smoke(1.85, 3.1), baseline, 2.5)
    assert failures == ["half/full_round_time"]


def test_async_slower_than_barrier_fails(baseline):
    """Async charging MORE than the barrier breaks the wait-rule
    contract; the cap1 check is tolerance-free (deterministic clock
    math), so even 1.01 must fail."""
    failures, _ = check(_smoke(1.85, 1.39, async_ratio=1.01),
                        baseline, 2.5)
    assert failures == ["async/barrier_makespan"]
    # exactly 1.0 (a fleet where staleness buys nothing) is fine
    failures, _ = check(_smoke(1.85, 1.39, async_ratio=1.0),
                        baseline, 2.5)
    assert failures == []


def test_fault_degradation_collapse_fails(baseline):
    """An engine that survives the chaos preset but quietly collapses
    to near-random accuracy must fail the degradation floor."""
    failures, _ = check(_smoke(1.85, 1.39, fault_ratio=0.2),
                        baseline, 2.5)
    assert failures == ["faulted/clean_final_acc"]


def test_resident_memory_growth_fails(baseline):
    """The streamed store's peak resident slab growing with the
    population (n=10^4 costing >2.5x the n=10^3 slab under the same
    cohort config) must fail the O(cohort)-memory ceiling."""
    failures, _ = check(_smoke(1.85, 1.39, resident_ratio=10.0),
                        baseline, 2.5)
    assert failures == ["resident_n10k/n1k"]


def test_pipelined_slower_than_serial_fails(baseline):
    """The pipelined driver strictly removes work from the streamed
    round, so — like the async makespan — its ratio vs the serial
    oracle is a tolerance-free cap: even 1.01 must fail."""
    failures, _ = check(_smoke(1.85, 1.39, pipelined_ratio=1.01),
                        baseline, 2.5)
    assert failures == ["pipelined/serial_round_us"]
    failures, _ = check(_smoke(1.85, 1.39, pipelined_ratio=1.0),
                        baseline, 2.5)
    assert failures == []


def test_missing_record_is_an_error(baseline, tmp_path, capsys):
    smoke = tmp_path / "smoke.json"
    smoke.write_text(json.dumps(_smoke(1.85, 1.39)[:1]))
    rc = main(["--smoke", str(smoke), "--baseline", BASELINE])
    assert rc == 1
    assert "missing bench record" in capsys.readouterr().out


def test_newest_baseline_picks_highest_pr_tag(tmp_path):
    from benchmarks.check_regression import newest_baseline
    for name in ("BENCH_pr3.json", "BENCH_pr5.json", "BENCH_pr10.json"):
        (tmp_path / name).write_text("[]")
    assert newest_baseline(str(tmp_path)).endswith("BENCH_pr10.json")
    with pytest.raises(FileNotFoundError):
        newest_baseline(str(tmp_path / "empty"))


def test_repo_newest_baseline_guards_pass():
    """The committed trajectory has multiple points and the default
    baseline resolution lands on the newest; every guarded field —
    including the async makespan ratio added in PR 7 — resolves in it
    (candidate record names cover smoke-lane JSONs)."""
    import re
    newest = newest_baseline(REPO)
    m = re.search(r"BENCH_pr(\d+)\.json$", os.path.basename(newest))
    assert m and int(m.group(1)) >= 7, newest
    with open(newest) as f:
        records = json.load(f)
    for field, base_names, _, _ in CHECKS:
        assert derived_field(records, base_names, field) > 0


def test_derived_field_candidate_fallback(baseline):
    """A smoke-lane baseline carries the mlp_smoke compaction record;
    the candidate tuple must fall through to it."""
    smoke_named = _smoke(2.0, 1.2)
    v = derived_field(smoke_named,
                      ("kern_compaction_ratio_femnist_cnn",
                       "kern_compaction_ratio_mlp_smoke"),
                      "half/full_round_time")
    assert v == 1.2
