"""Per-arch smoke tests (deliverable f): REDUCED variant of each family runs
one forward + one train step on CPU; asserts shapes + no NaNs. Also decode
correctness: incremental decode matches full-sequence forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_model_config
from repro.data.lm import synthetic_lm_batch
from repro.models import model as mdl
from repro.models.model import padded_vocab


def _reduced_batch(cfg, B=2, S=64, seed=0):
    batch = {k: jnp.asarray(v) for k, v in
             synthetic_lm_batch((B, S), cfg.vocab_size, seed=seed).items()}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            jax.random.PRNGKey(1), (B, cfg.encoder_seq, cfg.d_model)) * 0.02
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.PRNGKey(1), (B, cfg.num_patches, cfg.d_model)) * 0.02
        batch["tokens"] = batch["tokens"][:, :S - cfg.num_patches]
        batch["labels"] = batch["labels"][:, :S - cfg.num_patches]
    return batch


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_forward_shapes_no_nan(arch):
    cfg = get_model_config(arch).reduced()
    assert cfg.num_layers <= 2 or cfg.family == "hybrid"
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4
    params, logical = mdl.init_model(jax.random.PRNGKey(0), cfg)
    # every param leaf has a matching logical annotation
    for leaf, log in zip(
            jax.tree.leaves(params),
            jax.tree.leaves(logical, is_leaf=lambda x: isinstance(x, tuple))):
        assert leaf.ndim == len(log), (leaf.shape, log)
    batch = _reduced_batch(cfg)
    logits, aux = jax.jit(lambda p, b: mdl.forward(cfg, p, b))(params, batch)
    S_out = batch["tokens"].shape[1] + (
        cfg.num_patches if cfg.family == "vlm" else 0)
    assert logits.shape == (2, S_out, padded_vocab(cfg))
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_smoke_train_step_improves_and_finite(arch):
    cfg = get_model_config(arch).reduced()
    params, _ = mdl.init_model(jax.random.PRNGKey(0), cfg)
    batch = _reduced_batch(cfg)

    @jax.jit
    def step(p):
        loss, g = jax.value_and_grad(
            lambda q: mdl.lm_loss(cfg, q, batch))(p)
        p = jax.tree.map(lambda a, b: a - 0.1 * b.astype(a.dtype), p, g)
        return loss, p

    l0, params = step(params)
    l1, params = step(params)
    l2, _ = step(params)
    assert np.isfinite(float(l0)) and np.isfinite(float(l2))
    assert float(l2) < float(l0), (float(l0), float(l2))


@pytest.mark.parametrize("arch", ["qwen2-0.5b", "mamba2-2.7b",
                                  "mixtral-8x7b", "whisper-medium",
                                  "zamba2-2.7b", "qwen2.5-14b",
                                  "minitron-8b", "mistral-large-123b",
                                  "llama4-maverick-400b-a17b"])
def test_decode_matches_forward(arch):
    """Incremental decode logits == teacher-forced forward logits."""
    cfg = get_model_config(arch).reduced()
    if cfg.family == "moe":
        cfg = cfg  # routing is batch-dependent; still deterministic here
    params, _ = mdl.init_model(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    batch = _reduced_batch(cfg, B=B, S=S)
    logits_full, _ = mdl.forward(cfg, params, batch)

    cache, _ = mdl.init_decode_cache(cfg, B, S, dtype=jnp.float32)
    if cfg.family == "encdec":
        # precompute cross-attention K/V from the encoder output
        enc_logits = None
        from repro.models import layers as Lmod
        enc = batch["frames"].astype(jnp.float32)
        from repro.models.model import _sinusoidal, _scan
        enc = enc + _sinusoidal(jnp.arange(enc.shape[1]),
                                cfg.d_model)[None].astype(enc.dtype)

        def enc_body(x, lp):
            h = Lmod.apply_norm(cfg, lp["norm1"], x)
            x = x + Lmod.apply_attention(cfg, lp["attn"], h, causal=False)
            h = Lmod.apply_norm(cfg, lp["norm2"], x)
            x = x + Lmod.apply_mlp(cfg, lp["mlp"], h)
            return x, None
        enc, _ = _scan(enc_body, enc, params["enc_layers"], False)
        enc = Lmod.apply_norm(cfg, params["enc_final_norm"], enc)

        def xkv(lp):
            _, k, v = Lmod.qkv_project(cfg, lp["cross_attn"], enc, enc)
            return k, v
        ks, vs = jax.vmap(xkv)(params["dec_layers"])
        cache["xk"] = ks.astype(cache["xk"].dtype)
        cache["xv"] = vs.astype(cache["xv"].dtype)

    toks = batch["tokens"]
    outs = []
    for i in range(S):
        lg, cache = mdl.decode_step(cfg, params, cache, toks[:, i:i + 1],
                                    jnp.asarray(i, jnp.int32))
        outs.append(lg)
    logits_dec = jnp.concatenate(outs, axis=1)
    if cfg.family == "vlm":
        logits_full = logits_full[:, -S:]
    np.testing.assert_allclose(
        np.asarray(logits_dec, np.float32),
        np.asarray(logits_full, np.float32), atol=0.05, rtol=0.05)


def test_vocab_padding_multiple_of_256():
    for arch in sorted(ARCHS):
        cfg = get_model_config(arch)
        assert padded_vocab(cfg) % 256 == 0
        assert padded_vocab(cfg) >= cfg.vocab_size


def test_sliding_window_masks_old_tokens():
    cfg = get_model_config("mixtral-8x7b").reduced(
        num_layers=2, sliding_window=8)
    params, _ = mdl.init_model(jax.random.PRNGKey(0), cfg)
    b1 = _reduced_batch(cfg, B=1, S=32, seed=0)
    # perturb tokens far outside the window of the last position
    t2 = np.asarray(b1["tokens"]).copy()
    t2[:, :8] = (t2[:, :8] + 7) % cfg.vocab_size
    b2 = {"tokens": jnp.asarray(t2), "labels": b1["labels"]}
    l1, _ = mdl.forward(cfg, params, b1)
    l2, _ = mdl.forward(cfg, params, b2)
    np.testing.assert_allclose(np.asarray(l1[:, -1], np.float32),
                               np.asarray(l2[:, -1], np.float32),
                               atol=1e-3)
