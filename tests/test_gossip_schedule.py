"""Topology-general gossip schedules: host-side structure + device parity.

Host-side tests verify the schedule algebra (matchings are valid partial
permutations covering each backhaul edge exactly once, and the weighted
permutation sum reconstructs H / H^π). The subprocess tests assert the
acceptance property: sparse and ringweight backends match the dense
``mix(W_inter, ·)`` operator to ≤1e-5 on ring, torus, star, complete and
erdos_renyi backhauls, single-pod and multi-pod.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

from repro.config import FLConfig
from repro.core import topology as topo
from repro.core.gossip import GossipSchedule, color_edges
from repro.core.runtime import gossip_traffic_per_round

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

CASES = [("ring", 8), ("complete", 8), ("star", 8), ("torus", 9),
         ("erdos_renyi", 8)]


def _H(name, m):
    cfg = FLConfig(topology=name, er_prob=0.4)
    return topo.mixing_matrix(topo.build_adjacency(name, m, cfg))


# ---------------------------------------------------------------------------
# host-side structure
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name,m", CASES)
def test_edge_coloring_is_a_partition_of_valid_matchings(name, m):
    adj = (np.abs(_H(name, m)) > 1e-12) & ~np.eye(m, dtype=bool)
    colors = color_edges(adj)
    seen = set()
    for mt in colors:
        # a matching: all sources distinct (dict keys give distinct dsts)
        assert len(set(mt.values())) == len(mt)
        for dst, src in mt.items():
            assert adj[src, dst]
            assert (src, dst) not in seen
            seen.add((src, dst))
    assert len(seen) == int(adj.sum())  # every directed edge exactly once


@pytest.mark.parametrize("name,m", CASES)
@pytest.mark.parametrize("dpc", [1, 2])
def test_schedule_reconstructs_mixing_operator(name, m, dpc):
    H = _H(name, m)
    s = GossipSchedule.build(H, 3, dpc, "rounds")
    np.testing.assert_allclose(s.dense_equivalent(), H, atol=1e-12)
    e = GossipSchedule.build(H, 3, dpc, "exact")
    np.testing.assert_allclose(e.dense_equivalent(),
                               np.linalg.matrix_power(H, 3), atol=1e-12)


@pytest.mark.parametrize("name,m", CASES)
def test_traffic_formulas_match_schedule(name, m):
    H = _H(name, m)
    deg = ((np.abs(H) > 1e-12) & ~np.eye(m, dtype=bool)).sum(1)
    for impl, mode in [("sparse", "rounds"), ("ringweight", "exact")]:
        s = GossipSchedule.build(H, 4, 2, mode)
        tr = gossip_traffic_per_round(
            impl, num_clusters=m, devices_per_cluster=2, pi=4,
            degrees=deg, model_bits=1.0)
        assert s.models_received_per_replica() == tr["per_replica_bits"]
        assert s.models_received_total(2 * m) == tr["total_bits"]
    dense = gossip_traffic_per_round(
        "dense", num_clusters=m, devices_per_cluster=2, pi=4,
        degrees=deg, model_bits=1.0)
    assert dense["per_replica_bits"] == 2 * m - 1


def test_validate_rejects_bad_combinations():
    with pytest.raises(AssertionError):
        FLConfig(topology="hypercube").validate()
    with pytest.raises(AssertionError):
        FLConfig(gossip_impl="magic").validate()
    with pytest.raises(AssertionError):
        FLConfig(topology="torus", num_clusters=6).validate()
    with pytest.raises(AssertionError):
        FLConfig(topology="erdos_renyi", er_prob=0.0).validate()
    with pytest.raises(AssertionError):
        FLConfig(algorithm="hier_favg", gossip_impl="sparse").validate()
    FLConfig(topology="torus", num_clusters=9, gossip_impl="sparse",
             devices_per_cluster=1).validate()


def test_erdos_renyi_fallback_invariants():
    # p tiny enough that 1000 samples on m=16 nodes never connect
    adj = topo.erdos_renyi(16, 1e-6, seed=0)
    assert adj.dtype == bool
    assert (adj == adj.T).all()
    assert not adj.diagonal().any()
    # the fallback superimposes a ring, so the ring edges must be present
    assert (adj[topo.ring(16)]).all()
    # connectivity is the point of the fallback
    H = topo.mixing_matrix(adj)
    assert topo.zeta(H) < 1.0


# ---------------------------------------------------------------------------
# device parity (subprocess: needs a multi-device host)
# ---------------------------------------------------------------------------

PARITY = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from repro.core import topology as topo
from repro.core.cefedavg import mix
from repro.core.gossip import (GossipSchedule, apply_cluster_mean,
                               apply_gossip)

mesh = Mesh(np.asarray(jax.devices()).reshape(*{shape!r}), {axes!r})
specs = P(tuple(a for a in ("pod", "data") if a in {axes!r}))
M, dpc, pi = 4, 2, 3
rng = np.random.default_rng(0)
tree = {{"w": jnp.asarray(rng.normal(size=(8, 33)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(8, 5, 3)).astype(np.float32))}}
tspecs = {{"w": specs, "b": specs}}
worst = 0.0
for name in ["ring", "star", "complete", "torus", "erdos_renyi"]:
    H = topo.mixing_matrix(topo.build_adjacency(name, M))
    W_inter = topo.inter_cluster_operator([dpc] * M, H, pi)
    ref = jax.tree.map(np.asarray, mix(W_inter, tree))
    for mode in ("rounds", "exact"):
        s = GossipSchedule.build(H, pi, dpc, mode)
        with mesh:
            y = apply_cluster_mean(tree, tspecs, mesh, M, dpc)
            y = apply_gossip(s, y, tspecs, mesh)
        d = max(jax.tree.leaves(jax.tree.map(
            lambda a, b: float(np.abs(np.asarray(a) - b).max()), y, ref)))
        print(name, mode, d)
        assert d < 1e-5, (name, mode, d)
        worst = max(worst, d)
print("WORST", worst)
"""


def _run_parity(shape, axes):
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    out = subprocess.run(
        [sys.executable, "-c",
         textwrap.dedent(PARITY.format(shape=shape, axes=axes))],
        capture_output=True, text=True, env=env, timeout=1200)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    assert "WORST" in out.stdout
    return out.stdout


@pytest.mark.slow
def test_parity_all_topologies_singlepod():
    out = _run_parity((8,), ("data",))
    assert out.count("exact") == 5 and out.count("rounds") == 5


@pytest.mark.slow
def test_parity_all_topologies_multipod():
    out = _run_parity((2, 4), ("pod", "data"))
    assert out.count("exact") == 5 and out.count("rounds") == 5
