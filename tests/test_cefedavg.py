"""CE-FedAvg operator algebra + special-case equivalences (paper §4.3)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.config import FLConfig
from repro.core.cefedavg import FLSimulator, make_w_schedule, mix
from repro.data.federated import (build_fl_data, dirichlet_partition,
                                  make_synthetic_classification)
from repro.models.cnn import apply_mlp_classifier, init_mlp_classifier


def _sim(fl, *, seed=0, lr=0.1, d=16, classes=4, n_samples=800):
    x, y = make_synthetic_classification(n_samples, d, classes, seed=3)
    tx, ty = make_synthetic_classification(400, d, classes, seed=4)
    parts = dirichlet_partition(y, fl.n, alpha=0.5, seed=5)
    data = build_fl_data(x, y, parts, tx, ty, samples_per_device=64)
    data = {k: jnp.asarray(v) for k, v in data.items()}
    return FLSimulator(
        lambda k: init_mlp_classifier(k, d, 32, classes),
        apply_mlp_classifier, fl, data, lr=lr, batch_size=16, seed=seed)


def _params_close(a, b, atol=1e-5):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=atol)


def test_w_schedule_doubly_stochastic():
    for algo in ("ce_fedavg", "hier_favg", "fedavg", "local_edge"):
        fl = FLConfig(algorithm=algo, num_clusters=4, devices_per_cluster=2,
                      topology="ring")
        s = make_w_schedule(fl)
        for W in (s.W_intra, s.W_inter):
            np.testing.assert_allclose(W.sum(0), 1.0, atol=1e-9)
            np.testing.assert_allclose(W.sum(1), 1.0, atol=1e-9)


def test_mix_preserves_average():
    """Eq. (12): the device-average is invariant under every W_t."""
    fl = FLConfig(num_clusters=4, devices_per_cluster=2, topology="ring",
                  pi=3)
    s = make_w_schedule(fl)
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 5, 3))}
    for W in (s.W_intra, s.W_inter):
        mixed = mix(W, params)
        np.testing.assert_allclose(np.asarray(mixed["w"].mean(0)),
                                   np.asarray(params["w"].mean(0)),
                                   atol=1e-5)


def test_ce_reduces_to_fedavg():
    """m=1, q=1: CE-FedAvg == cloud FedAvg exactly (same seeds)."""
    fl_ce = FLConfig(algorithm="ce_fedavg", num_clusters=1,
                     devices_per_cluster=8, tau=2, q=1, pi=1,
                     topology="ring")
    fl_fa = dataclasses.replace(fl_ce, algorithm="fedavg")
    s1, s2 = _sim(fl_ce), _sim(fl_fa)
    s1.run(2)
    s2.run(2)
    _params_close(s1.params, s2.params)


def test_ce_complete_graph_reduces_to_hier_favg():
    """Complete backhaul: H = A_m so one gossip step == cloud averaging."""
    fl_ce = FLConfig(algorithm="ce_fedavg", num_clusters=4,
                     devices_per_cluster=2, tau=1, q=2, pi=1,
                     topology="complete")
    fl_h = dataclasses.replace(fl_ce, algorithm="hier_favg")
    s1, s2 = _sim(fl_ce), _sim(fl_h)
    s1.run(2)
    s2.run(2)
    _params_close(s1.params, s2.params)


def test_dec_local_sgd_special_case():
    fl = FLConfig(algorithm="dec_local_sgd", num_clusters=8,
                  devices_per_cluster=1, tau=1, q=4, pi=1, topology="ring")
    s = _sim(fl)
    hist = s.run(2)
    assert np.isfinite(hist["loss"][-1])


def test_local_edge_never_mixes_across_clusters():
    fl = FLConfig(algorithm="local_edge", num_clusters=4,
                  devices_per_cluster=2, tau=1, q=2, topology="ring")
    s = make_w_schedule(fl)
    # W_inter block-diagonal: no mass crosses cluster boundaries
    W = s.W_inter
    assert W[0, 2] == 0 and W[0, 7] == 0 and W[0, 1] > 0


def test_simulator_learns():
    fl = FLConfig(algorithm="ce_fedavg", num_clusters=4,
                  devices_per_cluster=2, tau=2, q=2, pi=4, topology="ring")
    s = _sim(fl, lr=0.1)
    acc0, _ = s.evaluate()
    hist = s.run(8)
    assert hist["acc"][-1] > max(acc0 + 0.15, 0.5), (acc0, hist["acc"])


def test_edge_models_equal_within_cluster_after_round():
    """After any aggregation boundary, devices in a cluster share the edge
    model (Algorithm 1 line 12)."""
    fl = FLConfig(algorithm="ce_fedavg", num_clusters=4,
                  devices_per_cluster=2, tau=1, q=1, pi=2, topology="ring")
    s = _sim(fl)
    s.run(1)
    w = np.asarray(jax.tree.leaves(s.params)[0])
    for c in range(4):
        np.testing.assert_allclose(w[2 * c], w[2 * c + 1], atol=1e-6)
