"""Crash-consistent resume (ISSUE 8 tentpole): a run killed at round k
and resumed from its RunCheckpoint replays rounds k..R *bit-identically*
to the uninterrupted run — parameters AND recorded accuracy history —
for the flat engine at staleness 0 and 2, the legacy pytree engine, the
sharded engine (per-shard restore, never materializing the bank on one
host), and a genuinely killed subprocess (os._exit mid-round)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import RunCheckpoint
from repro.config import FLConfig, FaultConfig, ScenarioConfig
from repro.core.cefedavg import FLSimulator
from repro.core.clock import run_wall_clock
from repro.core.compress import CompressionConfig
from repro.core.runtime import paper_runtime_model
from repro.data.federated import (build_fl_data, dirichlet_partition,
                                  make_synthetic_classification)
from repro.models.cnn import apply_mlp_classifier, init_mlp_classifier

FL = FLConfig(num_clusters=4, devices_per_cluster=3, tau=2, q=1, pi=2,
              topology="ring")
SC = ScenarioConfig(
    name="chaos", speed_dist="lognormal", speed_spread=0.5,
    faults=FaultConfig(outage_prob=0.2, outage_len=2, link_drop_prob=0.15,
                       timeout_factor=1.2, max_retries=2, seed=11))


def _sim(fl=FL, *, scenario=SC, seed=1, bank=True, schedule=None,
         compression=None):
    x, y = make_synthetic_classification(800, 16, 4, seed=3)
    tx, ty = make_synthetic_classification(400, 16, 4, seed=4)
    parts = dirichlet_partition(y, fl.n, alpha=0.5, seed=5)
    data = build_fl_data(x, y, parts, tx, ty, samples_per_device=64)
    data = {k: jnp.asarray(v) for k, v in data.items()}
    return FLSimulator(
        lambda k: init_mlp_classifier(k, 16, 32, 4),
        apply_mlp_classifier, fl, data, lr=0.1, batch_size=16, seed=seed,
        scenario=scenario, bank=bank, schedule=schedule,
        compression=compression)


def _params_np(sim):
    return [np.asarray(jax.device_get(l))
            for l in jax.tree.leaves(sim.params)]


def _replayable(hist):
    """Everything in a history that resume must reproduce bitwise —
    i.e. all of it except ``page_s``/``compute_s``, the *host*
    wall-seconds instrumentation (real elapsed time, legitimately
    nondeterministic)."""
    return {k: v for k, v in hist.items()
            if k not in ("page_s", "compute_s")}


def _run(tmpdir, *, kill_at=None, rounds=8, staleness=None, **simkw):
    """One trajectory through run_wall_clock with per-round checkpoints;
    ``kill_at`` truncates the first pass and resumes a FRESH sim."""
    d = str(tmpdir)
    sim = _sim(**simkw)
    rt = paper_runtime_model()
    kw = dict(eval_every=2, ckpt_dir=d, ckpt_every=1,
              async_staleness=staleness)
    if kill_at is None:
        return sim, run_wall_clock(sim, rt, rounds, **kw)
    run_wall_clock(sim, rt, kill_at, **kw)
    sim2 = _sim(**simkw)
    hist = run_wall_clock(sim2, rt, rounds, resume=True, **kw)
    return sim2, hist


@pytest.mark.parametrize("staleness", [None, 0, 2])
def test_flat_engine_kill_and_resume_bit_identical(tmp_path, staleness):
    ref_sim, ref = _run(tmp_path / "ref", staleness=staleness)
    got_sim, got = _run(tmp_path / "killed", kill_at=3,
                        staleness=staleness)
    assert _replayable(ref) == _replayable(got)
    for a, b in zip(_params_np(ref_sim), _params_np(got_sim)):
        np.testing.assert_array_equal(a, b)


def test_legacy_engine_kill_and_resume_bit_identical(tmp_path):
    ref_sim, ref = _run(tmp_path / "ref", bank=False)
    got_sim, got = _run(tmp_path / "killed", kill_at=4, bank=False)
    assert ref["acc"] == got["acc"] and ref["wall_time"] == got["wall_time"]
    for a, b in zip(_params_np(ref_sim), _params_np(got_sim)):
        np.testing.assert_array_equal(a, b)


def test_resume_with_error_feedback_residual(tmp_path):
    """The EF residual is part of the run state: dropping it from the
    checkpoint would silently change the post-resume trajectory."""
    comp = CompressionConfig(kind="topk", topk_frac=0.25,
                             error_feedback=True)
    ref_sim, ref = _run(tmp_path / "ref", rounds=6, compression=comp)
    got_sim, got = _run(tmp_path / "killed", rounds=6, kill_at=3,
                        compression=comp)
    assert ref["acc"] == got["acc"]
    for a, b in zip(_params_np(ref_sim), _params_np(got_sim)):
        np.testing.assert_array_equal(a, b)
    assert got_sim.bank.residual is not None


def test_resume_restores_schedule_state(tmp_path):
    ref_sim, ref = _run(tmp_path / "ref", schedule="pi_feedback")
    got_sim, got = _run(tmp_path / "killed", kill_at=4,
                        schedule="pi_feedback")
    assert ref["acc"] == got["acc"]
    assert ref_sim._schedule_fn.state == got_sim._schedule_fn.state
    # the post-resume depths match the uninterrupted run's tail
    k = len(got_sim._schedule_fn.pi_trace)
    assert (ref_sim._schedule_fn.pi_trace[-k:]
            == got_sim._schedule_fn.pi_trace)


def test_resume_without_checkpoint_starts_fresh(tmp_path):
    sim = _sim()
    rt = paper_runtime_model()
    hist = run_wall_clock(sim, rt, 2, eval_every=1, ckpt_dir=str(tmp_path),
                          ckpt_every=1, resume=True)   # nothing to resume
    assert hist["round"] == [1, 2]
    assert RunCheckpoint(str(tmp_path)).exists()


@pytest.mark.multidevice
def test_sharded_engine_per_shard_resume(tmp_path):
    if jax.device_count() < 8:
        pytest.skip("needs 8 devices (CI multidevice lane)")
    from repro.core.sharded import ShardedBankCEFedAvg
    from repro.launch.mesh import make_replica_mesh
    fl = FLConfig(num_clusters=4, devices_per_cluster=2, tau=2, q=1, pi=2)
    mesh = make_replica_mesh(8)
    x, y = make_synthetic_classification(800, 16, 4, seed=3)
    tx, ty = make_synthetic_classification(400, 16, 4, seed=4)
    parts = dirichlet_partition(y, 8, alpha=0.5, seed=5)
    data = build_fl_data(x, y, parts, tx, ty, samples_per_device=64)

    def mk():
        return ShardedBankCEFedAvg(
            lambda k: init_mlp_classifier(k, 16, 32, 4),
            apply_mlp_classifier, fl, data, mesh, lr=0.1, batch_size=16,
            seed=0, scenario=SC)

    ref = mk()
    for _ in range(5):
        ref.step_round()
    rc = RunCheckpoint(str(tmp_path))
    s1 = mk()
    for _ in range(3):
        s1.step_round()
    rc.save(s1, round_idx=3)
    s2 = mk()
    assert rc.restore(s2)["round"] == 3
    # restore preserved the row sharding: no single-device bank ever
    assert s2.bank.params.sharding == s1.bank.params.sharding
    for _ in range(3, 5):
        s2.step_round()
    np.testing.assert_array_equal(
        np.asarray(jax.device_get(ref.bank.params)),
        np.asarray(jax.device_get(s2.bank.params)))


# ---------------------------------------------------------------------------
# subprocess kill: the process genuinely dies mid-round (os._exit), the
# next process resumes from the surviving atomic checkpoint
# ---------------------------------------------------------------------------

_DRIVER = textwrap.dedent("""\
    import json, os, sys
    import jax.numpy as jnp
    from repro.config import FLConfig, FaultConfig, ScenarioConfig
    from repro.core.cefedavg import FLSimulator
    from repro.core.clock import run_wall_clock
    from repro.core.runtime import paper_runtime_model
    from repro.data.federated import (build_fl_data, dirichlet_partition,
                                      make_synthetic_classification)
    from repro.models.cnn import (apply_mlp_classifier,
                                  init_mlp_classifier)

    ckpt_dir, rounds, kill_at, out = sys.argv[1:5]
    rounds, kill_at = int(rounds), int(kill_at)
    fl = FLConfig(num_clusters=3, devices_per_cluster=2, tau=2, q=1,
                  pi=2)
    sc = ScenarioConfig(name="f", faults=FaultConfig(
        outage_prob=0.25, outage_len=1, seed=5))
    x, y = make_synthetic_classification(600, 16, 4, seed=3)
    tx, ty = make_synthetic_classification(300, 16, 4, seed=4)
    parts = dirichlet_partition(y, fl.n, alpha=0.5, seed=5)
    data = build_fl_data(x, y, parts, tx, ty, samples_per_device=64)
    data = {k: jnp.asarray(v) for k, v in data.items()}
    sim = FLSimulator(lambda k: init_mlp_classifier(k, 16, 32, 4),
                      apply_mlp_classifier, fl, data, lr=0.1,
                      batch_size=16, seed=1, scenario=sc)
    if kill_at >= 0:
        orig = sim.step_round
        done = [0]
        def dying_step():
            if done[0] == kill_at:
                os._exit(17)      # SIGKILL-equivalent: no cleanup runs
            done[0] += 1
            return orig()
        sim.step_round = dying_step
    hist = run_wall_clock(sim, paper_runtime_model(), rounds,
                          eval_every=2, ckpt_dir=ckpt_dir, ckpt_every=1,
                          resume=True)
    with open(out, "w") as f:
        json.dump(hist, f)
""")


def _spawn(ckpt_dir, rounds, kill_at, out):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..",
                                     "src")
    return subprocess.run(
        [sys.executable, "-c", _DRIVER, str(ckpt_dir), str(rounds),
         str(kill_at), str(out)], env=env, capture_output=True, text=True,
        timeout=600)


def _kill_resume_compare(tmp_path, rounds, kill_at):
    ref_out = tmp_path / "ref.json"
    p = _spawn(tmp_path / "ref", rounds, -1, ref_out)
    assert p.returncode == 0, p.stderr
    killed = _spawn(tmp_path / "killed", rounds, kill_at,
                    tmp_path / "never.json")
    assert killed.returncode == 17, (killed.returncode, killed.stderr)
    resumed_out = tmp_path / "resumed.json"
    p = _spawn(tmp_path / "killed", rounds, -1, resumed_out)
    assert p.returncode == 0, p.stderr
    ref = json.loads(ref_out.read_text())
    got = json.loads(resumed_out.read_text())
    assert _replayable(ref) == _replayable(got), (ref, got)


def test_subprocess_kill_and_resume_smoke(tmp_path):
    """Fast-lane variant: die after 2 rounds of 4, resume, compare."""
    _kill_resume_compare(tmp_path, rounds=4, kill_at=2)


@pytest.mark.slow
def test_subprocess_kill_and_resume_long(tmp_path):
    """Kill late in a longer faulted run; the resumed process must
    reproduce the uninterrupted history exactly."""
    _kill_resume_compare(tmp_path, rounds=10, kill_at=7)
