"""RoundProgram IR (ISSUE 5): validation, canonical compilation, engine
lowerings (randomized-schedule fuzz parity legacy-pytree vs flat-bank vs
compacted-cohort, including masked/mobility rounds), named schedules
(adaptive τ_k, π_t decay) and the per-op event-clock cost hook."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.config import FLConfig, ScenarioConfig
from repro.core.cefedavg import FLSimulator
from repro.core.clock import (EventClock, program_comm_time,
                              program_compute_time, run_wall_clock)
from repro.core.compress import CompressionConfig
from repro.core.program import (Compress, InterGossip, IntraMix, LocalSteps,
                                MaskRenorm, Privatize, RoundProgram,
                                adaptive_tau_map, block_runs,
                                canonical_program, lowering_plan,
                                make_schedule, resolve_matrices)
from repro.core.runtime import (compute_bound_runtime_model,
                                paper_runtime_model)
from repro.data.federated import (build_fl_data, dirichlet_partition,
                                  make_synthetic_classification)
from repro.models.cnn import apply_mlp_classifier, init_mlp_classifier

_FL = FLConfig(algorithm="ce_fedavg", num_clusters=4,
               devices_per_cluster=2, tau=2, q=2, pi=4, topology="ring")


def _sim(fl, *, scenario=None, schedule=None, seed=0, bank=True,
         compression=None):
    x, y = make_synthetic_classification(800, 16, 4, seed=3)
    tx, ty = make_synthetic_classification(400, 16, 4, seed=4)
    parts = dirichlet_partition(y, fl.n, alpha=0.5, seed=5)
    data = build_fl_data(x, y, parts, tx, ty, samples_per_device=64)
    data = {k: jnp.asarray(v) for k, v in data.items()}
    return FLSimulator(
        lambda k: init_mlp_classifier(k, 16, 32, 4),
        apply_mlp_classifier, fl, data, lr=0.1, batch_size=16, seed=seed,
        scenario=scenario, schedule=schedule, compression=compression,
        bank=bank)


def _params_close(a, b, atol=1e-6):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   atol=atol)


# ---------------------------------------------------------------------------
# IR structure + validation
# ---------------------------------------------------------------------------

def test_canonical_program_shape():
    prog = canonical_program(_FL)
    blocks = prog.blocks()
    assert len(blocks) == _FL.q
    assert all(b.local == LocalSteps(_FL.tau) for b in blocks)
    assert all(b.mixes == (IntraMix(),) for b in blocks[:-1])
    assert blocks[-1].mixes == (IntraMix(), InterGossip(_FL.pi))
    assert prog.mask_renorm and not prog.has_upload and not prog.adaptive


def test_canonical_program_upload_ops():
    prog = canonical_program(_FL, privatize=True, compress=True)
    b = prog.blocks()[0]
    assert b.privatize and b.compress and b.upload
    assert prog.has_upload


def test_flconfig_round_program_hook():
    """FLConfig compiles its τ/q/π knobs into the canonical program."""
    assert _FL.round_program() == canonical_program(_FL)


def test_program_validation_errors():
    with pytest.raises(ValueError, match="at least one"):
        RoundProgram((MaskRenorm(),))
    with pytest.raises(ValueError, match="start a block"):
        RoundProgram((IntraMix(),))
    with pytest.raises(ValueError, match="no closing mixing"):
        RoundProgram((LocalSteps(2), LocalSteps(2), IntraMix()))
    with pytest.raises(ValueError, match="precede Compress"):
        RoundProgram((LocalSteps(2), Compress(), Privatize(), IntraMix()))
    with pytest.raises(ValueError, match="tau must be"):
        RoundProgram((LocalSteps(0), IntraMix()))
    with pytest.raises(ValueError, match="pi must be"):
        RoundProgram((LocalSteps(1), InterGossip(0)))
    with pytest.raises(ValueError, match="tau_dev"):
        RoundProgram((LocalSteps(2, adaptive=True), IntraMix()))
    with pytest.raises(ValueError, match="lie in"):
        RoundProgram((LocalSteps(2, adaptive=True), IntraMix()),
                     tau_dev=np.array([1, 3], np.int32))


def test_signature_excludes_tau_dev():
    """Re-binding per-device cutoffs must not change the compile key."""
    a = RoundProgram((LocalSteps(3, adaptive=True), IntraMix()),
                     tau_dev=np.array([1, 2], np.int32))
    b = a.bind(np.array([3, 3], np.int32))
    assert a.signature == b.signature and a == b
    assert hash(a.ops) == hash(b.ops)
    assert not np.array_equal(a.tau_dev, b.tau_dev)


def test_lowering_plan_fusion_policy():
    prog = canonical_program(_FL)
    fused = lowering_plan(prog, fuse=True)
    assert [len(bp.groups) for bp in fused] == [1] * _FL.q
    assert len(fused[-1].groups[0].ops) == 2     # τ∘qτ fused to one pass
    seq = lowering_plan(prog, fuse=False)
    assert [len(bp.groups) for bp in seq] == [1] * (_FL.q - 1) + [2]
    # upload path: the first mix applies to the delta — never fused
    up = lowering_plan(canonical_program(_FL, compress=True), fuse=True)
    assert len(up[-1].groups) == 2
    assert up[-1].groups[0].ops == (IntraMix(),)


def test_block_runs_collapse_identical_blocks():
    prog = canonical_program(dataclasses.replace(_FL, q=5))
    runs = block_runs(lowering_plan(prog, fuse=True))
    assert [c for _, c in runs] == [4, 1]


def test_resolve_matrices_fuses_products():
    from repro.core.cefedavg import make_w_schedule
    sched = make_w_schedule(_FL)
    plans = lowering_plan(canonical_program(_FL), fuse=True)
    mats = resolve_matrices(plans, sched.W_intra, lambda pi: sched.W_inter)
    assert len(mats) == 2                         # scan run + final block
    np.testing.assert_allclose(mats[0], sched.W_intra, atol=0)
    np.testing.assert_allclose(mats[1], sched.W_inter @ sched.W_intra,
                               atol=1e-7)


# ---------------------------------------------------------------------------
# canonical lowering == implicit static schedule
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bank", [True, False])
def test_static_schedule_matches_default(bank):
    """schedule="static" routes through the ScheduleFn hook but must
    reproduce the default (no-schedule) trajectory bit-for-bit."""
    a = _sim(_FL, bank=bank)
    b = _sim(_FL, schedule="static", bank=bank)
    a.run(2)
    b.run(2)
    _params_close(a.params, b.params, atol=0)


def test_fixed_round_program_as_schedule():
    """A RoundProgram instance is accepted directly as the schedule."""
    prog = canonical_program(_FL)
    a, b = _sim(_FL), _sim(_FL, schedule=prog)
    a.run(2)
    b.run(2)
    _params_close(a.params, b.params, atol=0)


# ---------------------------------------------------------------------------
# randomized-schedule fuzz: the three single-host lowerings must agree
# ---------------------------------------------------------------------------

def random_program(rng: np.random.Generator, n: int,
                   allow_upload: bool = False) -> RoundProgram:
    """A random valid program: 1–3 blocks of random τ/lr_scale/adaptive
    local steps, random mixing boundaries (including mid-program gossip
    and non-canonical π), always MaskRenorm so masked rounds use the
    renormalized operators the scenario engine asserts elsewhere."""
    ops = [MaskRenorm()]
    nblocks = int(rng.integers(1, 4))
    any_adaptive = False
    max_tau = 1
    for i in range(nblocks):
        tau = int(rng.integers(1, 4))
        adaptive = bool(rng.random() < 0.4)
        any_adaptive |= adaptive
        max_tau = max(max_tau, tau) if adaptive else max_tau
        ops.append(LocalSteps(tau,
                              lr_scale=float(rng.choice([1.0, 0.5])),
                              adaptive=adaptive))
        if allow_upload and rng.random() < 0.5:
            ops.append(Compress())
        last = i == nblocks - 1
        choice = rng.integers(0, 3)
        if last or choice == 0:
            ops.append(IntraMix())
            if last or rng.random() < 0.3:
                ops.append(InterGossip(int(rng.integers(1, 4))))
        elif choice == 1:
            ops.append(IntraMix())
        else:
            ops.append(InterGossip(int(rng.integers(1, 3))))
    tau_dev = (rng.integers(1, max_tau + 1, size=n).astype(np.int32)
               if any_adaptive else None)
    return RoundProgram(tuple(ops), tau_dev=tau_dev)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_fuzz_legacy_vs_flat_full_participation(seed):
    """Same random program, same keys: the legacy pytree and flat-bank
    lowerings must produce the same trajectory (~1e-7-grade float32
    agreement) — the IR acceptance bar, on arbitrary programs rather
    than just the canonical one."""
    rng = np.random.default_rng(seed)
    prog = random_program(rng, _FL.n)
    sb = _sim(_FL, schedule=prog)
    sl = _sim(_FL, schedule=prog, bank=False)
    for _ in range(2):
        sb.step_round()
        sl.step_round()
    _params_close(sb.params, sl.params)
    _params_close(sb.mom, sl.mom)


@pytest.mark.parametrize("seed", [3, 4])
def test_fuzz_legacy_vs_flat_masked_mobility(seed):
    """Fuzz parity under a non-trivial scenario: partial cohorts route
    the flat engine through the compacted lowering (plain programs), so
    this exercises all three single-host lowerings on one trajectory."""
    rng = np.random.default_rng(100 + seed)
    prog = random_program(rng, _FL.n)
    # 0.5 of each 2-device cluster: the stratified keyed sampler draws
    # 1 per cluster, so the compacted cohort path engages every round
    sc = ScenarioConfig(speed_dist="lognormal", speed_spread=0.6,
                        sample_fraction=0.5, dropout_prob=0.2,
                        move_prob=0.3, seed=seed)
    sb = _sim(_FL, scenario=sc, schedule=prog)
    sl = _sim(_FL, scenario=sc, schedule=prog, bank=False)
    compacted = False
    for _ in range(3):
        sb.step_round()
        sl.step_round()
        compacted |= sb.last_bucket < sb.bank.n
    assert compacted, "fuzz scenario never dispatched the compact round"
    _params_close(sb.params, sl.params)


def test_fuzz_upload_program_with_compression():
    """Programs with Compress ops agree across engines on the EF
    residual too (flat vs pytree upload key schedules)."""
    rng = np.random.default_rng(42)
    prog = random_program(rng, _FL.n, allow_upload=True)
    while not prog.has_upload:
        prog = random_program(rng, _FL.n, allow_upload=True)
    comp = CompressionConfig("topk", topk_frac=0.25)
    sb = _sim(_FL, schedule=prog, compression=comp)
    sl = _sim(_FL, schedule=prog, compression=comp, bank=False)
    for _ in range(2):
        sb.step_round()
        sl.step_round()
    _params_close(sb.params, sl.params)
    if sb.residual is not None:
        _params_close(sb.residual, sl.residual)


def test_schedule_fn_can_vary_program_per_round():
    """A ScheduleFn may return a different structure each round; every
    distinct signature compiles once and replays from cache."""
    p1 = canonical_program(_FL)
    p2 = canonical_program(dataclasses.replace(_FL, pi=2))

    def fn(r, plan):
        return p1 if r % 2 == 0 else p2
    s = _sim(_FL, schedule=fn)
    for _ in range(4):
        s.step_round()
    assert len(s._lowered) == 2
    sigs = {sig for _, sig in s._lowered}
    assert sigs == {p1.signature, p2.signature}


# ---------------------------------------------------------------------------
# named schedules
# ---------------------------------------------------------------------------

def test_adaptive_tau_map_scales_with_cluster_speed():
    labels = np.array([0, 0, 1, 1])
    mask = np.ones(4)
    mult = np.array([1.0, 1.0, 0.25, 0.5])
    td = adaptive_tau_map(4, labels, mask, mult, 2)
    assert td.tolist() == [4, 4, 1, 1]     # slow cluster: min speed 0.25
    # a masked-out straggler no longer drags its cluster down
    td2 = adaptive_tau_map(4, labels, np.array([1, 1, 0, 1.0]), mult, 2)
    assert td2.tolist() == [4, 4, 2, 2]


def test_adaptive_tau_homogeneous_reduces_to_static():
    fl = _FL
    sched = make_schedule("adaptive_tau", fl, speeds=np.ones(fl.n))
    prog = sched(0, None)
    assert prog.adaptive
    assert prog.tau_dev.tolist() == [fl.tau] * fl.n
    a, b = _sim(fl), _sim(fl, schedule=sched)
    a.run(2)
    b.run(2)
    _params_close(a.params, b.params)


def test_pi_decay_switches_depth():
    sched = make_schedule("pi_decay", _FL, decay_round=2, pi_late=1)
    early = [op.pi for op in sched(0, None).ops
             if isinstance(op, InterGossip)]
    late = [op.pi for op in sched(5, None).ops
            if isinstance(op, InterGossip)]
    assert early == [_FL.pi] and late == [1]


def test_unknown_schedule_name_raises():
    with pytest.raises(ValueError, match="unknown schedule"):
        make_schedule("nope", _FL)


# ---------------------------------------------------------------------------
# per-op clock cost hook
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo,dpc", [
    ("ce_fedavg", 4), ("hier_favg", 4), ("fedavg", 4), ("local_edge", 4),
    ("dec_local_sgd", 1)])
def test_canonical_program_charge_matches_charge_round(algo, dpc):
    """The per-op pricing reduces to eq. 8 / the §6.1 per-algorithm
    formulas on the canonical program — to the last term."""
    fl = FLConfig(algorithm=algo, num_clusters=4, devices_per_cluster=dpc,
                  tau=2, q=4, pi=10)
    rt = paper_runtime_model()
    a = EventClock(rt, fl).charge_round()
    b = EventClock(rt, fl).charge_program(canonical_program(fl))
    assert a == pytest.approx(b, rel=1e-12)


@pytest.mark.parametrize("algo,dpc", [
    ("ce_fedavg", 4), ("hier_favg", 4), ("fedavg", 4), ("local_edge", 4),
    ("dec_local_sgd", 1)])
def test_canonical_charge_parity_with_compressed_uplink(algo, dpc):
    """uplink_ratio != 1 (compression) must price identically to
    RuntimeModel.comm_time — hier_favg's cloud hop carries the FULL
    model, only device→edge uploads shrink."""
    fl = FLConfig(algorithm=algo, num_clusters=4, devices_per_cluster=dpc,
                  tau=2, q=4, pi=10)
    rt = paper_runtime_model()
    a = EventClock(rt, fl).charge_round(uplink_ratio=0.5)
    b = EventClock(rt, fl).charge_program(canonical_program(fl),
                                          uplink_ratio=0.5)
    assert a == pytest.approx(b, rel=1e-12)


def test_adaptive_charge_caps_at_each_blocks_tau():
    """tau_dev is bounded by the max adaptive tau across blocks; a block
    with a smaller tau executes (and must be charged) at most its own
    tau steps."""
    prog = RoundProgram(
        (LocalSteps(2, adaptive=True), IntraMix(),
         LocalSteps(4, adaptive=True), IntraMix(), InterGossip(1)),
        tau_dev=np.array([4, 4], np.int32))
    rt = compute_bound_runtime_model()
    got = program_compute_time(rt, prog)
    per_step = rt.wl.flops_per_step / rt.hw.device_flops
    assert got == pytest.approx((2 + 4) * per_step, rel=1e-12)


def test_adaptive_program_charges_fewer_compute_seconds():
    fl = dataclasses.replace(_FL, tau=4)
    rt = compute_bound_runtime_model()
    mult = np.r_[np.full(2, 0.2), np.full(fl.n - 2, 1.0)]
    speeds = mult * rt.hw.device_flops
    static = program_compute_time(rt, canonical_program(fl), speeds)
    prog = make_schedule("adaptive_tau", fl, speeds=mult)(0, None)
    adapt = program_compute_time(rt, prog, speeds)
    assert adapt < static / 2


def test_pi_decay_charges_fewer_comm_seconds():
    rt = paper_runtime_model()
    sched = make_schedule("pi_decay", _FL, decay_round=1, pi_late=1)
    hi = program_comm_time(rt, "ce_fedavg", sched(0, None))
    lo = program_comm_time(rt, "ce_fedavg", sched(3, None))
    W = rt.wl.model_bits(rt.hw)
    assert hi - lo == pytest.approx((_FL.pi - 1) * W / rt.hw.b_e2e)


def test_run_wall_clock_charges_adaptive_rounds_cheaper():
    """End to end: identical fleet + seeds, adaptive-τ schedule, the
    wall-clock harness charges less time per round than static."""
    sc = ScenarioConfig(speed_dist="bimodal", slow_fraction=0.25,
                        slow_factor=0.2, seed=1)
    fl = dataclasses.replace(_FL, tau=4, q=1)
    rt = compute_bound_runtime_model()
    t_static = run_wall_clock(_sim(fl, scenario=sc), rt, 2)
    t_adapt = run_wall_clock(
        _sim(fl, scenario=sc, schedule="adaptive_tau"), rt, 2)
    assert t_adapt["wall_time"][-1] < t_static["wall_time"][-1]


# ---------------------------------------------------------------------------
# adaptive-τ execution semantics
# ---------------------------------------------------------------------------

def test_tau_dev_cutoff_freezes_devices_mid_block():
    """A device whose cutoff is k must leave the block with exactly the
    state it had after its k-th step — frozen like a masked device —
    checked by comparing against a plain run with tau=cutoff."""
    fl = dataclasses.replace(_FL, tau=3, q=1, pi=1, num_clusters=1,
                             devices_per_cluster=2)
    cut = RoundProgram(
        (MaskRenorm(), LocalSteps(3, adaptive=True), IntraMix(),
         InterGossip(1)),
        tau_dev=np.array([3, 1], np.int32))
    s = _sim(fl, schedule=cut)
    ref = _sim(fl)
    s.step_round()
    ref.step_round()
    # device 0 ran all 3 steps with the same keys as the static run
    for la, lb in zip(jax.tree.leaves(s.mom), jax.tree.leaves(ref.mom)):
        np.testing.assert_allclose(np.asarray(la)[0], np.asarray(lb)[0],
                                   atol=1e-6)
        # device 1 stopped after step 1: its momentum differs
    diffs = [float(np.abs(np.asarray(la)[1] - np.asarray(lb)[1]).max())
             for la, lb in zip(jax.tree.leaves(s.mom),
                               jax.tree.leaves(ref.mom))]
    assert max(diffs) > 0
