"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("BH,Sq,Sk,D", [
    (4, 256, 256, 64), (2, 200, 200, 64), (2, 128, 384, 128),
    (1, 512, 512, 64), (3, 130, 257, 128),
])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0),
                                           (True, 100)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(BH, Sq, Sk, D, causal, window, dtype):
    if not causal and Sq > Sk:
        pytest.skip("irrelevant combo")
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (BH, Sq, D)).astype(dtype)
    k = jax.random.normal(ks[1], (BH, Sk, D)).astype(dtype)
    v = jax.random.normal(ks[2], (BH, Sk, D)).astype(dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol)


def test_flash_attention_gqa_adapter_matches_model_attention():
    from repro.models.layers import attention_core
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, S, H, Hkv, D = 2, 256, 8, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    out = ops.flash_attention_bshd(q, k, v, causal=True, interpret=True)
    exp = attention_core(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=1e-4)


@pytest.mark.parametrize("BK,H,C,P,N", [
    (4, 3, 128, 64, 32), (2, 5, 256, 64, 128), (1, 2, 128, 128, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_intra_chunk_sweep(BK, H, C, P, N, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = (jax.random.normal(ks[0], (BK, H, C, P))).astype(dtype)
    a = (-jnp.abs(jax.random.normal(ks[1], (BK, H, C))) * 0.1).astype(dtype)
    B = jax.random.normal(ks[2], (BK, C, N)).astype(dtype)
    Cc = jax.random.normal(ks[3], (BK, C, N)).astype(dtype)
    dt = (jnp.abs(jax.random.normal(ks[4], (BK, H, C))) * 0.1).astype(dtype)
    y1, s1 = ops.ssd_intra_chunk(x, a, B, Cc, dt, interpret=True)
    y2, s2 = ref.ssd_intra_chunk_ref(x, a, B, Cc, dt)
    tol = 1e-4 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=tol,
                               rtol=tol)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=tol,
                               rtol=tol)


def test_ssd_kernel_inside_full_model_path():
    """ssd_chunked(intra_fn=pallas kernel) == pure-jnp ssd_chunked."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    B, S, H, P, N, chunk = 2, 160, 4, 32, 16, 64
    x = jax.random.normal(ks[0], (B, S, H, P))
    dtv = jnp.abs(jax.random.normal(ks[1], (B, S, H))) * 0.1
    A = -jnp.abs(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    y_ref, st_ref = ssd_chunked(x, dtv, A, Bm, Cm, chunk)
    y_k, st_k = ssd_chunked(x, dtv, A, Bm, Cm, chunk,
                            intra_fn=ops.ssd_intra_fn(interpret=True))
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_ref, np.float32), atol=1e-3)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_ref),
                               atol=1e-3)


@pytest.mark.parametrize("n,T", [(8, 5000), (16, 4096), (64, 1000),
                                 (4, 123)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gossip_mix_sweep(n, T, dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    W = jax.random.uniform(ks[0], (n, n))
    W = W / W.sum(0)
    Y = jax.random.normal(ks[1], (n, T)).astype(dtype)
    out = ops.gossip_mix_flat(W, Y, interpret=True)
    exp = ref.gossip_mix_ref(W, Y)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol,
                               rtol=tol)


def test_gossip_mix_tree_matches_dense_mix():
    """Fused kernel pass == the paper's per-leaf operator application."""
    from repro.core.cefedavg import mix
    from repro.core.topology import (inter_cluster_operator, mixing_matrix,
                                     ring)
    n = 8
    W = inter_cluster_operator([2] * 4, mixing_matrix(ring(4)), pi=3)
    params = {"a": jax.random.normal(jax.random.PRNGKey(5), (n, 17, 3)),
              "b": jax.random.normal(jax.random.PRNGKey(6), (n, 41))}
    got = ops.gossip_mix_tree(W, params, interpret=True)
    exp = mix(W, params)
    for g, e in zip(jax.tree.leaves(got), jax.tree.leaves(exp)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(e), atol=1e-5)
