"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


@pytest.mark.parametrize("BH,Sq,Sk,D", [
    (4, 256, 256, 64), (2, 200, 200, 64), (2, 128, 384, 128),
    (1, 512, 512, 64), (3, 130, 257, 128),
])
@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0),
                                           (True, 100)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(BH, Sq, Sk, D, causal, window, dtype):
    if not causal and Sq > Sk:
        pytest.skip("irrelevant combo")
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (BH, Sq, D)).astype(dtype)
    k = jax.random.normal(ks[1], (BH, Sk, D)).astype(dtype)
    v = jax.random.normal(ks[2], (BH, Sk, D)).astype(dtype)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              interpret=True)
    exp = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol)


def test_flash_attention_gqa_adapter_matches_model_attention():
    from repro.models.layers import attention_core
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, S, H, Hkv, D = 2, 256, 8, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, D))
    k = jax.random.normal(ks[1], (B, S, Hkv, D))
    v = jax.random.normal(ks[2], (B, S, Hkv, D))
    out = ops.flash_attention_bshd(q, k, v, causal=True, interpret=True)
    exp = attention_core(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=1e-4)


@pytest.mark.parametrize("BK,H,C,P,N", [
    (4, 3, 128, 64, 32), (2, 5, 256, 64, 128), (1, 2, 128, 128, 64),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_intra_chunk_sweep(BK, H, C, P, N, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    x = (jax.random.normal(ks[0], (BK, H, C, P))).astype(dtype)
    a = (-jnp.abs(jax.random.normal(ks[1], (BK, H, C))) * 0.1).astype(dtype)
    B = jax.random.normal(ks[2], (BK, C, N)).astype(dtype)
    Cc = jax.random.normal(ks[3], (BK, C, N)).astype(dtype)
    dt = (jnp.abs(jax.random.normal(ks[4], (BK, H, C))) * 0.1).astype(dtype)
    y1, s1 = ops.ssd_intra_chunk(x, a, B, Cc, dt, interpret=True)
    y2, s2 = ref.ssd_intra_chunk_ref(x, a, B, Cc, dt)
    tol = 1e-4 if dtype == jnp.float32 else 0.15
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=tol,
                               rtol=tol)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), atol=tol,
                               rtol=tol)


def test_ssd_kernel_inside_full_model_path():
    """ssd_chunked(intra_fn=pallas kernel) == pure-jnp ssd_chunked."""
    from repro.models.ssm import ssd_chunked
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    B, S, H, P, N, chunk = 2, 160, 4, 32, 16, 64
    x = jax.random.normal(ks[0], (B, S, H, P))
    dtv = jnp.abs(jax.random.normal(ks[1], (B, S, H))) * 0.1
    A = -jnp.abs(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))
    y_ref, st_ref = ssd_chunked(x, dtv, A, Bm, Cm, chunk)
    y_k, st_k = ssd_chunked(x, dtv, A, Bm, Cm, chunk,
                            intra_fn=ops.ssd_intra_fn(interpret=True))
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_ref, np.float32), atol=1e-3)
    np.testing.assert_allclose(np.asarray(st_k), np.asarray(st_ref),
                               atol=1e-3)


@pytest.mark.parametrize("n,T", [(8, 5000), (16, 4096), (64, 1000),
                                 (4, 123)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gossip_mix_sweep(n, T, dtype):
    ks = jax.random.split(jax.random.PRNGKey(4), 2)
    W = jax.random.uniform(ks[0], (n, n))
    W = W / W.sum(0)
    Y = jax.random.normal(ks[1], (n, T)).astype(dtype)
    out = ops.gossip_mix_flat(W, Y, interpret=True)
    exp = ref.gossip_mix_ref(W, Y)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), atol=tol,
                               rtol=tol)


def test_gossip_mix_tree_matches_dense_mix():
    """Fused kernel pass == the paper's per-leaf operator application."""
    from repro.core.cefedavg import mix
    from repro.core.topology import (inter_cluster_operator, mixing_matrix,
                                     ring)
    n = 8
    W = inter_cluster_operator([2] * 4, mixing_matrix(ring(4)), pi=3)
    params = {"a": jax.random.normal(jax.random.PRNGKey(5), (n, 17, 3)),
              "b": jax.random.normal(jax.random.PRNGKey(6), (n, 41))}
    got = ops.gossip_mix_tree(W, params, interpret=True)
    exp = mix(W, params)
    for g, e in zip(jax.tree.leaves(got), jax.tree.leaves(exp)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(e), atol=1e-5)


# -- cold-codec kernels (streamed paging path) -------------------------------

_SEGMENTS = ((0, 100), (100, 37), (137, 263))   # irregular FlatLayout-style


def _cold_rows(S=13, T=400, seed=7):
    rng = np.random.default_rng(seed)
    rows = (rng.standard_normal((S, T)) * 3).astype(np.float32)
    rows[2] = 0.0                      # all-zero row: the 1e-12 scale floor
    rows[5, :100] = 1e-9               # near-zero segment
    return rows


@pytest.mark.parametrize("codec", ["f32", "f16", "int8"])
def test_cold_codec_kernel_matches_host_codec(codec):
    """Pallas encode/decode (interpret) is byte-identical to the host
    oracle in core/compress.py — the property that makes device-side
    paging a drop-in for the PR 9 host codec."""
    from repro.core.compress import decode_cold_rows, encode_cold_rows
    from repro.kernels import cold_codec
    rows = _cold_rows()
    host = encode_cold_rows(rows, codec, _SEGMENTS)
    for kw in (dict(use_pallas=False),
               dict(use_pallas=True, interpret=True)):
        q, s = cold_codec.encode_rows(jnp.asarray(rows), codec,
                                      _SEGMENTS, **kw)
        assert np.asarray(q).dtype == host["q"].dtype
        np.testing.assert_array_equal(np.asarray(q), host["q"])
        np.testing.assert_allclose(np.asarray(s), host["scale"],
                                   rtol=1e-7)
        dec = cold_codec.decode_rows(q, s, codec, _SEGMENTS, **kw)
        np.testing.assert_allclose(
            np.asarray(dec), decode_cold_rows(host, codec, _SEGMENTS),
            atol=1e-6)


@pytest.mark.parametrize("codec,tol", [("f32", 0.0), ("f16", 1e-3),
                                       ("int8", 4e-2)])
def test_cold_codec_kernel_roundtrip_error_bounds(codec, tol):
    """interpret-mode decode(encode(x)) stays within the codec's bound
    (exact for f32; f16 ~2^-11 relative; int8 scale/2 per segment)."""
    from repro.kernels import cold_codec
    rows = _cold_rows(S=9)
    q, s = cold_codec.encode_rows(jnp.asarray(rows), codec, _SEGMENTS,
                                  use_pallas=True, interpret=True)
    dec = np.asarray(cold_codec.decode_rows(q, s, codec, _SEGMENTS,
                                            use_pallas=True,
                                            interpret=True))
    if codec == "f32":
        np.testing.assert_array_equal(dec, rows)
        return
    err = np.abs(dec - rows)
    assert err.max() <= tol * max(1.0, np.abs(rows).max()), err.max()
    # re-quantization fixed point: a decoded row re-encodes to itself
    q2, s2 = cold_codec.encode_rows(jnp.asarray(dec), codec, _SEGMENTS,
                                    use_pallas=True, interpret=True)
    np.testing.assert_array_equal(np.asarray(q2), np.asarray(q))


def test_quantize_int8_blocked_matches_cold_codec():
    """The uplink quantizer (kernels/quantize.py) and the cold codec
    share one affine scheme: per-1024-block quantization of a flat row
    equals encode_cold_rows over a blocked single-row layout."""
    from repro.core.compress import encode_cold_rows
    from repro.kernels.quantize import (dequantize_int8_blocked,
                                        quantize_int8_blocked)
    T, block = 4096, 1024
    rng = np.random.default_rng(11)
    x = (rng.standard_normal(T) * 2).astype(np.float32)
    codes, scales = quantize_int8_blocked(jnp.asarray(x), block=block,
                                          interpret=True)
    # blocks of the flat vector == rows of a (nb, block) single-segment
    # layout: per-row scale IS the per-block scale
    host = encode_cold_rows(x.reshape(-1, block), "int8",
                            ((0, block),))
    np.testing.assert_array_equal(
        np.asarray(codes).reshape(-1, block), host["q"])
    np.testing.assert_allclose(np.asarray(scales), host["scale"][:, 0],
                               rtol=1e-7)
    deq = dequantize_int8_blocked(codes, scales, block=block)
    np.testing.assert_allclose(np.asarray(deq), x,
                               atol=np.asarray(scales).max() / 2 + 1e-7)
